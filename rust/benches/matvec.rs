//! Microbenchmarks for the tensor kernels (the NEON-kernel analogs).
//!
//! Run: `cargo bench --bench matvec`.  Uses the in-crate bench harness
//! (S28); reports mean/p50/p95 per op plus effective GB/s, the number to
//! compare against the host's streaming bandwidth (§Perf roofline).
//!
//! The first section is the per-ISA dispatch comparison: every backend
//! the host can run ([`simd::kernels_for`]) is forced in turn and the
//! same fused matvec sweep is timed per dtype, printing GB/s and the
//! speedup over the scalar reference (all backends are bit-identical, so
//! the speedup is the whole story).

use rwkv_lite::pool::Par;
use rwkv_lite::tensor::{
    matmat_in_out, matmat_rows, matvec_in_out, matvec_rows, matvec_rows_indexed, simd, Mat,
    ShadowView, SimdBackend,
};
use rwkv_lite::util::timer::bench;
use rwkv_lite::util::XorShift;

fn randv(r: &mut XorShift, n: usize) -> Vec<f32> {
    (0..n).map(|_| r.normal()).collect()
}

/// One dot-path matvec sweep per dtype on the forced-active backend;
/// returns p50 seconds per dtype (for the speedup-vs-scalar column).
fn isa_sweep(label: &str, wmats: &[(&str, &Mat)], x: &[f32], out: &mut [f32]) -> Vec<f64> {
    wmats
        .iter()
        .map(|&(dt, w)| {
            let s = bench(&format!("matvec_rows {dt:<4} {label}"), 50, 0.3, || {
                matvec_rows(w, x, out);
            });
            let gbs = w.nbytes() as f64 / s.p50_s / 1e9;
            println!("    -> {gbs:.2} GB/s");
            s.p50_s
        })
        .collect()
}

fn main() {
    let mut r = XorShift::new(7);
    println!(
        "tensor kernel microbench (dims match the medium model; host auto simd = {})\n",
        simd::detect().name()
    );

    // --- per-ISA dispatch comparison (GB/s per dtype x backend) ---------
    {
        let (rows, cols) = (768usize, 768usize);
        let wf = randv(&mut r, rows * cols);
        let q: Vec<i8> = wf.iter().map(|v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();
        let wmats: Vec<(&str, Mat)> = vec![
            ("f32", Mat::from_f32(rows, cols, wf.clone())),
            ("f16", Mat::f32_to_f16_mat(rows, cols, &wf)),
            ("i8", Mat::I8 { rows, cols, data: q, scale: vec![0.025; rows] }),
            ("q4", Mat::quantize_q4_mat(rows, cols, &wf)),
            ("q4_1", Mat::quantize_q4_1_mat(rows, cols, &wf)),
        ];
        let wrefs: Vec<(&str, &Mat)> = wmats.iter().map(|(n, m)| (*n, m)).collect();
        let x = randv(&mut r, cols);
        let mut out = vec![0.0f32; rows];
        let backends: Vec<SimdBackend> =
            [SimdBackend::Scalar, SimdBackend::Neon, SimdBackend::Avx2]
                .into_iter()
                .filter(|&b| simd::kernels_for(b).is_some())
                .collect();
        println!("per-ISA dispatch comparison ({rows}x{cols}, forced via simd::select)\n");
        let mut scalar_p50: Vec<f64> = Vec::new();
        for &b in &backends {
            simd::select(Some(b)).expect("kernels_for said this backend is available");
            println!("  backend = {}", b.name());
            let p50s = isa_sweep(&format!("{rows}x{cols} [{}]", b.name()), &wrefs, &x, &mut out);
            if b == SimdBackend::Scalar {
                scalar_p50 = p50s;
            } else {
                for (&(dt, _), (&sp, &bp)) in wrefs.iter().zip(scalar_p50.iter().zip(&p50s)) {
                    println!("    {dt:<4} speedup vs scalar: {:.2}x", sp / bp);
                }
            }
            println!();
        }
        simd::select(None).expect("auto select always succeeds");
    }

    for &(rows, cols) in &[(192usize, 192usize), (192, 672), (1024, 192)] {
        let wf = randv(&mut r, rows * cols);
        let x = randv(&mut r, rows);
        let xc = randv(&mut r, cols);
        let w32 = Mat::from_f32(rows, cols, wf.clone());
        let w16 = Mat::f32_to_f16_mat(rows, cols, &wf);
        let q: Vec<i8> = wf.iter().map(|v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();
        let w8 = Mat::I8 { rows, cols, data: q, scale: vec![0.025; cols] };
        let mut out = vec![0.0f32; cols];
        let mut out_r = vec![0.0f32; rows];
        let mut acc = Vec::new();
        let bytes32 = (rows * cols * 4) as f64;

        let s = bench(&format!("matvec_in_out f32 {rows}x{cols}"), 50, 0.4, || {
            out.fill(0.0);
            matvec_in_out(&x, &w32, &mut out, &mut acc);
        });
        println!("    -> {:.2} GB/s", bytes32 / s.p50_s / 1e9);
        let s = bench(&format!("matvec_in_out f16 {rows}x{cols}"), 50, 0.4, || {
            out.fill(0.0);
            matvec_in_out(&x, &w16, &mut out, &mut acc);
        });
        println!("    -> {:.2} GB/s", bytes32 / 2.0 / s.p50_s / 1e9);
        let s = bench(&format!("matvec_in_out i8  {rows}x{cols} (fused dequant)"), 50, 0.4, || {
            out.fill(0.0);
            matvec_in_out(&x, &w8, &mut out, &mut acc);
        });
        println!("    -> {:.2} GB/s", bytes32 / 4.0 / s.p50_s / 1e9);
        // group-quantized: GB/s is computed over the PACKED footprint
        // (nibbles + f16 group scales), the bytes that actually stream
        let wq4 = Mat::quantize_q4_mat(rows, cols, &wf);
        let wq41 = Mat::quantize_q4_1_mat(rows, cols, &wf);
        let (bq4, bq41) = (wq4.nbytes() as f64, wq41.nbytes() as f64);
        let s = bench(&format!("matvec_in_out q4  {rows}x{cols} (fused dequant)"), 50, 0.4, || {
            out.fill(0.0);
            matvec_in_out(&x, &wq4, &mut out, &mut acc);
        });
        println!("    -> {:.2} GB/s", bq4 / s.p50_s / 1e9);
        let s = bench(&format!("matvec_in_out q4_1 {rows}x{cols} (fused dequant)"), 50, 0.4, || {
            out.fill(0.0);
            matvec_in_out(&x, &wq41, &mut out, &mut acc);
        });
        println!("    -> {:.2} GB/s", bq41 / s.p50_s / 1e9);
        bench(&format!("matvec_rows   f16 {rows}x{cols}"), 50, 0.4, || {
            matvec_rows(&w16, &xc, &mut out_r);
        });
        bench(&format!("matvec_rows   q4  {rows}x{cols}"), 50, 0.4, || {
            matvec_rows(&wq4, &xc, &mut out_r);
        });
        // sparse row selection at 80% sparsity (the paper's regime)
        let idx: Vec<u32> = (0..rows as u32).filter(|i| i % 5 == 0).collect();
        let mut out_s = vec![0.0f32; idx.len()];
        bench(&format!("matvec_rows_indexed f16 {}/{} rows", idx.len(), rows), 50, 0.4, || {
            matvec_rows_indexed(&w16, &idx, &xc, &mut out_s);
        });
        bench(&format!("matvec_rows_indexed q4  {}/{} rows", idx.len(), rows), 50, 0.4, || {
            matvec_rows_indexed(&wq4, &idx, &xc, &mut out_s);
        });
        println!();
    }

    // multi-vector kernels: per-slot-token cost should FALL with B because
    // each weight row streams once per call and serves every slot
    println!("batched matmat kernels (192x672 f16, per-slot-token amortization)\n");
    let (rows, cols) = (192usize, 672usize);
    let wf = randv(&mut r, rows * cols);
    let w16 = Mat::f32_to_f16_mat(rows, cols, &wf);
    let bytes16 = (rows * cols * 2) as f64;
    for &b in &[1usize, 2, 4, 8] {
        let xs = randv(&mut r, b * rows);
        let xsc = randv(&mut r, b * cols);
        let mut outs = vec![0.0f32; b * cols];
        let mut outs_r = vec![0.0f32; b * rows];
        let mut scratch = Vec::new();
        let s = bench(&format!("matmat_in_out f16 B={b}"), 50, 0.3, || {
            outs.fill(0.0);
            matmat_in_out(&xs, &w16, &mut outs, &mut scratch, Par::serial());
        });
        println!("    -> {:.2} GB/s per slot-token", bytes16 * b as f64 / s.p50_s / 1e9);
        let s = bench(&format!("matmat_rows   f16 B={b}"), 50, 0.3, || {
            matmat_rows(&w16, &xsc, &mut outs_r, Par::serial());
        });
        println!("    -> {:.2} GB/s per slot-token", bytes16 * b as f64 / s.p50_s / 1e9);
    }
    println!();

    // 1-bit predictor shadow (D=192, F=672 like the medium model)
    let (d, f) = (192usize, 672usize);
    let packed: Vec<u8> = (0..d.div_ceil(8) * f).map(|_| (r.next_u64() & 0xff) as u8).collect();
    let scale = randv(&mut r, f).iter().map(|v| v.abs() + 0.01).collect::<Vec<_>>();
    let x = randv(&mut r, d);
    let mut out = vec![0.0f32; f];
    let shadow = ShadowView::bits(&packed, &scale, d);
    bench(&format!("ShadowView 1-bit {d}x{f} (shadow predictor)"), 50, 0.4, || {
        shadow.matvec(&x, &mut out);
    });
}
