//! Dynamic-batching throughput: aggregate tok/s vs batch size — shows the
//! coordinator's batching actually amortizes per-round work (sparse row
//! unions, scheduler overhead) across concurrent requests.
//!
//! Run: `cargo bench --bench serving_throughput` (artifacts required).

use std::path::PathBuf;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator, Event, Request};
use rwkv_lite::util::Stopwatch;

fn main() {
    let model = "rwkv-ours-small";
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("models").join(format!("{model}.json")).exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    println!("serving throughput vs batch size ({model}, 24 tok/request)\n");
    println!("{:>6} {:>10} {:>14} {:>12}", "batch", "requests", "agg tok/s", "p50 lat (s)");
    for &batch in &[1usize, 2, 4, 8, 16] {
        let cfg = EngineConfig::all_techniques(model, artifacts.clone());
        let coordinator = Coordinator::spawn(
            move || rwkv_lite::engine::RwkvEngine::load(cfg),
            BatchPolicy { max_batch: batch, window_ms: 2 },
        );
        let n_req = batch * 3;
        let wall = Stopwatch::start();
        let rxs: Vec<_> = (0..n_req as u64)
            .map(|i| {
                coordinator.submit(Request {
                    id: i,
                    prompt: vec![2, 100 + i as u32 % 64],
                    max_tokens: 24,
                    temperature: 0.8,
                    top_p: 0.95,
                })
            })
            .collect();
        let mut total = 0usize;
        let mut lats = Vec::new();
        for rx in rxs {
            for ev in rx {
                match ev {
                    Event::Done { tokens, seconds } => {
                        total += tokens;
                        lats.push(seconds);
                        break;
                    }
                    Event::Error { message } => panic!("{message}"),
                    _ => {}
                }
            }
        }
        let secs = wall.elapsed_secs();
        println!(
            "{:>6} {:>10} {:>14.1} {:>12.3}",
            batch,
            n_req,
            total as f64 / secs,
            rwkv_lite::util::percentile(&lats, 50.0)
        );
    }
}
