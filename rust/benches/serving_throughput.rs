//! Serving throughput under the session-round scheduler.
//!
//! Part 1 — decode: aggregate tok/s vs batch size, showing the
//! weight-streaming round amortizes one pass over the weights across
//! concurrent requests (weight-GB per round ~constant in B for dense
//! layers).
//!
//! Part 2 — prefill: a prompt-heavy sweep over `prefill_chunk`, showing
//! chunked `(B', T)` prefill amortizes the SAME weight pass across the
//! chunk: weight-GB per prompt token falls ~1/T vs the old one-token-
//! per-round prompt loop (chunk=1 column).
//!
//! Part 3 — intra-round parallelism: a threads × batch sweep at the
//! engine level, showing aggregate tok/s rising with threads at fixed B
//! (bit-identical output — the knob only moves compute across cores)
//! plus the per-phase round split (wkv / matmul / head).
//!
//! Part 4 — layerwise streaming: prefetch on/off × threads at fixed B,
//! showing the double-buffered block prefetcher hides the per-layer load
//! stall: the round thread's exposed block acquisition time
//! (`round_block_load_secs`) collapses to the prefetch wait
//! (`round_prefetch_wait_secs`), which stays well under the off-row's
//! block load time — the streaming genuinely overlapped compute.
//!
//! Part 5 — prefix-state cache (runs only with `--state-cache-mb N`):
//! one shared system prompt + short per-request user turns, cold vs
//! warm.  The first request prefills the whole prompt and populates the
//! cache; every later request forks off the cached prefix state, so its
//! prefill tokens (and prefill weight-GB, and time-to-first-token)
//! collapse to just the un-cached suffix.  The sweep ASSERTS
//! `cache_hits > 0` (bit-identity is covered by
//! `tests/state_cache_equivalence.rs`), which makes it the warm-cache
//! release smoke: `-- --smoke --state-cache-mb 64`.  Gated on the flag
//! so the other CI smoke invocations stay distinct.
//!
//! Part 8 — open-loop serving (runs only with `--arrival-rate λ`):
//! requests arrive on a DETERMINISTIC seeded Poisson process (exponential
//! inter-arrivals, `--seed` pins the stream) instead of the closed-loop
//! sweeps' submit-all-then-drain shape, so queueing delay is real: late
//! arrivals wait behind a loaded system exactly as they would in
//! production.  Reports aggregate tok/s AND tail latency — p50/p99 TTFT,
//! ITL, queue wait and total — read from the coordinator's lock-free
//! histograms (server-side, so slow client draining cannot skew them).
//! Also exercises the round-trace ring: the run writes `--trace-out`
//! (default: a temp file) as JSONL and asserts every line parses back.
//! This is the standing workload ROADMAP items 3–5 are measured on.
//!
//! Run: `cargo bench --bench serving_throughput` (artifacts required;
//! falls back to a synthetic checkpoint when they are missing so the
//! bench is always runnable).  `-- --smoke` runs a seconds-long variant
//! (B<=2, few tokens) used by CI to exercise the serving path in release
//! mode; `-- --threads N` pins the thread sweep to {1, N} and runs the
//! decode/prefill sweeps with N compute threads (CI smokes `--threads 4`);
//! `-- --strategy layerwise` runs parts 1–3 under layerwise loading so CI
//! exercises the streaming+prefetch path in release (part 4 always runs
//! both prefetch settings); `-- --state-cache-mb N` enables part 5 with
//! an N-MiB cache budget (omitted, part 5 is skipped); `-- --overload`
//! enables part 6 (bounded-admission shedding); `-- --quantized` enables
//! part 7 (f16 vs Q4 bytes-per-round, asserting the <= 0.55x contract);
//! `-- --arrival-rate λ` enables part 8 (open-loop, λ requests/sec;
//! `--seed` pins the arrival stream, `--trace-out` names the JSONL).

use std::path::{Path, PathBuf};

use std::sync::OnceLock;

use rwkv_lite::config::{EngineConfig, LoadStrategy, SimdMode};
use rwkv_lite::tensor::simd;
use rwkv_lite::coordinator::{
    batcher::BatchPolicy, AdmissionPolicy, Coordinator, CoordinatorConfig, Event, Request,
};
use rwkv_lite::engine::session::Session;
use rwkv_lite::engine::state_cache::{CacheConfig, StateCache};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};
use rwkv_lite::util::Stopwatch;

/// `--flag value` / `--flag=value` lookup over argv — the one parser
/// every bench knob shares.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    let prefixed = format!("{flag}=");
    args.iter().enumerate().find_map(|(i, a)| {
        a.strip_prefix(&prefixed)
            .map(str::to_string)
            .or_else(|| (a == flag).then(|| args.get(i + 1).cloned().unwrap_or_default()))
    })
}

/// Histogram `(count, sum_secs)` point — the sweeps bracket their timed
/// windows with two points and report the delta's mean, so warm-up
/// rounds never pollute the phase means.
fn hist_point(m: &rwkv_lite::metrics::Registry, name: &str) -> (u64, f64) {
    m.hist_snapshot(name).map(|s| (s.count, s.sum_secs)).unwrap_or((0, 0.0))
}

/// Mean milliseconds of the samples added to `name` since `base` (a
/// [`hist_point`] captured before the timed window).
fn hist_window_mean_ms(m: &rwkv_lite::metrics::Registry, name: &str, base: (u64, f64)) -> f64 {
    let (c0, s0) = base;
    let (c, s) = hist_point(m, name);
    if c > c0 {
        (s - s0) / (c - c0) as f64 * 1e3
    } else {
        0.0
    }
}

/// `--simd auto|scalar|neon|avx2` parsed once in `main`; every sweep's
/// engine config picks it up so forced backends apply to ALL parts.
static SIMD: OnceLock<SimdMode> = OnceLock::new();

fn simd_mode() -> SimdMode {
    *SIMD.get().expect("main parses --simd before any sweep")
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // `--simd auto|scalar|neon|avx2` (or `--simd=...`): force the kernel
    // backend for every engine the sweeps build; invalid values abort.
    // Bit-identical across backends — this only moves the numbers.
    let simd_mode_arg: SimdMode = flag_value(&args, "--simd")
        .map(|v| SimdMode::parse(&v).unwrap_or_else(|e| panic!("{e}")))
        .unwrap_or(SimdMode::Auto);
    SIMD.set(simd_mode_arg).expect("--simd parsed once");
    let backend = simd::select(simd_mode_arg.requested()).unwrap_or_else(|e| panic!("{e}"));
    println!("active simd kernel backend: {} (--simd {})\n", backend.name(), simd_mode_arg.name());
    // `--threads N` / `--threads=N`: pin the compute-thread count for all
    // sweeps (0 = all cores); invalid values abort instead of silently
    // running single-threaded
    let pinned: Option<usize> = flag_value(&args, "--threads")
        .map(|v| v.parse().unwrap_or_else(|_| panic!("--threads needs a number, got '{v}'")))
        .map(|n: usize| {
            if n == 0 {
                std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
            } else {
                n
            }
        });
    // `--strategy full|layerwise` (or `--strategy=...`): the loading
    // strategy for parts 1–3 (part 4 is always layerwise — that is its
    // point); invalid values abort
    let strategy: LoadStrategy = flag_value(&args, "--strategy")
        .map(|v| LoadStrategy::parse(&v).unwrap_or_else(|e| panic!("{e}")))
        .unwrap_or(LoadStrategy::Full);
    // `--state-cache-mb N` (or `--state-cache-mb=N`): part 5's prefix-
    // state cache budget.  0 (the default) SKIPS part 5, so the plain
    // `--smoke` CI steps don't duplicate the dedicated warm-cache smoke;
    // invalid values abort
    let cache_mb: usize = flag_value(&args, "--state-cache-mb")
        .map(|v| {
            v.parse()
                .unwrap_or_else(|_| panic!("--state-cache-mb needs a number, got '{v}'"))
        })
        .unwrap_or(0);
    let mut model = "rwkv-ours-small".to_string();
    let mut artifacts = PathBuf::from("artifacts");
    let mut synth_guard: Option<PathBuf> = None;
    if smoke || !artifacts.join("models").join(format!("{model}.json")).exists() {
        // no artifacts (or smoke mode): synthesize an f16 medium-ish model
        // so the batching economics are still measurable
        let dir = std::env::temp_dir().join(format!("rwkv-bench-synth-{}", std::process::id()));
        let mut spec = SynthSpec::tiny();
        spec.layers = 6;
        spec.heads = 12;
        spec.head_size = 16; // D=192, the paper's medium shape
        spec.ffn = 672;
        spec.vocab = 1024;
        spec.f16 = true;
        eprintln!("NOTE: using a synthetic f16 model at {}", dir.display());
        write_synth_rwkv(&dir, "synthetic-medium", &spec).expect("synth model");
        model = "synthetic-medium".to_string();
        artifacts = dir.clone();
        synth_guard = Some(dir);
    }

    let threads = pinned.unwrap_or(1);
    decode_sweep(&model, &artifacts, smoke, threads, strategy);
    prefill_sweep(&model, &artifacts, smoke, threads, strategy);
    thread_sweep(&model, &artifacts, smoke, pinned, strategy);
    layerwise_sweep(&model, &artifacts, smoke, pinned);
    if cache_mb > 0 {
        state_cache_sweep(&model, &artifacts, smoke, threads, strategy, cache_mb);
    }
    // `--overload`: part 6, the bounded-admission release smoke — gated
    // on the flag so the other CI smoke invocations stay distinct
    if args.iter().any(|a| a == "--overload") {
        overload_smoke(&model, &artifacts, smoke, threads, strategy);
    }
    // `--quantized`: part 7, the sub-byte-weights release smoke — builds
    // its own f16 + q4 checkpoints, so it ignores the shared model
    if args.iter().any(|a| a == "--quantized") {
        quantized_smoke(smoke, threads, strategy);
    }
    // `--arrival-rate λ`: part 8, open-loop serving under a seeded
    // Poisson arrival process — tok/s plus p50/p99 TTFT/ITL tails
    if let Some(rate) = flag_value(&args, "--arrival-rate") {
        let rate: f64 = rate
            .parse()
            .ok()
            .filter(|r: &f64| r.is_finite() && *r > 0.0)
            .unwrap_or_else(|| panic!("--arrival-rate needs a positive req/s number, got '{rate}'"));
        let seed: u64 = flag_value(&args, "--seed")
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--seed needs a number, got '{v}'")))
            .unwrap_or(42);
        let trace_out = flag_value(&args, "--trace-out").map(PathBuf::from);
        open_loop_sweep(&model, &artifacts, smoke, threads, strategy, rate, seed, trace_out);
    }

    if let Some(dir) = synth_guard {
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Aggregate decode throughput vs dynamic batch size (coordinator path).
fn decode_sweep(
    model: &str,
    artifacts: &Path,
    smoke: bool,
    threads: usize,
    strategy: LoadStrategy,
) {
    let (batches, max_tokens, req_mult): (&[usize], usize, usize) =
        if smoke { (&[1, 2], 6, 2) } else { (&[1, 2, 4, 8], 24, 3) };
    println!(
        "serving throughput vs batch size ({model}, {max_tokens} tok/request, {threads} threads, {} loading)\n",
        strategy.name()
    );
    println!(
        "{:>6} {:>10} {:>14} {:>12} {:>14} {:>14}",
        "batch", "requests", "agg tok/s", "p50 lat (s)", "GB/round", "rounds"
    );
    for &batch in batches {
        let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
        cfg.simd = simd_mode();
        cfg.threads = threads;
        cfg.strategy = strategy;
        let coordinator = Coordinator::spawn(
            move || RwkvEngine::load(cfg),
            BatchPolicy { max_batch: batch, window_ms: 2 },
        );
        let n_req = batch * req_mult;
        let wall = Stopwatch::start();
        let rxs: Vec<_> = (0..n_req as u64)
            .map(|i| {
                coordinator.submit(Request {
                    id: i,
                    prompt: vec![2, 100 + i as u32 % 64],
                    max_tokens,
                    temperature: 0.8,
                    top_p: 0.95,
                    ..Request::default()
                })
            })
            .collect();
        let mut total = 0usize;
        let mut lats = Vec::new();
        for rx in rxs {
            for ev in rx {
                match ev {
                    Event::Done { tokens, seconds, .. } => {
                        total += tokens;
                        lats.push(seconds);
                        break;
                    }
                    Event::Error { message } => panic!("{message}"),
                    _ => {}
                }
            }
        }
        let secs = wall.elapsed_secs();
        let rounds = coordinator.metrics.counter("rounds").max(1);
        let round_bytes = coordinator.metrics.counter("round_weight_bytes");
        println!(
            "{:>6} {:>10} {:>14.1} {:>12.3} {:>14.4} {:>14}",
            batch,
            n_req,
            total as f64 / secs,
            rwkv_lite::util::percentile(&lats, 50.0),
            round_bytes as f64 / rounds as f64 / 1e9,
            rounds,
        );
    }
}

/// Prompt-heavy sweep: weight bytes per prompt token vs `prefill_chunk`
/// (engine-level session rounds; chunk=1 is the old per-token loop).
fn prefill_sweep(
    model: &str,
    artifacts: &Path,
    smoke: bool,
    threads: usize,
    strategy: LoadStrategy,
) {
    let (chunks, p, prompt_len): (&[usize], usize, usize) =
        if smoke { (&[1, 8], 2, 24) } else { (&[1, 2, 4, 8, 16], 4, 96) };
    println!(
        "\nprefill amortization ({model}, {p} concurrent prompts x {prompt_len} tokens, {} loading)\n",
        strategy.name()
    );
    println!(
        "{:>6} {:>16} {:>18} {:>16}",
        "chunk", "prefill tok/s", "GB/prompt-token", "prefill rounds"
    );
    for &chunk in chunks {
        let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
        cfg.simd = simd_mode();
        cfg.prefill_chunk = chunk;
        cfg.threads = threads;
        cfg.strategy = strategy;
        let mut engine = RwkvEngine::load(cfg).expect("load engine");
        // token ids stay small so the prompt is valid for any vocab size
        let prompt: Vec<u32> = (0..prompt_len as u32).map(|i| 2 + (i * 7) % 64).collect();
        let mut sessions: Vec<Session> = (0..p)
            .map(|i| {
                let mut s = Session::new(&engine, i as u64, &prompt);
                s.max_tokens = 2;
                s
            })
            .collect();
        let (mut prefill_secs, mut prefill_bytes, mut prefill_tokens, mut prefill_rounds) =
            (0.0f64, 0u64, 0usize, 0u64);
        while sessions.iter().any(|s| !s.is_done()) {
            let t = Stopwatch::start();
            let report = engine.step_round(&mut sessions).expect("round");
            if report.prefill_tokens > 0 {
                prefill_secs += t.elapsed_secs();
                prefill_bytes += report.round_weight_bytes;
                prefill_tokens += report.prefill_tokens;
                prefill_rounds += 1;
            }
        }
        println!(
            "{:>6} {:>16.1} {:>18.6} {:>16}",
            chunk,
            prefill_tokens as f64 / prefill_secs.max(1e-9),
            prefill_bytes as f64 / prefill_tokens.max(1) as f64 / 1e9,
            prefill_rounds,
        );
    }
    println!("\nGB/prompt-token falls ~1/chunk: one weight pass serves the whole chunk");
}

/// Intra-round parallelism: aggregate decode tok/s over a threads × batch
/// grid (engine-level rounds), with the per-phase round split.  Output is
/// bit-identical across the threads axis — only the wall clock moves.
fn thread_sweep(
    model: &str,
    artifacts: &Path,
    smoke: bool,
    pinned: Option<usize>,
    strategy: LoadStrategy,
) {
    let threads_list: Vec<usize> = match pinned {
        Some(n) if n > 1 => vec![1, n],
        Some(_) => vec![1],
        None if smoke => vec![1, 2],
        None => vec![1, 2, 4],
    };
    let (batches, steps): (&[usize], usize) = if smoke { (&[2], 8) } else { (&[1, 4, 8], 32) };
    println!("\nintra-round parallelism: decode tok/s over threads x batch\n");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "threads", "batch", "agg tok/s", "wkv ms", "matmul ms", "head ms", "round ms"
    );
    for &batch in batches {
        for &threads in &threads_list {
            let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
            cfg.simd = simd_mode();
            cfg.threads = threads;
            cfg.strategy = strategy;
            let mut engine = RwkvEngine::load(cfg).expect("load engine");
            let mut sessions: Vec<Session> = (0..batch)
                .map(|i| {
                    let mut s = Session::new(&engine, i as u64, &[2, 10 + i as u32]);
                    s.max_tokens = steps + 8; // never finishes inside the loop
                    s
                })
                .collect();
            // move every session into Decode (consume the tiny prompts)
            while sessions
                .iter()
                .any(|s| !matches!(s.phase(), rwkv_lite::engine::session::Phase::Decode))
            {
                engine.step_round(&mut sessions).expect("prefill round");
            }
            // phase means must cover ONLY the timed decode rounds below,
            // not the prefill warm-up rounds already observed above —
            // histogram (count, sum) deltas around the window give exact
            // window means without unbounded sample vectors
            let names = ["round_wkv_secs", "round_matmul_secs", "round_head_secs", "round_secs"];
            let base: Vec<(u64, f64)> =
                names.iter().map(|n| hist_point(&engine.metrics, n)).collect();
            let wall = Stopwatch::start();
            for _ in 0..steps {
                engine.step_round(&mut sessions).expect("decode round");
            }
            let secs = wall.elapsed_secs();
            let ms = |i: usize| hist_window_mean_ms(&engine.metrics, names[i], base[i]);
            println!(
                "{:>8} {:>6} {:>12.1} {:>12.3} {:>12.3} {:>12.3} {:>12.3}",
                threads,
                batch,
                (steps * batch) as f64 / secs,
                ms(0),
                ms(1),
                ms(2),
                ms(3),
            );
        }
    }
    println!("\ntok/s rises with threads at fixed batch; output is bit-identical across rows");
}

/// Layerwise streaming: prefetch on/off × threads at fixed batch
/// (engine-level decode rounds).  The `block ms` column is the round
/// thread's total exposed block-acquisition stall per round; `wait ms` is
/// the part spent waiting for an in-flight background load.  With
/// prefetch on, `block ms` ≈ `wait ms` and both sit well under the
/// off-row's `block ms` — block N+1 streamed while block N computed.
/// Output is bit-identical across every row.
fn layerwise_sweep(model: &str, artifacts: &Path, smoke: bool, pinned: Option<usize>) {
    let threads_list: Vec<usize> = match pinned {
        Some(n) if n > 1 => vec![1, n],
        Some(_) => vec![1],
        None if smoke => vec![1, 2],
        None => vec![1, 2, 4],
    };
    let (batch, steps): (usize, usize) = if smoke { (2, 6) } else { (4, 24) };
    println!("\nlayerwise streaming: decode rounds, prefetch on/off x threads (batch {batch})\n");
    println!(
        "{:>8} {:>9} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "threads", "prefetch", "agg tok/s", "round ms", "block ms", "wait ms", "blocks"
    );
    for &threads in &threads_list {
        for &prefetch in &[false, true] {
            let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
            cfg.simd = simd_mode();
            cfg.strategy = LoadStrategy::Layerwise;
            cfg.threads = threads;
            cfg.prefetch = prefetch;
            let mut engine = RwkvEngine::load(cfg).expect("load engine");
            let mut sessions: Vec<Session> = (0..batch)
                .map(|i| {
                    let mut s = Session::new(&engine, i as u64, &[2, 10 + i as u32]);
                    s.max_tokens = steps + 8; // never finishes inside the loop
                    s
                })
                .collect();
            // move every session into Decode (consume the tiny prompts)
            while sessions
                .iter()
                .any(|s| !matches!(s.phase(), rwkv_lite::engine::session::Phase::Decode))
            {
                engine.step_round(&mut sessions).expect("prefill round");
            }
            // window means via histogram (count, sum) deltas — the timed
            // decode rounds only, excluding the prefill warm-up above
            let names = ["round_secs", "round_block_load_secs", "round_prefetch_wait_secs"];
            let base: Vec<(u64, f64)> =
                names.iter().map(|n| hist_point(&engine.metrics, n)).collect();
            let blocks0 = engine.metrics.counter("blocks_prefetched");
            let wall = Stopwatch::start();
            for _ in 0..steps {
                engine.step_round(&mut sessions).expect("decode round");
            }
            let secs = wall.elapsed_secs();
            let ms = |i: usize| hist_window_mean_ms(&engine.metrics, names[i], base[i]);
            println!(
                "{:>8} {:>9} {:>12.1} {:>12.3} {:>12.3} {:>12.3} {:>8}",
                threads,
                if prefetch { "on" } else { "off" },
                (steps * batch) as f64 / secs,
                ms(0),
                ms(1),
                ms(2),
                engine.metrics.counter("blocks_prefetched") - blocks0,
            );
        }
    }
    println!(
        "\nprefetch on: the exposed block stall collapses to the prefetch wait \
         (wait << the off-row's block ms — streaming overlapped compute)"
    );
}

/// Prefix-state cache: one shared system prompt, distinct short user
/// turns.  Request 0 is cold (full prefill, populates the cache); every
/// later request forks from the deepest cached chunk boundary of the
/// shared prefix, so `prefill tok`, `prefill GB` and TTFT collapse to
/// the un-cached suffix.  The final assertions make this the warm-cache
/// release smoke.
fn state_cache_sweep(
    model: &str,
    artifacts: &Path,
    smoke: bool,
    threads: usize,
    strategy: LoadStrategy,
    cache_mb: usize,
) {
    let (sys_len, n_req, max_tokens): (usize, usize, usize) =
        if smoke { (24, 3, 4) } else { (96, 6, 8) };
    println!(
        "\nprefix-state cache: shared {sys_len}-token system prompt, distinct user turns \
         ({} MiB budget, {} loading)\n",
        cache_mb.max(1),
        strategy.name()
    );
    println!(
        "{:>8} {:>12} {:>13} {:>13} {:>12} {:>12}",
        "request", "cached tok", "prefill tok", "prefill GB", "ttft ms", "decode tok"
    );
    let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
    cfg.simd = simd_mode();
    cfg.threads = threads;
    cfg.strategy = strategy;
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let mut cache = StateCache::new(CacheConfig::with_mb(cache_mb.max(1)));
    // token ids stay small so the prompt is valid for any vocab size
    let system: Vec<u32> = (0..sys_len as u32).map(|i| 2 + (i * 5) % 64).collect();
    for r in 0..n_req {
        let mut prompt = system.clone();
        prompt.extend([68 + r as u32, 2 + r as u32]); // the user turn
        let (mut sess, cached) = Session::new_with_cache(&engine, r as u64, &prompt, &mut cache);
        sess.max_tokens = max_tokens;
        let wall = Stopwatch::start();
        let mut ttft = f64::NAN;
        let (mut prefill_tokens, mut prefill_bytes, mut decoded) = (0usize, 0u64, 0usize);
        while !sess.is_done() {
            let report = engine
                .step_round_cached(std::slice::from_mut(&mut sess), Some(&mut cache))
                .expect("round");
            if report.prefill_tokens > 0 {
                prefill_tokens += report.prefill_tokens;
                prefill_bytes += report.round_weight_bytes;
            }
            if ttft.is_nan() && !report.emitted.is_empty() {
                ttft = wall.elapsed_secs();
            }
            decoded += report.emitted.len();
        }
        println!(
            "{:>8} {:>12} {:>13} {:>13.6} {:>12.3} {:>12}",
            if r == 0 { "0 (cold)".to_string() } else { format!("{r} (warm)") },
            cached,
            prefill_tokens,
            prefill_bytes as f64 / 1e9,
            ttft * 1e3,
            decoded,
        );
    }
    let st = cache.stats();
    println!(
        "\ncache: {} hits / {} misses, {} tokens served from snapshots, \
         {} insertions, {} evictions, {:.2} MiB resident",
        st.hits,
        st.misses,
        st.hit_tokens,
        st.insertions,
        st.evictions,
        cache.bytes() as f64 / (1 << 20) as f64,
    );
    println!("warm rows: prefill collapses to the un-cached suffix — the state copy is free");
    // warm-cache smoke contract (CI runs `--smoke --state-cache-mb 64`):
    // every request after the first MUST hit the shared prefix
    assert!(st.hits as usize >= n_req - 1, "warm requests must hit the prefix-state cache");
    assert!(st.hit_tokens > 0, "cache hits must actually skip prefill tokens");
}

/// Part 7 — quantized-weights release smoke (CI runs `--smoke
/// --quantized`): the same synthetic model exported twice — f16 vs the
/// group-quantized Q4 hybrid recipe — and decoded under identical
/// configs.  Decode is bandwidth-bound, so the quantized round's weight
/// pass is the whole point: the smoke ASSERTS quantized GB/round <=
/// 0.55x the f16 figure (packed nibbles + f16 group scales ~ 0.31x per
/// matrix; embeddings and norms stay float).  Bit-exactness of the
/// quantized kernels is covered by `tests/properties.rs` and the
/// equivalence suites — this part pins the byte economics.
fn quantized_smoke(smoke: bool, threads: usize, strategy: LoadStrategy) {
    let (batch, steps): (usize, usize) = if smoke { (2, 6) } else { (4, 24) };
    println!("\nquantized streaming weights: f16 vs q4 checkpoint (batch {batch})\n");
    println!(
        "{:>8} {:>12} {:>14} {:>12} {:>12}",
        "format", "ckpt MiB", "GB/round", "agg tok/s", "rounds"
    );
    let mut gb_per_round = [0.0f64; 2];
    for (slot, q4) in [(0usize, false), (1, true)] {
        let tag = if q4 { "q4" } else { "f16" };
        let dir =
            std::env::temp_dir().join(format!("rwkv-bench-quant-{tag}-{}", std::process::id()));
        let mut spec = SynthSpec::tiny();
        spec.layers = 6;
        spec.heads = 12;
        spec.head_size = 16; // D=192, the paper's medium shape
        spec.ffn = 672;
        spec.vocab = 1024;
        spec.f16 = true;
        spec.q4 = q4;
        // pure dense rounds: predictors / hierarchical head would make the
        // streamed-row set input-dependent and cloud the byte comparison
        spec.predictors = false;
        spec.hier_head = false;
        write_synth_rwkv(&dir, "synthetic-quant", &spec).expect("synth model");
        let ckpt_bytes = std::fs::metadata(dir.join("models/synthetic-quant.rkv"))
            .map(|m| m.len())
            .unwrap_or(0);
        let mut cfg = EngineConfig::all_techniques("synthetic-quant", dir.clone());
        cfg.simd = simd_mode();
        cfg.threads = threads;
        cfg.strategy = strategy;
        let mut engine = RwkvEngine::load(cfg).expect("load engine");
        let mut sessions: Vec<Session> = (0..batch)
            .map(|i| {
                let mut s = Session::new(&engine, i as u64, &[2, 10 + i as u32]);
                s.max_tokens = steps + 8; // never finishes inside the loop
                s
            })
            .collect();
        // move every session into Decode (consume the tiny prompts)
        while sessions
            .iter()
            .any(|s| !matches!(s.phase(), rwkv_lite::engine::session::Phase::Decode))
        {
            engine.step_round(&mut sessions).expect("prefill round");
        }
        let (mut bytes, mut rounds) = (0u64, 0u64);
        let wall = Stopwatch::start();
        for _ in 0..steps {
            let report = engine.step_round(&mut sessions).expect("decode round");
            bytes += report.round_weight_bytes;
            rounds += 1;
        }
        let secs = wall.elapsed_secs();
        gb_per_round[slot] = bytes as f64 / rounds as f64 / 1e9;
        println!(
            "{:>8} {:>12.2} {:>14.6} {:>12.1} {:>12}",
            tag,
            ckpt_bytes as f64 / (1 << 20) as f64,
            gb_per_round[slot],
            (steps * batch) as f64 / secs,
            rounds,
        );
        std::fs::remove_dir_all(&dir).ok();
    }
    let ratio = gb_per_round[1] / gb_per_round[0];
    println!("\nquantized/f16 bytes-per-round ratio: {ratio:.3} (contract: <= 0.55)");
    assert!(
        ratio <= 0.55,
        "quantized round must stream <= 0.55x the f16 weight bytes, got {ratio:.3}"
    );
}

/// Part 6 — overload release smoke (CI runs `--smoke --overload`): a
/// burst far past `max_queue=2, max_concurrency=2` must shed the excess
/// IMMEDIATELY with structured rejections, complete every admitted
/// request, keep the accounting invariant, and never deadlock.
fn overload_smoke(
    model: &str,
    artifacts: &Path,
    smoke: bool,
    threads: usize,
    strategy: LoadStrategy,
) {
    let (burst, max_tokens): (usize, usize) = if smoke { (16, 4) } else { (64, 16) };
    println!("\noverload: burst of {burst} vs max_queue=2, max_concurrency=2\n");
    let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
    cfg.simd = simd_mode();
    cfg.threads = threads;
    cfg.strategy = strategy;
    let coordinator = Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 2, window_ms: 2 },
            admission: AdmissionPolicy {
                max_queue: 2,
                max_concurrency: 2,
                ..AdmissionPolicy::default()
            },
            ..CoordinatorConfig::default()
        },
    );
    // warm up: the burst must land on a loaded engine to measure shedding
    coordinator
        .generate_blocking(Request {
            id: 10_000,
            prompt: vec![2, 9],
            max_tokens: 1,
            ..Request::default()
        })
        .expect("warm-up request");
    let wall = Stopwatch::start();
    let rxs: Vec<_> = (0..burst as u64)
        .map(|i| {
            coordinator.submit(Request {
                id: i,
                prompt: vec![2, 50 + i as u32 % 32],
                max_tokens,
                ..Request::default()
            })
        })
        .collect();
    let (mut completed, mut rejected) = (0usize, 0usize);
    let mut reject_lat = Vec::new();
    for rx in rxs {
        let t = Stopwatch::start();
        for ev in rx {
            match ev {
                Event::Done { .. } => {
                    completed += 1;
                    break;
                }
                Event::Rejected { .. } => {
                    rejected += 1;
                    reject_lat.push(t.elapsed_secs());
                    break;
                }
                Event::Error { message } => panic!("{message}"),
                Event::Token { .. } => {}
            }
        }
    }
    let secs = wall.elapsed_secs();
    println!(
        "{:>10} {:>10} {:>14} {:>16}",
        "completed", "rejected", "wall (s)", "p50 shed lat (ms)"
    );
    println!(
        "{:>10} {:>10} {:>14.2} {:>16.3}",
        completed,
        rejected,
        secs,
        rwkv_lite::util::percentile(&reject_lat, 50.0) * 1e3,
    );
    assert_eq!(completed + rejected, burst, "every request gets exactly one terminal event");
    assert!(rejected > 0, "a {burst}-deep burst against a 4-slot system must shed");
    assert!(completed > 0, "the queue must still make progress under overload");
    let m = &coordinator.metrics;
    let admitted = m.counter("requests_admitted");
    let terminated = m.counter("requests_completed")
        + m.counter("requests_cancelled")
        + m.counter("requests_deadline_exceeded");
    assert_eq!(admitted, terminated, "accounting invariant violated");
    println!(
        "\nsheds are immediate (no queue wait) and the admitted set completes: \
         admitted={admitted} rejected={rejected}"
    );
}

/// Part 8 — open-loop serving (CI runs `--smoke --arrival-rate 20`):
/// requests arrive on a seeded Poisson process, so queueing is real and
/// the tails mean something.  Latency quantiles are read from the
/// coordinator's histograms — recorded server-side at round boundaries —
/// and the round-trace ring is exported + parse-checked.
#[allow(clippy::too_many_arguments)]
fn open_loop_sweep(
    model: &str,
    artifacts: &Path,
    smoke: bool,
    threads: usize,
    strategy: LoadStrategy,
    rate: f64,
    seed: u64,
    trace_out: Option<PathBuf>,
) {
    let (n_req, max_tokens): (usize, usize) = if smoke { (12, 4) } else { (64, 16) };
    let trace_path = trace_out.unwrap_or_else(|| {
        std::env::temp_dir().join(format!("rwkv-openloop-trace-{}.jsonl", std::process::id()))
    });
    println!(
        "\nopen-loop serving: {n_req} requests at {rate} req/s (seed {seed}, \
         {max_tokens} tok/request, {threads} threads, {} loading)\n",
        strategy.name()
    );
    let mut cfg = EngineConfig::all_techniques(model, artifacts.to_path_buf());
    cfg.simd = simd_mode();
    cfg.threads = threads;
    cfg.strategy = strategy;
    let mut coordinator = Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 8, window_ms: 2 },
            trace_out: Some(trace_path.clone()),
            ..CoordinatorConfig::default()
        },
    );
    // warm up: arrivals must land on a loaded engine or the first
    // inter-arrival gaps all hide behind checkpoint I/O
    coordinator
        .generate_blocking(Request {
            id: 10_000,
            prompt: vec![2, 9],
            max_tokens: 1,
            ..Request::default()
        })
        .expect("warm-up request");
    // the warm-up's own TTFT sample must not count against the run
    let ttft_base = hist_point(&coordinator.metrics, "ttft_secs").0;
    // deterministic exponential inter-arrivals: same seed, same schedule
    let mut rng = rwkv_lite::util::XorShift::new(seed);
    let wall = Stopwatch::start();
    let mut rxs = Vec::with_capacity(n_req);
    let mut next_at = 0.0f64;
    for i in 0..n_req as u64 {
        let u = rng.next_f64();
        next_at += -(1.0 - u).ln() / rate;
        let pause = next_at - wall.elapsed_secs();
        if pause > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(pause));
        }
        rxs.push(coordinator.submit(Request {
            id: i,
            prompt: vec![2, 50 + i as u32 % 32],
            max_tokens,
            temperature: 0.8,
            top_p: 0.95,
            ..Request::default()
        }));
    }
    let (mut completed, mut total_tokens) = (0usize, 0usize);
    for rx in rxs {
        for ev in rx {
            match ev {
                Event::Done { tokens, .. } => {
                    completed += 1;
                    total_tokens += tokens;
                    break;
                }
                Event::Rejected { .. } => break,
                Event::Error { message } => panic!("{message}"),
                Event::Token { .. } => {}
            }
        }
    }
    let secs = wall.elapsed_secs();
    println!(
        "completed {completed}/{n_req}, {total_tokens} tokens in {secs:.2}s -> {:.1} agg tok/s\n",
        total_tokens as f64 / secs
    );
    println!(
        "{:>16} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "latency", "count", "p50 ms", "p90 ms", "p99 ms", "max ms"
    );
    for (label, name) in [
        ("ttft", "ttft_secs"),
        ("itl", "itl_secs"),
        ("queue wait", "queue_wait_secs"),
        ("total", "request_total_secs"),
    ] {
        let s = coordinator.metrics.hist_snapshot(name).expect("histogram exists");
        println!(
            "{:>16} {:>8} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            label,
            s.count,
            s.quantile(50.0) * 1e3,
            s.quantile(90.0) * 1e3,
            s.quantile(99.0) * 1e3,
            s.max_secs * 1e3,
        );
    }
    assert!(completed > 0, "an open-loop run must complete requests");
    let ttft = coordinator.metrics.hist_snapshot("ttft_secs").expect("ttft histogram");
    assert_eq!(
        (ttft.count - ttft_base) as usize,
        completed,
        "every completed request records one TTFT"
    );
    // shutdown flushes the round-trace ring to JSONL; every line must
    // parse back (the CI trace contract)
    coordinator.shutdown();
    let text = std::fs::read_to_string(&trace_path).expect("trace JSONL written at shutdown");
    let mut rounds = 0usize;
    for line in text.lines() {
        let v = rwkv_lite::json::parse(line)
            .unwrap_or_else(|e| panic!("trace line does not parse: {e}\n{line}"));
        assert!(v.f64_at(&["round"]).is_some(), "trace line missing round field");
        rounds += 1;
    }
    assert!(rounds > 0, "the trace ring must have recorded rounds");
    println!("\ntrace: {rounds} rounds exported to {} (all lines parse)", trace_path.display());
    std::fs::remove_file(&trace_path).ok();
}
