//! Dynamic-batching throughput: aggregate tok/s vs batch size — shows the
//! weight-streaming batched decode actually amortizes per-round work
//! (one pass over the weights, sparse row unions, scheduler overhead)
//! across concurrent requests.  Alongside tok/s it reports the weight-GB
//! streamed per decode round: for dense layers this is ~constant in B,
//! which is exactly why aggregate throughput scales.
//!
//! Run: `cargo bench --bench serving_throughput` (artifacts required;
//! falls back to a synthetic checkpoint when they are missing so the
//! bench is always runnable).

use std::path::PathBuf;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator, Event, Request};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};
use rwkv_lite::util::Stopwatch;

fn main() {
    let mut model = "rwkv-ours-small".to_string();
    let mut artifacts = PathBuf::from("artifacts");
    let mut synth_guard: Option<PathBuf> = None;
    if !artifacts.join("models").join(format!("{model}.json")).exists() {
        // no artifacts: synthesize an f16 medium-ish model so the batching
        // economics are still measurable
        let dir = std::env::temp_dir().join(format!("rwkv-bench-synth-{}", std::process::id()));
        let mut spec = SynthSpec::tiny();
        spec.layers = 6;
        spec.heads = 12;
        spec.head_size = 16; // D=192, the paper's medium shape
        spec.ffn = 672;
        spec.vocab = 1024;
        spec.f16 = true;
        eprintln!("NOTE: artifacts missing; using a synthetic f16 model at {}", dir.display());
        write_synth_rwkv(&dir, "synthetic-medium", &spec).expect("synth model");
        model = "synthetic-medium".to_string();
        artifacts = dir.clone();
        synth_guard = Some(dir);
    }
    println!("serving throughput vs batch size ({model}, 24 tok/request)\n");
    println!(
        "{:>6} {:>10} {:>14} {:>12} {:>14} {:>14}",
        "batch", "requests", "agg tok/s", "p50 lat (s)", "GB/round", "rounds"
    );
    for &batch in &[1usize, 2, 4, 8] {
        let cfg = EngineConfig::all_techniques(&model, artifacts.clone());
        let coordinator = Coordinator::spawn(
            move || rwkv_lite::engine::RwkvEngine::load(cfg),
            BatchPolicy { max_batch: batch, window_ms: 2 },
        );
        let n_req = batch * 3;
        let wall = Stopwatch::start();
        let rxs: Vec<_> = (0..n_req as u64)
            .map(|i| {
                coordinator.submit(Request {
                    id: i,
                    prompt: vec![2, 100 + i as u32 % 64],
                    max_tokens: 24,
                    temperature: 0.8,
                    top_p: 0.95,
                })
            })
            .collect();
        let mut total = 0usize;
        let mut lats = Vec::new();
        for rx in rxs {
            for ev in rx {
                match ev {
                    Event::Done { tokens, seconds } => {
                        total += tokens;
                        lats.push(seconds);
                        break;
                    }
                    Event::Error { message } => panic!("{message}"),
                    _ => {}
                }
            }
        }
        let secs = wall.elapsed_secs();
        let rounds = coordinator.metrics.counter("decode_rounds").max(1);
        let round_bytes = coordinator.metrics.counter("decode_round_weight_bytes");
        println!(
            "{:>6} {:>10} {:>14.1} {:>12.3} {:>14.4} {:>14}",
            batch,
            n_req,
            total as f64 / secs,
            rwkv_lite::util::percentile(&lats, 50.0),
            round_bytes as f64 / rounds as f64 / 1e9,
            rounds,
        );
    }
    if let Some(dir) = synth_guard {
        std::fs::remove_dir_all(&dir).ok();
    }
}
