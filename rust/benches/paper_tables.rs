//! Regenerate every paper table/figure in one run (the `cargo bench`
//! umbrella for deliverable (d)): delegates to the `exp` drivers so the
//! same code path serves `rwkv-lite exp <id>` and `cargo bench`.

use rwkv_lite::cli;

fn main() {
    let specs = [
        cli::opt_def("artifacts", "artifacts dir", "artifacts"),
        cli::opt_def("limit", "examples per task", "40"),
        cli::opt_def("n", "tokens per measurement", "80"),
        cli::opt_def("model", "model override", "rwkv-ours-small"),
    ];
    // cargo bench passes --bench; swallow unknown flags by filtering
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench")
        .collect();
    let args = match cli::parse(&argv, &specs) {
        Ok(a) => a,
        Err(_) => cli::parse(&[], &specs).unwrap(),
    };
    if !std::path::Path::new("artifacts/models").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    if let Err(e) = rwkv_lite::exp::run("all", &args) {
        eprintln!("paper_tables failed: {e:#}");
        std::process::exit(1);
    }
}
