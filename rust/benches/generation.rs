//! End-to-end generation TPS per model/runtime configuration — the bench
//! behind Figures 8/10/12's host measurements.
//!
//! Run: `cargo bench --bench generation` (artifacts required).

use std::path::PathBuf;

use rwkv_lite::config::{EngineConfig, LoadStrategy};
use rwkv_lite::engine::sampler::Sampler;
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::util::Stopwatch;

fn artifacts() -> PathBuf {
    PathBuf::from("artifacts")
}

fn tps(cfg: EngineConfig, n: usize) -> anyhow::Result<(f64, u64)> {
    let mut engine = RwkvEngine::load(cfg)?;
    let mut sampler = Sampler::new(0.8, 0.95, 3);
    let mut state = engine.new_state();
    engine.generate(&[2, 100, 200], 8, &mut sampler, &mut state)?; // warmup
    let mut state = engine.new_state();
    let t = Stopwatch::start();
    engine.generate(&[2, 100, 200], n, &mut sampler, &mut state)?;
    Ok((n as f64 / t.elapsed_secs(), engine.memory_report().1))
}

fn main() {
    let n = 160;
    println!("generation TPS (n={n} tokens, host CPU)\n");
    println!("{:<30} {:<12} {:>10} {:>12}", "model", "runtime", "tok/s", "peak MiB");
    for size in ["tiny", "small", "medium"] {
        for (name, ours, strategy) in [
            (format!("rwkv-vanilla-{size}"), false, LoadStrategy::Full),
            (format!("rwkv-vanilla-{size}"), false, LoadStrategy::Layerwise),
            (format!("rwkv-ours-{size}"), true, LoadStrategy::Full),
            (format!("rwkv-ours-{size}-int8"), true, LoadStrategy::Full),
            (format!("rwkv-vanilla-{size}-int8"), false, LoadStrategy::Full),
        ] {
            if !artifacts().join("models").join(format!("{name}.json")).exists() {
                continue;
            }
            let mut cfg = if ours {
                EngineConfig::all_techniques(&name, artifacts())
            } else {
                EngineConfig::vanilla(&name, artifacts())
            };
            cfg.strategy = strategy;
            let label = format!(
                "{}{}",
                if ours { "ours" } else { "vanilla" },
                if strategy == LoadStrategy::Layerwise { "+layerwise" } else { "" }
            );
            match tps(cfg, n) {
                Ok((tps, peak)) => println!(
                    "{:<30} {:<12} {:>10.1} {:>12.2}",
                    name,
                    label,
                    tps,
                    peak as f64 / (1 << 20) as f64
                ),
                Err(e) => println!("{name:<30} {label:<12}   error: {e}"),
            }
        }
    }
}
