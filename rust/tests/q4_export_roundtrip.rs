//! Cross-language Q4 export round-trip: the python compile pipeline
//! quantizes a matrix (compile/compress/quant.py) and writes an `.rkv`
//! container (compile/export.py); the rust reader must recover tensors
//! that are BIT-identical to what rust's own quantizer produces from the
//! same float input, and the fused kernels over them must match the
//! dequantize-to-dense reference exactly.
//!
//! The two quantizers are specified to agree nibble-for-nibble (both
//! divide by the f16-ROUNDED scale, round ties-to-even, and write
//! canonical pad nibbles), so any drift between the languages fails this
//! test rather than silently degrading served models.
//!
//! Skips (with a notice) when `python3` + numpy aren't installed, so
//! plain `cargo test` still works in minimal environments.

use std::path::PathBuf;
use std::process::Command;

use rwkv_lite::io::rkv::RkvFile;
use rwkv_lite::tensor::{dot_f32, matvec_rows, Mat};

const ROWS: usize = 6;
const COLS: usize = 37; // ragged final group + odd trailing column

/// Deterministic float32 pattern computable identically in numpy: every
/// op stays in f32, so both languages see the exact same input bits.
fn pattern(n: usize) -> Vec<f32> {
    (0..n).map(|i| (i % 13) as f32 * 0.3_f32 - 1.7_f32).collect()
}

const PY_SCRIPT: &str = r#"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
from compile import export
from compile.compress import quant

rows, cols = 6, 37
w = (np.arange(rows * cols) % 13).astype(np.float32) * np.float32(0.3) - np.float32(1.7)
w = w.reshape(rows, cols)
p4, s4 = quant.group_q4(w)
p41, s41, m41 = quant.group_q4_1(w)
export.write_rkv(sys.argv[2], {
    "w4": export.PackedTensor(export.DTYPES["q4"], w.shape, p4),
    "w4.scale": s4,
    "w41": export.PackedTensor(export.DTYPES["q4_1"], w.shape, p41),
    "w41.scale": s41,
    "w41.min": m41,
})
"#;

#[test]
fn python_q4_export_matches_rust_quantizer_bitwise() {
    let python_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../python");
    let dir = std::env::temp_dir().join(format!("rwkv-q4-xlang-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let rkv_path = dir.join("x.rkv");

    let run = Command::new("python3")
        .arg("-c")
        .arg(PY_SCRIPT)
        .arg(&python_dir)
        .arg(&rkv_path)
        .output();
    let run = match run {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP q4_export_roundtrip: python3 unavailable ({e})");
            return;
        }
    };
    if !run.status.success() {
        let err = String::from_utf8_lossy(&run.stderr);
        if err.contains("ModuleNotFoundError") || err.contains("ImportError") {
            eprintln!("SKIP q4_export_roundtrip: python deps unavailable\n{err}");
            return;
        }
        panic!("python quantizer/exporter failed:\n{err}");
    }

    let f = RkvFile::open(&rkv_path).unwrap();
    let vals = pattern(ROWS * COLS);

    // container contents == rust quantizer output, bit for bit (packed
    // nibbles, f16 scale bits, f16 min bits)
    let want4 = Mat::quantize_q4_mat(ROWS, COLS, &vals);
    let want41 = Mat::quantize_q4_1_mat(ROWS, COLS, &vals);
    assert_eq!(f.mat("w4").unwrap(), want4);
    assert_eq!(f.mat("w41").unwrap(), want41);

    // and the fused kernels over the python-written tensors match the
    // dequantize-to-f32 dense reference exactly
    let x: Vec<f32> = (0..COLS).map(|c| (c as f32 * 0.17).sin()).collect();
    for m in [&want4, &want41] {
        let mut dense = vec![0.0f32; ROWS * COLS];
        for r in 0..ROWS {
            m.decode_row(r, &mut dense[r * COLS..(r + 1) * COLS]);
        }
        let mut got = vec![0.0f32; ROWS];
        matvec_rows(m, &x, &mut got);
        for r in 0..ROWS {
            assert_eq!(got[r], dot_f32(&dense[r * COLS..(r + 1) * COLS], &x));
        }
    }

    std::fs::remove_dir_all(&dir).ok();
}
