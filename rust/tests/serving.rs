//! Serving-stack integration: coordinator batching + TCP server/client.

use std::path::PathBuf;
use std::sync::Arc;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator, Event, Request};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::server::{Client, Server};
use rwkv_lite::text::Vocab;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(model: &str) -> bool {
    artifacts().join("models").join(format!("{model}.json")).exists()
}

fn coordinator(model: &'static str, batch: usize) -> Coordinator {
    let cfg = EngineConfig::all_techniques(model, artifacts());
    Coordinator::spawn(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: batch, window_ms: 1 },
    )
}

#[test]
fn single_request_completes() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let c = coordinator("rwkv-ours-tiny", 4);
    let out = c
        .generate_blocking(Request {
            id: 1,
            prompt: vec![2, 5, 6],
            max_tokens: 8,
            temperature: 0.0,
            top_p: 1.0,
        })
        .unwrap();
    assert!(!out.is_empty() && out.len() <= 8);
    assert_eq!(c.metrics.counter("requests_completed"), 1);
}

#[test]
fn concurrent_requests_all_complete_and_batch() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let c = Arc::new(coordinator("rwkv-ours-tiny", 8));
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        rxs.push(c.submit(Request {
            id: i,
            prompt: vec![2, (10 + i) as u32],
            max_tokens: 12,
            temperature: 0.7,
            top_p: 0.95,
        }));
    }
    let mut done = 0;
    for rx in rxs {
        let mut tokens = 0;
        for ev in rx {
            match ev {
                Event::Token { .. } => tokens += 1,
                Event::Done { tokens: t, .. } => {
                    assert_eq!(tokens, t);
                    done += 1;
                    break;
                }
                Event::Error { message } => panic!("request failed: {message}"),
            }
        }
    }
    assert_eq!(done, 6);
    assert_eq!(c.metrics.counter("requests_completed"), 6);
    // with 6 concurrent requests and round-based decode, rounds must be
    // far fewer than total tokens (i.e. batching actually happened)
    let rounds = c.metrics.counter("rounds");
    let tokens = c.metrics.counter("tokens_out");
    assert!(rounds < tokens, "rounds={rounds} tokens={tokens}");
}

#[test]
fn deterministic_same_seed_same_output() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let c = coordinator("rwkv-ours-tiny", 2);
    let req = |id| Request {
        id,
        prompt: vec![2, 7, 8],
        max_tokens: 10,
        temperature: 0.9,
        top_p: 0.9,
    };
    // sampler seeded by request id: same id -> same tokens
    let a = c.generate_blocking(req(42)).unwrap();
    let b = c.generate_blocking(req(42)).unwrap();
    assert_eq!(a, b);
}

#[test]
fn tcp_server_round_trip() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let vocab = Vocab::load(&artifacts().join("data/vocab.json")).unwrap();
    let server = Arc::new(Server::new(coordinator("rwkv-ours-tiny", 4), vocab));
    let addr = "127.0.0.1:17371";
    let s2 = Arc::clone(&server);
    let handle = std::thread::spawn(move || s2.serve(addr, Some(1)));
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut client = Client::connect(addr).unwrap();
    let completion = client.complete("the", 8, 0.0).unwrap();
    assert!(completion.tokens > 0);
    assert!(!completion.text.is_empty());
    assert!(completion.tps > 0.0);
    drop(client);
    handle.join().unwrap().unwrap();
}
