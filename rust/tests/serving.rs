//! Serving-stack integration: session-round coordinator + TCP
//! server/client.  Coordinator behaviour (cancellation, stop tokens,
//! explicit seeds) runs on synthetic checkpoints so it is tier-1
//! coverage; the end-to-end TCP tests still require `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{
    batcher::BatchPolicy, AdmissionPolicy, Coordinator, CoordinatorConfig, Event, FinishReason,
    Request,
};
use rwkv_lite::engine::state_cache::{CacheConfig, StateCache};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::json;
use rwkv_lite::server::{Client, ServeOptions, Server};
use rwkv_lite::testutil::faults::FaultPlan;
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};
use rwkv_lite::text::Vocab;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(model: &str) -> bool {
    artifacts().join("models").join(format!("{model}.json")).exists()
}

fn coordinator(model: &'static str, batch: usize) -> Coordinator {
    let cfg = EngineConfig::all_techniques(model, artifacts());
    Coordinator::spawn(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: batch, window_ms: 1 },
    )
}

/// Coordinator over a synthetic checkpoint (runs without artifacts).
fn synth_coordinator(tag: &str, batch: usize) -> (Coordinator, PathBuf) {
    synth_coordinator_spec(tag, batch, SynthSpec::tiny())
}

/// Like [`synth_coordinator`] but with a caller-chosen model shape — the
/// cancellation tests use a bigger model so decode rounds take real time
/// and the producer cannot outrun the consumer by hundreds of tokens.
fn synth_coordinator_spec(tag: &str, batch: usize, spec: SynthSpec) -> (Coordinator, PathBuf) {
    let dir = std::env::temp_dir().join(format!("rwkv-serve-synth-{}-{}", tag, std::process::id()));
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    let c = Coordinator::spawn(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: batch, window_ms: 1 },
    );
    (c, dir)
}

/// A medium-shaped synthetic model: one decode round costs enough wall
/// time that a consumer thread acting within a few rounds is safe.
fn slow_spec() -> SynthSpec {
    let mut spec = SynthSpec::tiny();
    spec.layers = 6;
    spec.heads = 12;
    spec.head_size = 16; // D = 192
    spec.ffn = 672;
    spec.vocab = 1024;
    spec
}

#[test]
fn single_request_completes() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let c = coordinator("rwkv-ours-tiny", 4);
    let out = c
        .generate_blocking(Request {
            id: 1,
            prompt: vec![2, 5, 6],
            max_tokens: 8,
            ..Request::default()
        })
        .unwrap();
    assert!(!out.is_empty() && out.len() <= 8);
    assert_eq!(c.metrics.counter("requests_completed"), 1);
}

#[test]
fn concurrent_requests_all_complete_and_batch() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let c = Arc::new(coordinator("rwkv-ours-tiny", 8));
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        rxs.push(c.submit(Request {
            id: i,
            prompt: vec![2, (10 + i) as u32],
            max_tokens: 12,
            temperature: 0.7,
            top_p: 0.95,
            ..Request::default()
        }));
    }
    let mut done = 0;
    for rx in rxs {
        let mut tokens = 0;
        for ev in rx {
            match ev {
                Event::Token { .. } => tokens += 1,
                Event::Done { tokens: t, .. } => {
                    assert_eq!(tokens, t);
                    done += 1;
                    break;
                }
                Event::Error { message } => panic!("request failed: {message}"),
                Event::Rejected { reason, .. } => panic!("rejected: {}", reason.wire_name()),
            }
        }
    }
    assert_eq!(done, 6);
    assert_eq!(c.metrics.counter("requests_completed"), 6);
    // with 6 concurrent requests and round-based decode, rounds must be
    // far fewer than total tokens (i.e. batching actually happened)
    let rounds = c.metrics.counter("rounds");
    let tokens = c.metrics.counter("tokens_out");
    assert!(rounds < tokens, "rounds={rounds} tokens={tokens}");
}

#[test]
fn deterministic_same_id_same_output() {
    let (c, dir) = synth_coordinator("det-id", 2);
    let req = |id| Request {
        id,
        prompt: vec![2, 7, 8],
        max_tokens: 10,
        temperature: 0.9,
        top_p: 0.9,
        ..Request::default()
    };
    // without an explicit seed the sampler falls back to the request id:
    // same id -> same tokens
    let a = c.generate_blocking(req(42)).unwrap();
    let b = c.generate_blocking(req(42)).unwrap();
    assert_eq!(a, b);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn explicit_seed_decouples_determinism_from_request_id() {
    let (c, dir) = synth_coordinator("det-seed", 2);
    let req = |id, seed| Request {
        id,
        prompt: vec![2, 7, 8],
        max_tokens: 10,
        temperature: 0.9,
        top_p: 0.9,
        seed,
        ..Request::default()
    };
    // DIFFERENT ids, same explicit seed -> identical streams
    let a = c.generate_blocking(req(1, Some(777))).unwrap();
    let b = c.generate_blocking(req(2, Some(777))).unwrap();
    assert_eq!(a, b, "explicit seed must pin the stream across request ids");
    assert!(!a.is_empty());
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Find a prompt whose deterministic greedy continuation is at least
/// `need` tokens long (i.e. EOS-free that far) — keeps the cancellation /
/// stop tests deterministic on synthetic models, where greedy streams can
/// hit EOS by chance.
fn eos_free_prompt(c: &Coordinator, need: usize) -> Option<Vec<u32>> {
    let candidates: [&[u32]; 8] = [
        &[2, 11, 30],
        &[2, 5],
        &[2, 9],
        &[4, 40, 4],
        &[7, 3, 19],
        &[2, 50, 61],
        &[2, 33, 8, 21],
        &[5, 77],
    ];
    for (i, p) in candidates.iter().enumerate() {
        let out = c
            .generate_blocking(Request {
                id: 900 + i as u64,
                prompt: p.to_vec(),
                max_tokens: need,
                ..Request::default()
            })
            .unwrap();
        if out.len() == need {
            return Some(p.to_vec());
        }
    }
    None
}

#[test]
fn stop_tokens_end_the_stream() {
    let (c, dir) = synth_coordinator("stop", 2);
    let Some(prompt) = eos_free_prompt(&c, 8) else {
        eprintln!("SKIP: no EOS-free greedy stream on this synth model");
        std::fs::remove_dir_all(&dir).ok();
        return;
    };
    let base = Request { id: 9, prompt, max_tokens: 8, ..Request::default() };
    // greedy is deterministic: learn the stream, then stop on its 3rd token
    let stream = c.generate_blocking(base.clone()).unwrap();
    assert!(stream.len() >= 3, "need a few tokens to stop on");
    let stop = stream[2];
    let first = stream.iter().position(|&t| t == stop).unwrap();
    let handle = c.submit(Request { id: 10, stop_tokens: vec![stop], max_tokens: 64, ..base });
    let mut out = Vec::new();
    let mut reason = None;
    for ev in handle {
        match ev {
            Event::Token { token } => out.push(token),
            Event::Done { reason: r, .. } => {
                reason = Some(r);
                break;
            }
            Event::Error { message } => panic!("{message}"),
            Event::Rejected { reason, .. } => panic!("rejected: {}", reason.wire_name()),
        }
    }
    assert_eq!(out, stream[..=first].to_vec(), "stream ends AT the stop token");
    assert_eq!(reason, Some(FinishReason::Stop(stop)));
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Multi-token stop sequences end the stream AFTER the matching suffix
/// is emitted, with `reason: "stop"` — on top of single stop tokens.
#[test]
fn stop_sequences_end_the_stream() {
    let (c, dir) = synth_coordinator("stopseq", 2);
    let Some(prompt) = eos_free_prompt(&c, 8) else {
        eprintln!("SKIP: no EOS-free greedy stream on this synth model");
        std::fs::remove_dir_all(&dir).ok();
        return;
    };
    let base = Request { id: 20, prompt, max_tokens: 8, ..Request::default() };
    // greedy is deterministic: learn the stream, stop on tokens 2..=3
    let stream = c.generate_blocking(base.clone()).unwrap();
    assert!(stream.len() >= 4, "need a few tokens for a 2-token stop seq");
    let seq = vec![stream[1], stream[2]];
    // earliest suffix match in the greedy stream (it may repeat tokens)
    let first_end = (1..stream.len())
        .find(|&e| stream[e - 1..=e] == seq[..])
        .unwrap();
    let handle = c.submit(Request {
        id: 21,
        stop_sequences: vec![seq.clone()],
        max_tokens: 64,
        ..base
    });
    let mut out = Vec::new();
    let mut reason = None;
    for ev in handle {
        match ev {
            Event::Token { token } => out.push(token),
            Event::Done { reason: r, .. } => {
                reason = Some(r);
                break;
            }
            Event::Error { message } => panic!("{message}"),
            Event::Rejected { reason, .. } => panic!("rejected: {}", reason.wire_name()),
        }
    }
    assert_eq!(out, stream[..=first_end].to_vec(), "stream ends AFTER the stop sequence");
    assert_eq!(reason, Some(FinishReason::StopSeq(0)));
    assert_eq!(reason.unwrap().name(), "stop", "wire name matches single-token stops");
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Coordinator-owned prefix-state cache: a repeated prompt's second
/// request reports `cached_tokens > 0`, streams identically, and the
/// registry carries the cache telemetry.
#[test]
fn coordinator_cache_skips_repeat_prefill() {
    let dir = std::env::temp_dir().join(format!("rwkv-serve-cache-{}", std::process::id()));
    write_synth_rwkv(&dir, "m", &SynthSpec::tiny()).expect("write synth model");
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let state_path = dir.join("cache.rwst");
    let c = Coordinator::spawn_with_cache(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: 2, window_ms: 1 },
        Some(StateCache::new(CacheConfig::with_mb(16))),
        Some(state_path.clone()),
    );
    let prompt: Vec<u32> = (0..24).map(|i| (4 + 3 * i) % 90).collect();
    let run = |id: u64| {
        let handle = c.submit(Request {
            id,
            prompt: prompt.clone(),
            max_tokens: 4,
            seed: Some(7),
            ..Request::default()
        });
        let mut out = Vec::new();
        let mut cached = usize::MAX;
        for ev in handle {
            match ev {
                Event::Token { token } => out.push(token),
                Event::Done { cached_tokens, .. } => {
                    cached = cached_tokens;
                    break;
                }
                Event::Error { message } => panic!("{message}"),
                Event::Rejected { reason, .. } => panic!("rejected: {}", reason.wire_name()),
            }
        }
        (out, cached)
    };
    let (cold_stream, cold_cached) = run(1);
    assert_eq!(cold_cached, 0, "first request is a cold miss");
    let (warm_stream, warm_cached) = run(2);
    assert!(warm_cached > 0, "repeat prompt must fork off the cache");
    assert_eq!(warm_stream, cold_stream, "warm stream must be bit-identical");
    assert!(c.metrics.counter("cache_hits") >= 1);
    assert!(c.metrics.counter("cache_hit_tokens") >= warm_cached as u64);
    assert!(c.metrics.counter("cache_bytes") > 0);
    // prefill telemetry confirms the skipped forward passes: the warm
    // request only prefills feed_len - cached tokens
    let feed_len = (prompt.len() + 1) as u64;
    assert_eq!(
        c.metrics.counter("prefill_tokens"),
        feed_len + (feed_len - warm_cached as u64),
        "second request must not re-run matched prefill tokens"
    );
    // shutdown persists the snapshots for the next process
    drop(c);
    assert!(state_path.exists(), "coordinator saves the cache on shutdown");
    let (tag, entries) = rwkv_lite::io::read_statefile(&state_path).expect("readable statefile");
    assert!(tag.starts_with("m:"), "statefile carries the model fingerprint, got '{tag}'");
    assert!(!entries.is_empty());
    std::fs::remove_dir_all(&dir).ok();
}

/// `"cache": false` requests neither read nor populate the shared cache.
#[test]
fn cache_opt_out_request_stays_cold() {
    let dir = std::env::temp_dir().join(format!("rwkv-serve-nocache-{}", std::process::id()));
    write_synth_rwkv(&dir, "m", &SynthSpec::tiny()).expect("write synth model");
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let c = Coordinator::spawn_with_cache(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: 2, window_ms: 1 },
        Some(StateCache::new(CacheConfig::with_mb(16))),
        None,
    );
    let prompt: Vec<u32> = (0..20).map(|i| (5 + 2 * i) % 90).collect();
    let req = |id| Request {
        id,
        prompt: prompt.clone(),
        max_tokens: 2,
        cache: false,
        ..Request::default()
    };
    c.generate_blocking(req(1)).unwrap();
    c.generate_blocking(req(2)).unwrap();
    assert_eq!(c.metrics.counter("cache_hits"), 0);
    assert_eq!(c.metrics.counter("cache_insertions"), 0);
    assert_eq!(c.metrics.counter("cache_bytes"), 0);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_handle_retires_session() {
    let (c, dir) = synth_coordinator_spec("cancel", 2, slow_spec());
    // the producer can outrun the consumer before cancel lands, so the
    // greedy stream must stay EOS-free well past the cancellation point
    let Some(prompt) = eos_free_prompt(&c, 256) else {
        eprintln!("SKIP: no EOS-free greedy stream on this synth model");
        std::fs::remove_dir_all(&dir).ok();
        return;
    };
    let done_before = c.metrics.counter("requests_completed");
    let handle = c.submit(Request {
        id: 1,
        prompt,
        max_tokens: 100_000, // would decode for a long time without cancel
        ..Request::default()
    });
    let mut seen = 0;
    let mut reason = None;
    for ev in handle.iter() {
        match ev {
            Event::Token { .. } => {
                seen += 1;
                if seen == 3 {
                    handle.cancel();
                }
            }
            Event::Done { reason: r, .. } => {
                reason = Some(r);
                break;
            }
            Event::Error { message } => panic!("{message}"),
            Event::Rejected { reason, .. } => panic!("rejected: {}", reason.wire_name()),
        }
    }
    assert!(seen >= 3, "got {seen} tokens before cancel");
    assert_eq!(reason, Some(FinishReason::Cancelled));
    assert_eq!(c.metrics.counter("requests_cancelled"), 1);
    assert_eq!(c.metrics.counter("requests_completed"), done_before);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dropped_client_is_detected_and_cancelled() {
    let (c, dir) = synth_coordinator_spec("gone", 2, slow_spec());
    let Some(prompt) = eos_free_prompt(&c, 256) else {
        eprintln!("SKIP: no EOS-free greedy stream on this synth model");
        std::fs::remove_dir_all(&dir).ok();
        return;
    };
    let handle = c.submit(Request {
        id: 1,
        prompt,
        max_tokens: 100_000,
        ..Request::default()
    });
    // consume a couple of tokens, then walk away mid-stream
    let mut seen = 0;
    for ev in handle.iter() {
        if matches!(ev, Event::Token { .. }) {
            seen += 1;
            if seen == 2 {
                break;
            }
        }
    }
    drop(handle);
    // the coordinator notices the dead stream on the next emitted token
    // and retires the session instead of decoding into the void
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    while c.metrics.counter("requests_cancelled") == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "coordinator never cancelled the orphaned session"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(c.metrics.counter("requests_cancelled"), 1);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefill_rounds_are_chunked_not_per_token() {
    let (c, dir) = synth_coordinator("chunked", 2);
    // a 30-token prompt with prefill_chunk=8 needs ceil(31/8)=4 prefill
    // rounds; the old per-token loop needed 31
    let prompt: Vec<u32> = (0..30).map(|i| (4 + i) % 90).collect();
    let out = c
        .generate_blocking(Request { id: 1, prompt, max_tokens: 2, ..Request::default() })
        .unwrap();
    assert!(!out.is_empty());
    let prefill = c.metrics.counter("prefill_tokens");
    let rounds = c.metrics.counter("rounds");
    assert_eq!(prefill, 31, "BOS + 30 prompt tokens prefilled");
    assert!(
        rounds <= 6,
        "31 prefill tokens + 2 decode tokens must fit in ~5 chunked rounds, got {rounds}"
    );
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Vocabulary matching the synthetic checkpoints (96 words, specials in
/// the standard slots) — lets the TCP protocol tests run without
/// `make artifacts`.
fn synth_vocab() -> Vocab {
    let mut words: Vec<String> =
        ["<pad>", "<unk>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
    for i in 4..96 {
        words.push(format!("w{i}"));
    }
    Vocab::from_words(words)
}

/// Spawn a TCP server over a synthetic coordinator; returns the server
/// thread handle (serves `conns` connections then exits) and the addr.
fn synth_server(
    tag: &str,
    addr: &'static str,
    conns: usize,
    admission: AdmissionPolicy,
    faults: Option<FaultPlan>,
) -> (std::thread::JoinHandle<anyhow::Result<()>>, PathBuf) {
    let dir = std::env::temp_dir().join(format!("rwkv-tcp-{}-{}", tag, std::process::id()));
    let spec = SynthSpec::tiny();
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    let c = Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, window_ms: 1 },
            admission,
            faults,
            ..CoordinatorConfig::default()
        },
    );
    let server = Arc::new(Server::new(c, synth_vocab()));
    let handle = std::thread::spawn(move || {
        server.serve(
            addr,
            ServeOptions { max_total_conns: Some(conns), ..ServeOptions::default() },
        )
    });
    std::thread::sleep(std::time::Duration::from_millis(150));
    (handle, dir)
}

/// Out-of-range numerics are refused with a structured error line (no
/// silent `as usize` casts), and the connection keeps serving.
#[test]
fn tcp_validation_rejects_bad_numerics() {
    let (server, dir) =
        synth_server("validate", "127.0.0.1:17372", 1, AdmissionPolicy::default(), None);
    let mut client = Client::connect("127.0.0.1:17372").unwrap();
    let bad = [
        (r#"{"prompt":"w5","max_tokens":-3}"#, "invalid max_tokens"),
        (r#"{"prompt":"w5","max_tokens":2000000000000}"#, "invalid max_tokens"),
        (r#"{"prompt":"w5","max_tokens":1.5}"#, "invalid max_tokens"),
        (r#"{"prompt":"w5","temperature":-0.5}"#, "invalid temperature"),
        (r#"{"prompt":"w5","top_p":1.5}"#, "invalid top_p"),
        (r#"{"prompt":"w5","top_p":0}"#, "invalid top_p"),
        (r#"{"prompt":"w5","deadline_ms":-20}"#, "invalid deadline_ms"),
    ];
    for (req, want) in bad {
        let lines = client.request_raw(req).unwrap();
        assert_eq!(lines.len(), 1, "validation failure is a single terminal line: {lines:?}");
        let v = json::parse(&lines[0]).unwrap();
        let err = v.str_at(&["error"]).expect("structured error field");
        assert!(err.contains(want), "error '{err}' should mention '{want}'");
    }
    // the same connection still serves a valid request afterwards
    let done = client.complete("w5 w6", 3, 0.0).unwrap();
    assert!(done.tokens > 0);
    drop(client);
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Admission rejections reach the wire as structured error lines with
/// 429 semantics: `prompt_too_long` here (deterministic — no timing).
#[test]
fn tcp_prompt_limit_rejection_wire_shape() {
    let admission = AdmissionPolicy { max_prompt_tokens: 2, ..AdmissionPolicy::default() };
    let (server, dir) = synth_server("promptcap", "127.0.0.1:17373", 1, admission, None);
    let mut client = Client::connect("127.0.0.1:17373").unwrap();
    let lines = client.request_raw(r#"{"prompt":"w5 w6 w7 w8","max_tokens":2}"#).unwrap();
    assert_eq!(lines.len(), 1);
    let v = json::parse(&lines[0]).unwrap();
    assert_eq!(v.str_at(&["error"]), Some("prompt_too_long"));
    assert_eq!(v.f64_at(&["retry_after_ms"]), Some(0.0));
    assert!(v.str_at(&["detail"]).unwrap_or("").contains("limit 2"));
    // an in-bounds prompt on the same connection completes
    let done = client.complete("w5 w6", 2, 0.0).unwrap();
    assert!(done.tokens > 0);
    drop(client);
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// A mid-request engine failure reaches the client as ONE terminal error
/// line that still carries the final token/latency accounting (the
/// coordinator's Error + Done merge on the wire).
#[test]
fn tcp_round_error_line_carries_final_counts() {
    let faults = FaultPlan::new().fail_round(0).with_message("injected: round lost");
    let (server, dir) = synth_server(
        "rounderr",
        "127.0.0.1:17374",
        1,
        AdmissionPolicy::default(),
        Some(faults),
    );
    let mut client = Client::connect("127.0.0.1:17374").unwrap();
    let lines = client.request_raw(r#"{"prompt":"w5 w6","max_tokens":4}"#).unwrap();
    let last = json::parse(lines.last().expect("terminal line")).unwrap();
    assert_eq!(last.str_at(&["error"]), Some("injected: round lost"));
    assert_eq!(last.str_at(&["reason"]), Some("cancelled"));
    let token_lines =
        lines.iter().filter(|l| json::parse(l).unwrap().get("token").is_some()).count();
    assert_eq!(last.f64_at(&["tokens"]), Some(token_lines as f64), "counts survive the error");
    // the server recovered: the NEXT request on this connection completes
    // (round 0 is the only poisoned round)
    let done = client.complete("w7 w8", 2, 0.0).unwrap();
    assert!(done.tokens > 0);
    drop(client);
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Per-request `deadline_ms` over the wire: injected slow rounds make a
/// short deadline land mid-prefill, and the terminal line reports
/// `reason: "deadline"` with the partial token count.
#[test]
fn tcp_deadline_wire_shape() {
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 25);
    let (server, dir) = synth_server(
        "deadline",
        "127.0.0.1:17375",
        1,
        AdmissionPolicy::default(),
        Some(faults),
    );
    let mut client = Client::connect("127.0.0.1:17375").unwrap();
    // 40-word prompt: ~6 prefill rounds at 25ms each vs a 60ms deadline
    let words: Vec<String> = (0..40).map(|i| format!("w{}", 4 + i % 32)).collect();
    let req = format!(
        r#"{{"prompt":"{}","max_tokens":50,"deadline_ms":60}}"#,
        words.join(" ")
    );
    let lines = client.request_raw(&req).unwrap();
    let last = json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.str_at(&["reason"]), Some("deadline"));
    assert!(last.get("done").is_some(), "a deadline expiry is a normal Done");
    let token_lines =
        lines.iter().filter(|l| json::parse(l).unwrap().get("token").is_some()).count();
    assert_eq!(last.f64_at(&["tokens"]), Some(token_lines as f64));
    drop(client);
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Numeric extremes and malformed JSON over the wire (PR 7, rides the
/// parser-fuzzing work): `1e999` overflows f64 to inf, `1e-999`
/// underflows to 0, `9e18` exceeds the sane cap, a literal `NaN` is a
/// JSON syntax error, and absurd nesting trips the depth limit — every
/// one must come back as a structured error line with the connection
/// still usable.  An over-long request line (the one unrecoverable case:
/// no frame boundary to resync on) gets an error line and a close.
#[test]
fn tcp_extreme_numerics_and_malformed_json() {
    let (server, dir) =
        synth_server("extreme", "127.0.0.1:17376", 1, AdmissionPolicy::default(), None);
    let mut client = Client::connect("127.0.0.1:17376").unwrap();
    let deep = format!(r#"{{"prompt":{}"x"{}}}"#, "[".repeat(200), "]".repeat(200));
    let bad = [
        (r#"{"prompt":"w5","temperature":1e999}"#, "invalid temperature"),
        (r#"{"prompt":"w5","max_tokens":1e999}"#, "invalid max_tokens"),
        (r#"{"prompt":"w5","max_tokens":9e18}"#, "invalid max_tokens"),
        (r#"{"prompt":"w5","top_p":1e-999}"#, "invalid top_p"),
        (r#"{"prompt":"w5","temperature":NaN}"#, "bad request"),
        (deep.as_str(), "bad request"),
    ];
    for (req, want) in bad {
        let lines = client.request_raw(req).unwrap();
        assert_eq!(lines.len(), 1, "one terminal error line for {want}: {lines:?}");
        let v = json::parse(&lines[0]).unwrap();
        let err = v.str_at(&["error"]).expect("structured error field");
        assert!(err.contains(want), "error '{err}' should mention '{want}'");
    }
    // connection survived all of the above
    let done = client.complete("w5 w6", 2, 0.0).unwrap();
    assert!(done.tokens > 0);
    // a >1 MiB request line: the server sends a best-effort error line
    // and closes (no newline was seen, so there is no resync point).
    // Closing with unread bytes still in the socket makes the kernel
    // reset the connection, which can race the error line's delivery —
    // accept any of {error line, clean EOF, reset}, but never a hang or
    // a served request
    let huge = format!(r#"{{"prompt":"{}"}}"#, "w".repeat(1 << 20));
    match client.request_raw(&huge) {
        Ok(lines) => {
            assert!(lines.len() <= 1, "over-cap line must not be served: {lines:?}");
            if let Some(first) = lines.first() {
                let v = json::parse(first).unwrap();
                assert!(v.str_at(&["error"]).unwrap().contains("request line exceeds"));
            }
        }
        Err(_) => {} // connection reset before the error line arrived
    }
    drop(client);
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tcp_server_round_trip() {
    if !have("rwkv-ours-tiny") {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let vocab = Vocab::load(&artifacts().join("data/vocab.json")).unwrap();
    let server = Arc::new(Server::new(coordinator("rwkv-ours-tiny", 4), vocab));
    let addr = "127.0.0.1:17371";
    let s2 = Arc::clone(&server);
    let handle = std::thread::spawn(move || {
        s2.serve(addr, ServeOptions { max_total_conns: Some(1), ..ServeOptions::default() })
    });
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut client = Client::connect(addr).unwrap();
    let completion = client.complete("the", 8, 0.0).unwrap();
    assert!(completion.tokens > 0);
    assert!(!completion.text.is_empty());
    assert!(!completion.reason.is_empty(), "done line carries a finish reason");
    assert!(completion.tps > 0.0);
    drop(client);
    handle.join().unwrap().unwrap();
}
