//! Overload resilience: bounded admission / load shedding, per-request
//! deadlines, and graceful shutdown.  Everything runs on synthetic
//! checkpoints (tier-1 — no `make artifacts` needed); the fault plan's
//! deterministic slow rounds stand in for a loaded engine so the tests
//! assert on guarantees, not on timing luck.
//!
//! The accounting invariant checked throughout: every submission is
//! rejected or admitted, and every admitted request terminates exactly
//! once — `requests_admitted == requests_completed + requests_cancelled
//! + requests_deadline_exceeded`.

use std::path::PathBuf;
use std::sync::Arc;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{
    batcher::BatchPolicy, AdmissionPolicy, Coordinator, CoordinatorConfig, Event, FinishReason,
    RejectReason, Request,
};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::server::{http_get, ServeOptions, Server};
use rwkv_lite::testutil::faults::FaultPlan;
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};
use rwkv_lite::text::Vocab;

/// Coordinator over a synthetic checkpoint with explicit admission bounds
/// and an optional fault plan (slow rounds = deterministic pressure).
fn overload_coordinator(
    tag: &str,
    policy: BatchPolicy,
    admission: AdmissionPolicy,
    faults: Option<FaultPlan>,
) -> (Coordinator, PathBuf) {
    let dir = std::env::temp_dir().join(format!("rwkv-overload-{}-{}", tag, std::process::id()));
    let spec = SynthSpec::tiny();
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    let c = Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig { policy, admission, faults, ..CoordinatorConfig::default() },
    );
    (c, dir)
}

fn assert_accounting(c: &Coordinator) {
    let admitted = c.metrics.counter("requests_admitted");
    let terminated = c.metrics.counter("requests_completed")
        + c.metrics.counter("requests_cancelled")
        + c.metrics.counter("requests_deadline_exceeded");
    assert_eq!(
        admitted, terminated,
        "every admitted request must terminate exactly once \
         (admitted={admitted} terminated={terminated})"
    );
}

/// Drain one handle to its terminal event.
fn outcome(handle: rwkv_lite::coordinator::RequestHandle) -> Event {
    let mut last = None;
    for ev in handle {
        let terminal = !matches!(ev, Event::Token { .. });
        last = Some(ev);
        if terminal {
            break;
        }
    }
    last.expect("stream ended without a terminal event")
}

/// A 16-request burst against `max_queue=2, max_concurrency=2` sheds most
/// of the burst immediately with structured rejections, completes every
/// admitted request, and never deadlocks.
#[test]
fn burst_sheds_cleanly_and_admitted_requests_complete() {
    let admission = AdmissionPolicy {
        max_queue: 2,
        max_concurrency: 2,
        ..AdmissionPolicy::default()
    };
    // every round sleeps 20ms: the burst lands while slot 0/1 are busy,
    // so the shed decision is forced, not timing-dependent
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 20);
    let (c, dir) = overload_coordinator(
        "burst",
        BatchPolicy { max_batch: 2, window_ms: 1 },
        admission,
        Some(faults),
    );
    // warm-up: engine load happens on the coordinator thread; bursting
    // while it is still loading would shed everything but the queue
    let warm = Request { id: 100, prompt: vec![2, 5], max_tokens: 1, ..Request::default() };
    c.generate_blocking(warm).unwrap();
    let handles: Vec<_> = (0..16u64)
        .map(|i| {
            c.submit(Request {
                id: i,
                prompt: vec![2, 5 + (i as u32 % 8)],
                max_tokens: 2,
                ..Request::default()
            })
        })
        .collect();
    let mut completed = 0u64;
    let mut rejected = 0u64;
    for h in handles {
        match outcome(h) {
            Event::Done { .. } => completed += 1,
            Event::Rejected { reason, retry_after_ms } => {
                assert_eq!(reason, RejectReason::Overloaded);
                assert!(
                    retry_after_ms >= 1,
                    "shed replies must carry a usable backoff hint"
                );
                rejected += 1;
            }
            other => panic!("unexpected terminal event: {other:?}"),
        }
    }
    assert_eq!(completed + rejected, 16, "every request gets exactly one terminal event");
    // at any instant at most 2 requests are in flight and 2 queued; a
    // 16-deep burst against a 20ms round MUST shed well over half (the
    // exact count depends on how admission interleaves with submission)
    assert!(rejected >= 8, "expected most of the burst shed, got {rejected}/16");
    assert!(completed >= 4, "the queue must still make progress, got {completed}/16");
    assert_eq!(c.metrics.counter("requests_rejected"), rejected);
    // +1: the warm-up request
    assert_eq!(c.metrics.counter("requests_completed"), completed + 1);
    assert_accounting(&c);
    // the queue_depth gauge settled back to empty
    assert_eq!(c.metrics.counter("queue_depth"), 0);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Prompts over `max_prompt_tokens` are refused before any engine work.
#[test]
fn over_limit_prompt_is_rejected() {
    let admission = AdmissionPolicy { max_prompt_tokens: 8, ..AdmissionPolicy::default() };
    let (c, dir) = overload_coordinator("promptcap", BatchPolicy::default(), admission, None);
    let h = c.submit(Request {
        id: 1,
        prompt: (0..20).map(|i| 4 + i % 32).collect(),
        max_tokens: 4,
        ..Request::default()
    });
    match outcome(h) {
        Event::Rejected { reason, retry_after_ms } => {
            assert_eq!(reason, RejectReason::PromptTooLong { tokens: 20, limit: 8 });
            assert_eq!(retry_after_ms, 0, "a longer wait will not shrink the prompt");
        }
        other => panic!("expected rejection, got {other:?}"),
    }
    // an in-bounds prompt still works on the same coordinator
    let out = c
        .generate_blocking(Request {
            id: 2,
            prompt: vec![2, 5, 6],
            max_tokens: 4,
            ..Request::default()
        })
        .unwrap();
    assert!(!out.is_empty());
    assert_eq!(c.metrics.counter("requests_rejected"), 1);
    assert_eq!(c.metrics.counter("requests_admitted"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// A request whose deadline passes mid-flight retires at the next round
/// boundary with `reason: "deadline"`, keeping the tokens it already
/// streamed; an injected slow round guarantees the deadline is hit during
/// prefill, where EOS cannot end the stream first.
#[test]
fn deadline_exceeded_mid_request() {
    // 25ms per round vs a 60ms deadline: the 40-token prompt needs ~6
    // prefill rounds at the default chunk, so the deadline always lands
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 25);
    let (c, dir) = overload_coordinator(
        "deadline",
        BatchPolicy { max_batch: 2, window_ms: 1 },
        AdmissionPolicy::default(),
        Some(faults),
    );
    let h = c.submit(Request {
        id: 1,
        prompt: (0..40).map(|i| 4 + i % 32).collect(),
        max_tokens: 100,
        deadline_ms: Some(60),
        ..Request::default()
    });
    let mut streamed = 0usize;
    let mut terminal = None;
    for ev in h {
        match ev {
            Event::Token { .. } => streamed += 1,
            other => {
                terminal = Some(other);
                break;
            }
        }
    }
    match terminal.expect("no terminal event") {
        Event::Done { tokens, reason, .. } => {
            assert_eq!(reason, FinishReason::DeadlineExceeded);
            assert_eq!(reason.name(), "deadline", "wire name");
            assert_eq!(tokens, streamed, "Done must carry the partial token count");
        }
        other => panic!("expected deadline Done, got {other:?}"),
    }
    assert_eq!(c.metrics.counter("requests_deadline_exceeded"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// `AdmissionPolicy::default_deadline_ms` applies to requests that carry
/// no deadline of their own — the `--deadline-ms` server default.
#[test]
fn policy_default_deadline_applies() {
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 25);
    let admission = AdmissionPolicy { default_deadline_ms: 60, ..AdmissionPolicy::default() };
    let (c, dir) = overload_coordinator(
        "deadline-default",
        BatchPolicy { max_batch: 2, window_ms: 1 },
        admission,
        Some(faults),
    );
    let h = c.submit(Request {
        id: 1,
        prompt: (0..40).map(|i| 4 + i % 32).collect(),
        max_tokens: 100,
        ..Request::default()
    });
    match outcome(h) {
        Event::Done { reason, .. } => assert_eq!(reason, FinishReason::DeadlineExceeded),
        other => panic!("expected deadline Done, got {other:?}"),
    }
    assert_eq!(c.metrics.counter("requests_deadline_exceeded"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful shutdown: in-flight requests drain to natural completion (the
/// drain budget is generous), new submissions are refused with
/// `shutting_down`, and the coordinator thread exits.
#[test]
fn graceful_shutdown_drains_in_flight_and_rejects_new() {
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 10);
    let admission = AdmissionPolicy { drain_ms: 30_000, ..AdmissionPolicy::default() };
    let (mut c, dir) = overload_coordinator(
        "drain",
        BatchPolicy { max_batch: 4, window_ms: 1 },
        admission,
        Some(faults),
    );
    let in_flight: Vec<_> = (0..2u64)
        .map(|i| {
            c.submit(Request {
                id: i,
                prompt: (0..20).map(|j| 4 + (j + i as u32) % 32).collect(),
                max_tokens: 3,
                ..Request::default()
            })
        })
        .collect();
    // let the round loop pick both up (10ms rounds; 200ms is plenty)
    std::thread::sleep(std::time::Duration::from_millis(200));
    c.begin_shutdown();
    // a post-shutdown submission is refused, never queued
    match outcome(c.submit(Request {
        id: 99,
        prompt: vec![2, 5],
        max_tokens: 2,
        ..Request::default()
    })) {
        Event::Rejected { reason, .. } => assert_eq!(reason, RejectReason::ShuttingDown),
        other => panic!("expected shutting_down rejection, got {other:?}"),
    }
    // the in-flight requests still finish with a terminal Done each
    for h in in_flight {
        match outcome(h) {
            Event::Done { reason, .. } => {
                assert_ne!(
                    reason,
                    FinishReason::Cancelled,
                    "a generous drain budget must let requests finish naturally"
                );
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }
    c.shutdown(); // join the coordinator thread
    assert_eq!(c.metrics.counter("requests_completed"), 2);
    assert_eq!(c.metrics.counter("requests_rejected"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// An exhausted drain budget hard-stops stragglers — each STILL gets a
/// terminal Done (reason: cancelled), so clients never hang on shutdown.
#[test]
fn drain_budget_hard_stops_stragglers() {
    // 30ms rounds vs a 1ms drain budget: the straggler cannot finish
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 30);
    let admission = AdmissionPolicy { drain_ms: 1, ..AdmissionPolicy::default() };
    let (mut c, dir) = overload_coordinator(
        "drain-cut",
        BatchPolicy { max_batch: 2, window_ms: 1 },
        admission,
        Some(faults),
    );
    let h = c.submit(Request {
        id: 1,
        prompt: (0..60).map(|i| 4 + i % 32).collect(),
        max_tokens: 100,
        ..Request::default()
    });
    std::thread::sleep(std::time::Duration::from_millis(100));
    c.begin_shutdown();
    match outcome(h) {
        Event::Done { reason, .. } => assert_eq!(reason, FinishReason::Cancelled),
        other => panic!("expected cancelled Done, got {other:?}"),
    }
    c.shutdown();
    assert_eq!(c.metrics.counter("requests_cancelled"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// First value of a bare `name value` sample line in a text exposition
/// (0 when the family is absent — counters appear on first increment).
fn prom_counter(text: &str, name: &str) -> u64 {
    let prefix = format!("{name} ");
    text.lines()
        .find_map(|l| l.strip_prefix(&prefix))
        .map(|v| v.parse().expect("counter value parses"))
        .unwrap_or(0)
}

/// The accounting invariant is readable through `GET /metrics` WHILE an
/// overload burst is in flight: counters and gauges render under one
/// registry lock, so a single scrape is internally consistent —
/// `admitted - terminated` is exactly the live population, bounded by
/// `max_concurrency + max_queue`, and settles to zero after the drain.
#[test]
fn metrics_scrape_is_consistent_during_overload_burst() {
    let dir = std::env::temp_dir().join(format!("rwkv-overload-scrape-{}", std::process::id()));
    let spec = SynthSpec::tiny();
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    let admission = AdmissionPolicy {
        max_queue: 2,
        max_concurrency: 2,
        ..AdmissionPolicy::default()
    };
    // 15ms rounds keep the burst in flight long enough to scrape mid-air
    let faults = FaultPlan::new().slow_rounds_from(0, 10_000, 15);
    let c = Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 2, window_ms: 1 },
            admission,
            faults: Some(faults),
            ..CoordinatorConfig::default()
        },
    );
    let mut words: Vec<String> =
        ["<pad>", "<unk>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
    for i in 4..96 {
        words.push(format!("w{i}"));
    }
    let server = Arc::new(Server::new(c, Vocab::from_words(words)));
    let addr = "127.0.0.1:17383";
    let s2 = Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || {
        // exactly the 4 scrape connections below, then exit
        s2.serve(
            addr,
            ServeOptions {
                max_total_conns: Some(4),
                metrics_endpoint: true,
                ..ServeOptions::default()
            },
        )
    });
    std::thread::sleep(std::time::Duration::from_millis(150));
    // warm up so the burst does not land while the engine is loading
    let coord = Arc::clone(&server.coordinator);
    coord
        .generate_blocking(Request {
            id: 100,
            prompt: vec![2, 5],
            max_tokens: 1,
            ..Request::default()
        })
        .unwrap();
    // the burst drains on its own thread while this one scrapes
    let producer = std::thread::spawn(move || {
        let handles: Vec<_> = (0..12u64)
            .map(|i| {
                coord.submit(Request {
                    id: i,
                    prompt: vec![2, 5 + (i as u32 % 8)],
                    max_tokens: 2,
                    ..Request::default()
                })
            })
            .collect();
        for h in handles {
            outcome(h);
        }
    });
    let scrape = || {
        let (status, body) = http_get(addr, "/metrics").expect("scrape mid-burst");
        assert_eq!(status, 200);
        body
    };
    for _ in 0..3 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let body = scrape();
        let admitted = prom_counter(&body, "rwkv_requests_admitted");
        let terminated = prom_counter(&body, "rwkv_requests_completed")
            + prom_counter(&body, "rwkv_requests_cancelled")
            + prom_counter(&body, "rwkv_requests_deadline_exceeded");
        assert!(
            admitted >= terminated,
            "a single scrape must never show a request terminating before admission \
             (admitted={admitted} terminated={terminated})"
        );
        assert!(
            admitted - terminated <= 4,
            "live population exceeds max_concurrency + max_queue: \
             admitted={admitted} terminated={terminated}"
        );
    }
    producer.join().unwrap();
    // after the drain the very same surface shows exact equality
    let body = scrape();
    let admitted = prom_counter(&body, "rwkv_requests_admitted");
    let terminated = prom_counter(&body, "rwkv_requests_completed")
        + prom_counter(&body, "rwkv_requests_cancelled")
        + prom_counter(&body, "rwkv_requests_deadline_exceeded");
    assert_eq!(admitted, terminated, "every admitted request terminated exactly once");
    assert!(admitted >= 1, "the warm-up plus admitted burst slice must show up");
    assert_eq!(prom_counter(&body, "rwkv_queue_depth"), 0, "queue gauge settles to empty");
    assert_accounting(&server.coordinator);
    serve_thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
