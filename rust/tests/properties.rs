//! Property-based tests (testutil harness) on kernel/coordinator
//! invariants — the no-proptest substrate exercised for real.

use rwkv_lite::pool::Par;
use rwkv_lite::tensor::{
    self, accum_rows_indexed, accum_rows_indexed_batch, layer_norm, matmat_in_out, matmat_rows,
    matmat_rows_indexed, matvec_in_out, matvec_rows, matvec_rows_indexed, Mat, ShadowView,
};
use rwkv_lite::testutil::{check, ensure, ensure_close, Gen};
use rwkv_lite::util::{f16_to_f32, f32_to_f16, logsumexp, softmax_inplace};

#[test]
fn prop_matvec_linearity() {
    // matvec(a*x + b*y) == a*matvec(x) + b*matvec(y)
    check("matvec linearity", 120, |g: &mut Gen| {
        let rows = g.usize_in(1, 48);
        let cols = g.usize_in(1, 48);
        let w = Mat::from_f32(rows, cols, g.vec_normal(rows * cols));
        let x = g.vec_normal(rows);
        let y = g.vec_normal(rows);
        let (a, b) = (g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0));
        let mut lhs = vec![0.0; cols];
        let mut acc = Vec::new();
        let mix: Vec<f32> = x.iter().zip(&y).map(|(xi, yi)| a * xi + b * yi).collect();
        matvec_in_out(&mix, &w, &mut lhs, &mut acc);
        let mut ox = vec![0.0; cols];
        let mut oy = vec![0.0; cols];
        matvec_in_out(&x, &w, &mut ox, &mut acc);
        matvec_in_out(&y, &w, &mut oy, &mut acc);
        for j in 0..cols {
            ensure_close(lhs[j], a * ox[j] + b * oy[j], 1e-3, "linearity")?;
        }
        Ok(())
    });
}

#[test]
fn prop_rows_layout_is_transpose_of_in_out() {
    check("rows == transpose(in_out)", 100, |g: &mut Gen| {
        let rows = g.usize_in(1, 32);
        let cols = g.usize_in(1, 32);
        let data = g.vec_normal(rows * cols);
        // W (rows, cols) consumed row-per-output == W^T consumed in-out
        let w_rows = Mat::from_f32(rows, cols, data.clone());
        let mut t = vec![0.0; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = data[r * cols + c];
            }
        }
        let w_io = Mat::from_f32(cols, rows, t);
        let x = g.vec_normal(cols);
        let mut a = vec![0.0; rows];
        matvec_rows(&w_rows, &x, &mut a);
        let mut b = vec![0.0; rows];
        matvec_in_out(&x, &w_io, &mut b, &mut Vec::new());
        for j in 0..rows {
            ensure_close(a[j], b[j], 1e-3, "transpose equivalence")?;
        }
        Ok(())
    });
}

#[test]
fn prop_indexed_matvec_subset_of_dense() {
    check("indexed == dense subset", 100, |g: &mut Gen| {
        let rows = g.usize_in(2, 40);
        let cols = g.usize_in(1, 24);
        let w = Mat::from_f32(rows, cols, g.vec_normal(rows * cols));
        let x = g.vec_normal(cols);
        let idx = g.indices(rows, 10);
        let mut dense = vec![0.0; rows];
        matvec_rows(&w, &x, &mut dense);
        let mut sparse = vec![0.0; idx.len()];
        matvec_rows_indexed(&w, &idx, &x, &mut sparse);
        for (k, &j) in idx.iter().enumerate() {
            ensure(sparse[k] == dense[j as usize], "exact subset")?;
        }
        Ok(())
    });
}

#[test]
fn prop_f16_round_trip_monotone() {
    check("f16 conversion order-preserving", 150, |g: &mut Gen| {
        let a = g.f32_in(-1e4, 1e4);
        let b = g.f32_in(-1e4, 1e4);
        let (fa, fb) = (f16_to_f32(f32_to_f16(a)), f16_to_f32(f32_to_f16(b)));
        if a < b {
            ensure(fa <= fb, "monotone")?;
        }
        ensure_close(fa, a, 2e-3, "round trip")?;
        Ok(())
    });
}

#[test]
fn prop_softmax_invariant_to_shift() {
    check("softmax shift invariance", 100, |g: &mut Gen| {
        let mut x = g.vec_f32(32, -10.0, 10.0);
        let shift = g.f32_in(-50.0, 50.0);
        let mut y: Vec<f32> = x.iter().map(|v| v + shift).collect();
        softmax_inplace(&mut x);
        softmax_inplace(&mut y);
        for (a, b) in x.iter().zip(&y) {
            ensure_close(*a, *b, 1e-3, "shift invariance")?;
        }
        Ok(())
    });
}

#[test]
fn prop_logsumexp_bounds() {
    check("max <= lse <= max + ln(n)", 100, |g: &mut Gen| {
        let x = g.vec_f32(64, -30.0, 30.0);
        let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = logsumexp(&x);
        ensure(lse >= m - 1e-4, "lower bound")?;
        ensure(lse <= m + (x.len() as f32).ln() + 1e-4, "upper bound")?;
        Ok(())
    });
}

#[test]
fn prop_layernorm_scale_invariant() {
    check("layernorm(a*x) == layernorm(x) for a>0", 80, |g: &mut Gen| {
        let n = g.usize_in(2, 48);
        let x = g.vec_normal(n);
        let a = g.f32_in(0.5, 20.0);
        let ones = vec![1.0f32; n];
        let zeros = vec![0.0f32; n];
        let scaled: Vec<f32> = x.iter().map(|v| v * a).collect();
        let mut o1 = vec![0.0; n];
        let mut o2 = vec![0.0; n];
        layer_norm(&x, &ones, &zeros, 1e-6, &mut o1);
        layer_norm(&scaled, &ones, &zeros, 1e-6, &mut o2);
        for (p, q) in o1.iter().zip(&o2) {
            ensure_close(*p, *q, 1e-2, "scale invariance")?;
        }
        Ok(())
    });
}

#[test]
fn prop_bit_matvec_sign_flip_antisymmetric() {
    // scores(x) == -scores(-x)
    check("bit matvec antisymmetry", 80, |g: &mut Gen| {
        let in_dim = g.usize_in(1, 40);
        let out_dim = g.usize_in(1, 24);
        let packed: Vec<u8> = (0..in_dim.div_ceil(8) * out_dim)
            .map(|_| (g.rng.next_u64() & 0xff) as u8)
            .collect();
        let scale: Vec<f32> = (0..out_dim).map(|_| g.f32_in(0.01, 2.0)).collect();
        let x = g.vec_normal(in_dim);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let mut a = vec![0.0; out_dim];
        let mut b = vec![0.0; out_dim];
        let shadow = ShadowView::bits(&packed, &scale, in_dim);
        shadow.matvec(&x, &mut a);
        shadow.matvec(&neg, &mut b);
        for (p, q) in a.iter().zip(&b) {
            ensure_close(*p, -*q, 1e-3, "antisymmetry")?;
        }
        Ok(())
    });
}

/// Random-dtype matrix generator shared by the matmat properties:
/// f32 / f16 / i8 / q4 / q4_1 with the scale length the consumer expects.
fn gen_mat(g: &mut Gen, rows: usize, cols: usize, scale_rows: bool) -> Mat {
    let data = g.vec_normal(rows * cols);
    match g.usize_in(0, 5) % 5 {
        0 => Mat::from_f32(rows, cols, data),
        1 => Mat::f32_to_f16_mat(rows, cols, &data),
        2 => Mat::quantize_q4_mat(rows, cols, &data),
        3 => Mat::quantize_q4_1_mat(rows, cols, &data),
        _ => {
            let q: Vec<i8> = data.iter().map(|v| (v * 30.0).clamp(-127.0, 127.0) as i8).collect();
            let n = if scale_rows { rows } else { cols };
            let scale: Vec<f32> = (0..n).map(|_| g.f32_in(0.005, 0.05)).collect();
            Mat::I8 { rows, cols, data: q, scale }
        }
    }
}

/// Dense f32 matrix holding exactly a quantized matrix's decoded values.
fn dequantized_dense(q: &Mat) -> Mat {
    Mat::from_f32(q.rows(), q.cols(), q.to_f32_vec())
}

#[test]
fn prop_q4_kernels_bitwise_match_dequantized_dense() {
    // the fused Q4/Q4_1 kernels must produce bit-identical outputs to the
    // plain f32 kernels run on the dequantized weights — across random
    // shapes (ragged final groups, cols below / straddling / beyond the
    // 32-wide group), indexed subsets, and nonzero residuals
    check("q4 kernels == dequantized dense", 120, |g: &mut Gen| {
        let rows = g.usize_in(2, 40);
        let cols = g.usize_in(1, 80);
        let data = g.vec_normal(rows * cols);
        let quants = [
            Mat::quantize_q4_mat(rows, cols, &data),
            Mat::quantize_q4_1_mat(rows, cols, &data),
        ];
        for q in &quants {
            let d = dequantized_dense(q);
            // row-per-output
            let x = g.vec_normal(cols);
            let mut got = vec![0.0f32; rows];
            let mut want = vec![0.0f32; rows];
            matvec_rows(q, &x, &mut got);
            matvec_rows(&d, &x, &mut want);
            ensure(got == want, "matvec_rows bitwise")?;
            // indexed subset
            let idx = g.indices(rows, 10);
            let mut gi = vec![0.0f32; idx.len()];
            let mut wi = vec![0.0f32; idx.len()];
            matvec_rows_indexed(q, &idx, &x, &mut gi);
            matvec_rows_indexed(&d, &idx, &x, &mut wi);
            ensure(gi == wi, "matvec_rows_indexed bitwise")?;
            // in-out with a residual already in the output
            let xi = g.vec_normal(rows);
            let residual = g.vec_normal(cols);
            let mut go = residual.clone();
            let mut wo = residual.clone();
            matvec_in_out(&xi, q, &mut go, &mut Vec::new());
            matvec_in_out(&xi, &d, &mut wo, &mut Vec::new());
            ensure(go == wo, "matvec_in_out bitwise")?;
            // accumulate selected rows (zero coefficients must be skipped)
            let mut hs = g.vec_normal(idx.len());
            if let Some(h) = hs.first_mut() {
                *h = 0.0;
            }
            let mut ga = residual.clone();
            let mut wa = residual.clone();
            accum_rows_indexed(q, &idx, &hs, &mut ga);
            accum_rows_indexed(&d, &idx, &hs, &mut wa);
            ensure(ga == wa, "accum_rows_indexed bitwise")?;
        }
        Ok(())
    });
}

#[test]
fn prop_matmat_in_out_is_per_slot_matvec() {
    // every dtype, random B: batched kernel == B independent matvecs, bitwise
    check("matmat_in_out == per-slot matvec", 100, |g: &mut Gen| {
        let rows = g.usize_in(1, 24);
        let cols = g.usize_in(1, 24);
        let b = g.usize_in(1, 9);
        let w = gen_mat(g, rows, cols, false);
        let xs = g.vec_normal(b * rows);
        let residual = g.vec_normal(b * cols);
        let mut outs = residual.clone();
        matmat_in_out(&xs, &w, &mut outs, &mut Vec::new(), Par::serial());
        for s in 0..b {
            let mut want = residual[s * cols..(s + 1) * cols].to_vec();
            matvec_in_out(&xs[s * rows..(s + 1) * rows], &w, &mut want, &mut Vec::new());
            ensure(outs[s * cols..(s + 1) * cols] == want[..], "bitwise slot equality")?;
        }
        Ok(())
    });
}

#[test]
fn prop_matmat_rows_is_per_slot_matvec() {
    check("matmat_rows == per-slot matvec_rows", 100, |g: &mut Gen| {
        let rows = g.usize_in(1, 24);
        let cols = g.usize_in(1, 24);
        let b = g.usize_in(1, 9);
        let w = gen_mat(g, rows, cols, true);
        let xs = g.vec_normal(b * cols);
        let mut outs = vec![0.0f32; b * rows];
        matmat_rows(&w, &xs, &mut outs, Par::serial());
        for s in 0..b {
            let mut want = vec![0.0f32; rows];
            matvec_rows(&w, &xs[s * cols..(s + 1) * cols], &mut want);
            ensure(outs[s * rows..(s + 1) * rows] == want[..], "bitwise slot equality")?;
        }
        Ok(())
    });
}

#[test]
fn prop_matmat_rows_indexed_is_per_slot_matvec() {
    check("matmat_rows_indexed == per-slot", 100, |g: &mut Gen| {
        let rows = g.usize_in(2, 32);
        let cols = g.usize_in(1, 20);
        let b = g.usize_in(1, 6);
        let w = gen_mat(g, rows, cols, true);
        let idx = g.indices(rows, 12);
        let xs = g.vec_normal(b * cols);
        let k = idx.len();
        let mut outs = vec![0.0f32; b * k];
        matmat_rows_indexed(&w, &idx, &xs, &mut outs, Par::serial());
        for s in 0..b {
            let mut want = vec![0.0f32; k];
            matvec_rows_indexed(&w, &idx, &xs[s * cols..(s + 1) * cols], &mut want);
            ensure(outs[s * k..(s + 1) * k] == want[..], "bitwise slot equality")?;
        }
        Ok(())
    });
}

#[test]
fn prop_accum_rows_batch_is_per_slot_accum() {
    check("accum_rows_indexed_batch == per-slot", 100, |g: &mut Gen| {
        let rows = g.usize_in(2, 32);
        let cols = g.usize_in(1, 20);
        let b = g.usize_in(1, 6);
        let w = gen_mat(g, rows, cols, false);
        let idx = g.indices(rows, 10);
        let k = idx.len();
        let mut hs = g.vec_normal(b * k);
        // union-masking is expressed as zeros — they must be skipped
        for (i, h) in hs.iter_mut().enumerate() {
            if i % 4 == 0 {
                *h = 0.0;
            }
        }
        let mut outs = vec![0.0f32; b * cols];
        accum_rows_indexed_batch(&w, &idx, &hs, b, &mut outs, Par::serial());
        for s in 0..b {
            let mut want = vec![0.0f32; cols];
            accum_rows_indexed(&w, &idx, &hs[s * k..(s + 1) * k], &mut want);
            ensure(outs[s * cols..(s + 1) * cols] == want[..], "bitwise slot equality")?;
        }
        Ok(())
    });
}

#[test]
fn prop_kth_largest_is_order_statistic() {
    check("kth largest", 100, |g: &mut Gen| {
        let xs = g.vec_f32(64, -100.0, 100.0);
        let k = g.usize_in(1, xs.len() + 1).min(xs.len()).max(1);
        let thr = rwkv_lite::engine::sparse_ffn::kth_largest(&xs, k);
        let ge = xs.iter().filter(|&&v| v >= thr).count();
        ensure(ge >= k, &format!("at least k={k} elements >= thr, got {ge}"))?;
        Ok(())
    });
}

#[test]
fn prop_sqrelu_nonnegative_and_monotone() {
    check("sqrelu", 80, |g: &mut Gen| {
        let mut x = g.vec_f32(48, -5.0, 5.0);
        let orig = x.clone();
        tensor::sqrelu_inplace(&mut x);
        for (o, v) in orig.iter().zip(&x) {
            ensure(*v >= 0.0, "non-negative")?;
            if *o <= 0.0 {
                ensure(*v == 0.0, "negatives suppressed")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_group_norm_per_head_zero_mean() {
    check("group norm per-head mean", 60, |g: &mut Gen| {
        let heads = g.usize_in(1, 8);
        let hs = g.usize_in(2, 16);
        let n = heads * hs;
        let mut x = g.vec_normal(n);
        let ones = vec![1.0f32; n];
        let zeros = vec![0.0f32; n];
        tensor::group_norm_heads(&mut x, heads, &ones, &zeros);
        for h in 0..heads {
            let seg = &x[h * hs..(h + 1) * hs];
            let mean: f32 = seg.iter().sum::<f32>() / hs as f32;
            ensure(mean.abs() < 1e-3, "per-head zero mean")?;
        }
        Ok(())
    });
}
