//! Observability layer: histogram bucket math against the exact
//! sorted-sample reference, Prometheus text-exposition validity, and the
//! `GET /metrics` / `GET /stats` scrape path over TCP.  Everything runs
//! on synthetic checkpoints (no `make artifacts` needed) so it is all
//! tier-1 coverage.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator, CoordinatorConfig};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::json;
use rwkv_lite::metrics::hist::Histogram;
use rwkv_lite::metrics::Registry;
use rwkv_lite::server::{http_get, Client, ServeOptions, Server};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};
use rwkv_lite::text::Vocab;
use rwkv_lite::util::{percentile, XorShift};

// ---------------------------------------------------------------------
// bucket math vs the exact reference
// ---------------------------------------------------------------------

/// Seeded sample sets with different shapes: uniform, exponential-ish,
/// and bimodal (1ms vs 100ms modes) — quantile error bounds must hold
/// regardless of how samples spread across octaves.
fn distributions() -> Vec<(&'static str, Vec<f64>)> {
    let mut out = Vec::new();
    let mut rng = XorShift::new(11);
    out.push((
        "uniform",
        (0..5000).map(|_| 1e-6 + rng.next_f64() * 0.25).collect(),
    ));
    let mut rng = XorShift::new(22);
    out.push((
        "exponential",
        (0..5000).map(|_| -(1.0 - rng.next_f64()).ln() * 0.01).collect(),
    ));
    let mut rng = XorShift::new(33);
    out.push((
        "bimodal",
        (0..5000)
            .map(|_| {
                let u = rng.next_f64();
                if u < 0.8 {
                    1e-3 * (0.5 + u)
                } else {
                    0.1 * (0.5 + u)
                }
            })
            .collect(),
    ));
    out
}

/// The tentpole accuracy claim: every histogram quantile sits within ONE
/// bucket width of the exact sorted-sample percentile (same nearest-rank
/// convention as [`rwkv_lite::util::percentile`]).
#[test]
fn quantiles_match_exact_reference_within_one_bucket() {
    for (name, samples) in distributions() {
        let h = Histogram::new();
        for &s in &samples {
            h.record(s);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, samples.len() as u64);
        for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
            let exact = percentile(&samples, p);
            let est = snap.quantile(p);
            let (lo, hi) = Histogram::bucket_bounds_secs(exact);
            let width = hi - lo;
            // the estimate is the containing bucket's upper bound: never
            // below the exact value (modulo 1ns quantization), never more
            // than one bucket width above it
            assert!(
                est >= exact - 2e-9,
                "{name} p{p}: estimate {est} fell below exact {exact}"
            );
            assert!(
                est - exact <= width + 2e-9,
                "{name} p{p}: estimate {est} vs exact {exact} exceeds bucket width {width}"
            );
        }
    }
}

/// Merging shard histograms is equivalent to one histogram that saw all
/// the samples — counts, sums, and quantiles all agree.
#[test]
fn merged_shards_equal_whole() {
    let (_, samples) = distributions().remove(1);
    let whole = Histogram::new();
    let shards: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
    for (i, &s) in samples.iter().enumerate() {
        whole.record(s);
        shards[i % 4].record(s);
    }
    let merged = Histogram::new();
    for sh in &shards {
        merged.merge_from(sh);
    }
    let (w, m) = (whole.snapshot(), merged.snapshot());
    assert_eq!(w.count, m.count);
    assert!((w.sum_secs - m.sum_secs).abs() < 1e-12);
    assert_eq!(w.max_secs, m.max_secs);
    for p in [50.0, 90.0, 99.0] {
        assert_eq!(w.quantile(p), m.quantile(p), "p{p} must match after merge");
    }
}

/// Absurd observations saturate into the top bucket instead of indexing
/// out of bounds, and the saturated family still renders parseable
/// exposition lines.
#[test]
fn top_bucket_saturation_is_visible_and_renders() {
    let m = Registry::new();
    m.observe("weird_secs", 1e12); // ~31,700 years
    m.observe("weird_secs", f64::MAX);
    m.observe("weird_secs", 0.001);
    let s = m.hist_snapshot("weird_secs").unwrap();
    assert_eq!(s.count, 3);
    let p100 = s.quantile(100.0);
    assert!(p100.is_finite() && p100 > 1e9, "saturated quantile reports the top bucket");
    for (family, lines) in parse_prom(&m.render_prometheus()) {
        for (labels, v) in lines {
            assert!(v.is_finite(), "{family}{labels} rendered a non-finite value {v}");
        }
    }
}

// ---------------------------------------------------------------------
// Prometheus exposition format
// ---------------------------------------------------------------------

/// Parse a text exposition into `family -> [(labels, value)]`, panicking
/// on any line that does not match the
/// `name[{labels}] value` / `# TYPE name kind` grammar.
fn parse_prom(text: &str) -> BTreeMap<String, Vec<(String, f64)>> {
    let mut out: BTreeMap<String, Vec<(String, f64)>> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line has a name");
            let kind = it.next().expect("TYPE line has a kind");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown metric kind in '{line}'"
            );
            assert!(it.next().is_none(), "trailing junk in '{line}'");
            assert!(name.starts_with("rwkv_"), "metric '{name}' missing the rwkv_ prefix");
            continue;
        }
        // sample line: `name value` or `name{labels} value` (no label
        // value in this exposition ever contains a space)
        let (name_labels, value) = line.rsplit_once(' ').expect("sample line has a value");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("unparseable value in '{line}'"));
        let (name, labels) = match name_labels.split_once('{') {
            Some((n, l)) => (n.to_string(), format!("{{{l}")),
            None => (name_labels.to_string(), String::new()),
        };
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "invalid metric name in '{line}'"
        );
        out.entry(name).or_default().push((labels, v));
    }
    out
}

/// Every `_bucket` series is cumulative with increasing `le`, ends at
/// `+Inf` == `_count`, and `_sum`/`_count` agree with the registry's own
/// snapshot — on a registry populated with seeded data plus the labeled
/// finish-reason family.
#[test]
fn exposition_bucket_sum_count_consistency() {
    let m = Registry::new();
    m.inc("requests_admitted", 9);
    m.inc("finish_reason_length", 5);
    m.inc("finish_reason_stop", 3);
    m.inc("finish_reason_deadline", 1);
    m.set("queue_depth", 4);
    let mut rng = XorShift::new(7);
    for _ in 0..2000 {
        m.observe("ttft_secs", 0.002 + rng.next_f64() * 0.05);
        m.observe("itl_secs", 0.0005 + rng.next_f64() * 0.004);
    }
    let families = parse_prom(&m.render_prometheus());

    // the labeled family carries every reason exactly once
    let finished = &families["rwkv_requests_finished_total"];
    assert_eq!(finished.len(), 3);
    let total: f64 = finished.iter().map(|(_, v)| v).sum();
    assert_eq!(total, 9.0);
    assert!(finished.iter().any(|(l, v)| l == "{reason=\"length\"}" && *v == 5.0));

    for key in ["ttft_secs", "itl_secs"] {
        let snap = m.hist_snapshot(key).unwrap();
        let prom = format!("rwkv_{}", key.replace("_secs", "_seconds"));
        let buckets = &families[&format!("{prom}_bucket")];
        assert!(buckets.len() >= 2, "{prom} should spread across buckets");
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_cum = 0.0;
        for (labels, cum) in buckets {
            let le_str = labels
                .strip_prefix("{le=\"")
                .and_then(|l| l.strip_suffix("\"}"))
                .unwrap_or_else(|| panic!("bucket labels malformed: {labels}"));
            let le = if le_str == "+Inf" { f64::INFINITY } else { le_str.parse().unwrap() };
            assert!(le > prev_le, "{prom} le bounds must strictly increase");
            assert!(*cum >= prev_cum, "{prom} bucket counts must be cumulative");
            prev_le = le;
            prev_cum = *cum;
        }
        assert_eq!(prev_le, f64::INFINITY, "{prom} ends with the +Inf bucket");
        assert_eq!(prev_cum, snap.count as f64, "+Inf bucket equals _count");
        let count = families[&format!("{prom}_count")][0].1;
        let sum = families[&format!("{prom}_sum")][0].1;
        assert_eq!(count, snap.count as f64);
        assert!((sum - snap.sum_secs).abs() < 1e-9);
    }
}

// ---------------------------------------------------------------------
// TCP scrape path
// ---------------------------------------------------------------------

fn synth_vocab() -> Vocab {
    let mut words: Vec<String> =
        ["<pad>", "<unk>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
    for i in 4..96 {
        words.push(format!("w{i}"));
    }
    Vocab::from_words(words)
}

/// Synthetic-model TCP server with a caller-chosen scrape setting;
/// serves `conns` connections then exits.
fn scrape_server(
    tag: &str,
    addr: &'static str,
    conns: usize,
    metrics_endpoint: bool,
) -> (std::thread::JoinHandle<anyhow::Result<()>>, PathBuf) {
    let dir = std::env::temp_dir().join(format!("rwkv-scrape-{}-{}", tag, std::process::id()));
    let spec = SynthSpec::tiny();
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    let c = Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, window_ms: 1 },
            ..CoordinatorConfig::default()
        },
    );
    let server = Arc::new(Server::new(c, synth_vocab()));
    let handle = std::thread::spawn(move || {
        server.serve(
            addr,
            ServeOptions {
                max_total_conns: Some(conns),
                metrics_endpoint,
                ..ServeOptions::default()
            },
        )
    });
    std::thread::sleep(std::time::Duration::from_millis(150));
    (handle, dir)
}

/// End-to-end scrape: one completion over the line protocol, then
/// `GET /metrics` exposes the latency histograms and request counters
/// and `GET /stats` summarizes them as JSON — on the SAME port.
#[test]
fn tcp_metrics_and_stats_scrape() {
    let (server, dir) = scrape_server("on", "127.0.0.1:17381", 4, true);

    // one real completion so the histograms have data, checking the
    // extended Done wire line as we go
    let mut client = Client::connect("127.0.0.1:17381").unwrap();
    let lines = client.request_raw(r#"{"prompt":"w5 w6","max_tokens":4}"#).unwrap();
    let last = json::parse(lines.last().expect("terminal line")).unwrap();
    assert!(last.get("done").is_some());
    assert!(last.f64_at(&["queue_secs"]).is_some(), "Done line reports queue wait");
    let token_lines =
        lines.iter().filter(|l| json::parse(l).unwrap().get("token").is_some()).count();
    assert!(token_lines > 0, "greedy 'w5 w6' always emits on the synth model");
    let ttft = last.f64_at(&["ttft_secs"]).expect("Done line reports TTFT");
    assert!(ttft >= 0.0);
    drop(client);

    let (status, body) = http_get("127.0.0.1:17381", "/metrics").unwrap();
    assert_eq!(status, 200, "metrics scrape should succeed: {body}");
    let families = parse_prom(&body);
    assert_eq!(families["rwkv_requests_completed"][0].1, 1.0);
    for f in [
        "rwkv_ttft_seconds_count",
        "rwkv_queue_wait_seconds_count",
        "rwkv_request_total_seconds_count",
        "rwkv_coord_round_seconds_count",
        "rwkv_round_seconds_count",
    ] {
        assert!(families.contains_key(f), "scrape is missing {f}\n{body}");
    }
    assert_eq!(families["rwkv_request_total_seconds_count"][0].1, 1.0);
    assert!(
        families.contains_key("rwkv_requests_finished_total"),
        "completion must show up in the labeled finish-reason family"
    );

    let (status, body) = http_get("127.0.0.1:17381", "/stats").unwrap();
    assert_eq!(status, 200);
    let v = json::parse(body.trim()).expect("stats body is valid JSON");
    assert_eq!(v.f64_at(&["counters", "requests_completed"]), Some(1.0));
    assert_eq!(v.f64_at(&["histograms", "request_total_secs", "count"]), Some(1.0));
    assert!(v.f64_at(&["histograms", "request_total_secs", "p99_secs"]).unwrap() > 0.0);

    let (status, _) = http_get("127.0.0.1:17381", "/nope").unwrap();
    assert_eq!(status, 404, "unknown paths 404");

    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// With the `--metrics off` knob the GET paths 404 and the line protocol
/// still serves.
#[test]
fn tcp_scrape_disabled_returns_404() {
    let (server, dir) = scrape_server("off", "127.0.0.1:17382", 2, false);
    let (status, _) = http_get("127.0.0.1:17382", "/metrics").unwrap();
    assert_eq!(status, 404, "scrape must be off by default");
    let mut client = Client::connect("127.0.0.1:17382").unwrap();
    let done = client.complete("w5 w6", 2, 0.0).unwrap();
    assert!(done.tokens > 0);
    drop(client);
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// per-request spans through the coordinator
// ---------------------------------------------------------------------

/// The coordinator's span plumbing populates every request-level
/// histogram and the identity `request_total = queue + service` holds in
/// the sum.
#[test]
fn coordinator_populates_request_histograms() {
    let dir = std::env::temp_dir().join(format!("rwkv-spans-{}", std::process::id()));
    write_synth_rwkv(&dir, "m", &SynthSpec::tiny()).expect("write synth model");
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let c = Coordinator::spawn(
        move || RwkvEngine::load(cfg),
        BatchPolicy { max_batch: 4, window_ms: 1 },
    );
    let mut total_tokens = 0usize;
    let mut emitting_requests = 0usize; // requests that produced >= 1 token
    for id in 0..3u64 {
        let out = c
            .generate_blocking(rwkv_lite::coordinator::Request {
                id,
                prompt: vec![2, (5 + id) as u32, 9],
                max_tokens: 6,
                ..rwkv_lite::coordinator::Request::default()
            })
            .unwrap();
        total_tokens += out.len();
        emitting_requests += usize::from(!out.is_empty());
    }
    let queue = c.metrics.hist_snapshot("queue_wait_secs").expect("queue wait recorded");
    let total = c.metrics.hist_snapshot("request_total_secs").expect("total recorded");
    assert_eq!(queue.count, 3);
    assert_eq!(total.count, 3);
    // TTFT is recorded once per request at its FIRST emitted token; every
    // later token contributes one inter-token-latency sample instead
    let ttft = c.metrics.hist_snapshot("ttft_secs").map(|s| s.count).unwrap_or(0);
    assert_eq!(ttft as usize, emitting_requests, "one TTFT per emitting request");
    let itl = c.metrics.hist_snapshot("itl_secs").map(|s| s.count).unwrap_or(0);
    assert_eq!(
        itl as usize,
        total_tokens - emitting_requests,
        "ITL counts token gaps, not tokens"
    );
    // cold-start requests land in the cold TTFT split, none in warm
    let cold = c.metrics.hist_snapshot("ttft_cold_secs").map(|s| s.count).unwrap_or(0);
    let warm = c.metrics.hist_snapshot("ttft_warm_secs").map(|s| s.count).unwrap_or(0);
    assert_eq!(cold + warm, ttft, "every TTFT lands in exactly one cache split");
    assert_eq!(warm, 0, "no cache configured, so no warm hits");
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}
