//! Bit-exact equivalence for the layerwise block prefetcher: under
//! `LoadStrategy::Layerwise`, serving runs with prefetch on/off ×
//! `threads ∈ {1, 2, 8}` must produce IDENTICAL emitted token streams,
//! recurrent states, logits and per-round weight-byte accounting
//! (`round_weight_bytes`).
//!
//! The prefetcher only moves WHERE a block's bytes are decoded (a
//! background I/O worker instead of the round thread) and WHEN (during
//! the previous layer's compute instead of at the layer boundary) — never
//! what they decode to.  This test is the end-to-end enforcement of that
//! contract across dense and all-techniques (sparse-FFN + hier-head +
//! f16/low-rank) configs, on both the fused round path and the per-slot
//! `forward_token` path.
//!
//! Runs on synthetic checkpoints (testutil::synth) — no `make artifacts`
//! needed, so this is tier-1 coverage.

use std::path::PathBuf;

use rwkv_lite::config::{EngineConfig, LoadStrategy};
use rwkv_lite::engine::session::Session;
use rwkv_lite::engine::{state::RwkvState, RwkvEngine};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

const THREADS: [usize; 3] = [1, 2, 8];

fn synth_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rwkv-pfeq-{}-{}", tag, std::process::id()))
}

/// Everything one serving run produces that must not depend on the
/// prefetch knob (or threads).
#[derive(Debug, PartialEq)]
struct RunTrace {
    /// Emitted tokens per session, in emission order.
    emitted: Vec<Vec<u32>>,
    /// `round_weight_bytes` of every round, in order.
    round_bytes: Vec<u64>,
    /// Final logits of a standalone chunked prefill per prompt.
    logits: Vec<Vec<f32>>,
}

fn assert_states_identical(a: &RwkvState, b: &RwkvState, ctx: &str) {
    assert_eq!(a.att_x, b.att_x, "{ctx}: att_x state diverged");
    assert_eq!(a.wkv, b.wkv, "{ctx}: wkv state diverged");
    assert_eq!(a.ffn_x, b.ffn_x, "{ctx}: ffn_x state diverged");
}

/// Drive a mixed prefill/decode serving run + standalone prefills and
/// record everything observable, plus the `blocks_prefetched` counter.
fn run_with(
    cfg: &EngineConfig,
    prompts: &[Vec<u32>],
    threads: usize,
    prefetch: bool,
) -> (RunTrace, Vec<RwkvState>, u64) {
    let mut cfg = cfg.clone();
    cfg.strategy = LoadStrategy::Layerwise;
    cfg.threads = threads;
    cfg.prefetch = prefetch;
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let mut sessions: Vec<Session> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut s = Session::new(&engine, i as u64, p);
            s.max_tokens = 5; // greedy sampler is the Session default
            s
        })
        .collect();
    let mut emitted: Vec<Vec<u32>> = vec![Vec::new(); sessions.len()];
    let mut round_bytes = Vec::new();
    let mut rounds = 0;
    while sessions.iter().any(|s| !s.is_done()) {
        let report = engine.step_round(&mut sessions).expect("round");
        for e in &report.emitted {
            emitted[e.session].push(e.token);
        }
        round_bytes.push(report.round_weight_bytes);
        rounds += 1;
        assert!(rounds < 64, "round loop did not converge");
    }
    // standalone chunked prefill: logits must be bit-identical too
    let logits = prompts
        .iter()
        .map(|p| {
            let mut feed = vec![2u32]; // BOS
            feed.extend_from_slice(p);
            let mut st = engine.new_state();
            engine.forward_sequence(&feed, &mut st).expect("prefill")
        })
        .collect();
    let states = sessions.iter().map(|s| s.state().clone()).collect();
    (RunTrace { emitted, round_bytes, logits }, states, engine.metrics.counter("blocks_prefetched"))
}

/// The core check: prefetch on/off × every thread count yields the same
/// trace and states as the single-threaded non-prefetching reference.
fn check_prefetch_equivalence(tag: &str, spec: &SynthSpec, cfg_mut: impl Fn(&mut EngineConfig)) {
    let dir = synth_dir(tag);
    write_synth_rwkv(&dir, "m", spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.prefill_chunk = 3; // long prompts still prefill while short decode
    cfg_mut(&mut cfg);
    // mixed lengths: genuinely mixed prefill+decode rounds under chunk 3
    let prompts: Vec<Vec<u32>> = vec![
        (0..9).map(|i| ((11 + 5 * i) % spec.vocab) as u32).collect(),
        vec![7],
        vec![4, 40, 4, 44],
        (0..13).map(|i| ((3 + 17 * i) % spec.vocab) as u32).collect(),
    ];
    let (want, want_states, base_blocks) = run_with(&cfg, &prompts, THREADS[0], false);
    assert!(want.round_bytes.iter().any(|&b| b > 0), "{tag}: rounds stream weight bytes");
    assert_eq!(base_blocks, 0, "{tag}: prefetch off must never count prefetched blocks");
    for &threads in &THREADS {
        for &prefetch in &[false, true] {
            if threads == THREADS[0] && !prefetch {
                continue; // the reference itself
            }
            let ctx = format!("{tag} threads={threads} prefetch={prefetch}");
            let (got, got_states, blocks) = run_with(&cfg, &prompts, threads, prefetch);
            assert_eq!(got.emitted, want.emitted, "{ctx}: emitted streams must be bit-identical");
            assert_eq!(
                got.round_bytes, want.round_bytes,
                "{ctx}: round_weight_bytes must not depend on prefetch/threads"
            );
            assert_eq!(got.logits, want.logits, "{ctx}: prefill logits must be bit-identical");
            for (i, (a, b)) in want_states.iter().zip(&got_states).enumerate() {
                assert_states_identical(a, b, &format!("{ctx} session {i}"));
            }
            if prefetch {
                assert!(blocks > 0, "{ctx}: the double buffer must actually serve blocks");
            } else {
                assert_eq!(blocks, 0, "{ctx}: prefetch off must stay synchronous");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefetch_equivalent_dense_f32() {
    let mut spec = SynthSpec::tiny();
    spec.layers = 3; // a real pipeline: N computes while N+1 streams
    spec.predictors = false;
    spec.hier_head = false;
    check_prefetch_equivalence("dense-f32", &spec, |_| {});
}

#[test]
fn prefetch_equivalent_all_techniques_f16_lowrank() {
    let mut spec = SynthSpec::tiny();
    spec.f16 = true;
    spec.lowrank = true;
    spec.seed = 0xBEEF;
    check_prefetch_equivalence("all-f16-lr", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
        c.emb_cache = true;
    });
}

/// The prefetching fused round must also match the SINGLE-SLOT sequential
/// per-token path (`forward_token` on a prefetching layerwise engine),
/// tying the prefetcher back to the per-slot reference the other
/// equivalence suites use — both entry points walk layers 0..L, so both
/// ride the same double buffer.
#[test]
fn prefetched_round_matches_sequential_reference() {
    let mut spec = SynthSpec::tiny();
    spec.layers = 3;
    let dir = synth_dir("seqref");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.strategy = LoadStrategy::Layerwise;
    cfg.sparse_ffn = true;
    let feed: Vec<u32> = vec![2, 9, 21, 3, 15, 40];
    // sequential per-token reference, prefetch off, single-threaded
    cfg.threads = 1;
    cfg.prefetch = false;
    let mut seq = RwkvEngine::load(cfg.clone()).unwrap();
    let mut st_ref = seq.new_state();
    for &t in &feed[..feed.len() - 1] {
        seq.forward_hidden(t, &mut st_ref).unwrap();
    }
    let want = seq.forward_token(feed[feed.len() - 1], &mut st_ref).unwrap();
    // per-token path on a PREFETCHING engine
    cfg.prefetch = true;
    let mut pf = RwkvEngine::load(cfg.clone()).unwrap();
    let mut st_pf = pf.new_state();
    for &t in &feed[..feed.len() - 1] {
        pf.forward_hidden(t, &mut st_pf).unwrap();
    }
    let got_tok = pf.forward_token(feed[feed.len() - 1], &mut st_pf).unwrap();
    assert_eq!(got_tok, want, "per-token path with prefetch == without");
    assert_states_identical(&st_ref, &st_pf, "seqref per-token");
    // fused chunked prefill on a prefetching 8-lane engine
    cfg.threads = 8;
    let mut fused = RwkvEngine::load(cfg).unwrap();
    let mut st = fused.new_state();
    let got = fused.forward_sequence(&feed, &mut st).unwrap();
    assert_eq!(got, want, "prefetched fused prefill == sequential per-token logits");
    assert_states_identical(&st_ref, &st, "seqref fused");
    std::fs::remove_dir_all(&dir).ok();
}
