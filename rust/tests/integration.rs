//! Integration tests over real artifacts (built by `make artifacts`).
//!
//! Tests skip (with a notice) when artifacts are absent so `cargo test`
//! stays meaningful on a fresh checkout; CI runs `make test` which builds
//! artifacts first.

use std::path::PathBuf;

use rwkv_lite::config::{Backend, EngineConfig, LoadStrategy};
use rwkv_lite::engine::sampler::Sampler;
use rwkv_lite::engine::weights::WeightStore;
use rwkv_lite::engine::RwkvEngine;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(model: &str) -> bool {
    artifacts().join("models").join(format!("{model}.json")).exists()
}

macro_rules! require {
    ($model:expr) => {
        if !have($model) {
            eprintln!("SKIP: {} not built (run `make artifacts`)", $model);
            return;
        }
    };
}

fn vanilla(model: &str) -> EngineConfig {
    EngineConfig::vanilla(model, artifacts())
}

fn ours(model: &str) -> EngineConfig {
    EngineConfig::all_techniques(model, artifacts())
}

fn greedy_tokens(mut engine: RwkvEngine, prompt: &[u32], n: usize) -> Vec<u32> {
    let mut sampler = Sampler::greedy();
    let mut state = engine.new_state();
    engine.generate(prompt, n, &mut sampler, &mut state).expect("generate")
}

const PROMPT: &[u32] = &[2, 200, 300, 5];

// ---------------------------------------------------------------------------
// Checkpoint / manifest contract
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_tensors_match_manifest_shapes() {
    require!("rwkv-vanilla-tiny");
    let store = WeightStore::open(
        &artifacts().join("models").join("rwkv-vanilla-tiny.json"),
    )
    .unwrap();
    let m = &store.manifest;
    let emb = store.rkv.entry("emb").unwrap();
    assert_eq!(emb.shape, vec![m.vocab, m.dim]);
    let head = store.rkv.entry("head").unwrap();
    assert_eq!(head.shape, vec![m.vocab, m.dim], "head stored transposed");
    let wk_t = store.rkv.entry("b0.ffn.wk_t").unwrap();
    assert_eq!(wk_t.shape, vec![m.ffn_dim, m.dim]);
    let wkv_decay = store.rkv.entry("b0.att.decay").unwrap();
    assert_eq!(wkv_decay.numel(), m.dim);
    // decay precomputed into (0,1)
    let decay = store.rkv.vec_f32("b0.att.decay").unwrap();
    assert!(decay.iter().all(|&w| w > 0.0 && w < 1.0));
}

#[test]
fn ours_checkpoint_has_lowrank_and_attachments() {
    require!("rwkv-ours-small");
    let store = WeightStore::open(
        &artifacts().join("models").join("rwkv-ours-small.json"),
    )
    .unwrap();
    assert!(store.rkv.has("b0.att.wr.l") && store.rkv.has("b0.att.wr.r"));
    assert!(!store.rkv.has("b0.att.wr.w"));
    assert!(store.rkv.has("b0.att.wo.w"), "wo must stay dense (paper §3.1)");
    assert!(store.rkv.has("b0.pred.l1") && store.rkv.has("b0.pred.sign"));
    assert!(store.rkv.has("hh.h1") && store.rkv.has("hh.assign"));
    let h1 = store.rkv.entry("hh.h1").unwrap();
    assert_eq!(h1.shape[1], store.manifest.dim, "h1 stored (C, D)");
}

// ---------------------------------------------------------------------------
// Engine correctness
// ---------------------------------------------------------------------------

#[test]
fn full_and_layerwise_agree_exactly() {
    require!("rwkv-vanilla-tiny");
    let a = greedy_tokens(RwkvEngine::load(vanilla("rwkv-vanilla-tiny")).unwrap(), PROMPT, 16);
    let mut cfg = vanilla("rwkv-vanilla-tiny");
    cfg.strategy = LoadStrategy::Layerwise;
    let b = greedy_tokens(RwkvEngine::load(cfg).unwrap(), PROMPT, 16);
    assert_eq!(a, b, "loading strategy must not change the math");
}

#[test]
fn native_and_xla_backends_agree() {
    require!("rwkv-vanilla-tiny");
    let a = greedy_tokens(RwkvEngine::load(vanilla("rwkv-vanilla-tiny")).unwrap(), PROMPT, 12);
    let mut cfg = vanilla("rwkv-vanilla-tiny");
    cfg.backend = Backend::Xla;
    let b = greedy_tokens(RwkvEngine::load(cfg).unwrap(), PROMPT, 12);
    assert_eq!(a, b, "AOT HLO components must match native kernels");
}

#[test]
fn state_carries_context() {
    require!("rwkv-vanilla-tiny");
    let mut engine = RwkvEngine::load(vanilla("rwkv-vanilla-tiny")).unwrap();
    let mut s1 = engine.new_state();
    let mut s2 = engine.new_state();
    // different contexts -> different logits for the same next token
    for &t in &[2u32, 100, 101] {
        engine.forward_hidden(t, &mut s1).unwrap();
    }
    for &t in &[2u32, 400, 401] {
        engine.forward_hidden(t, &mut s2).unwrap();
    }
    let l1 = engine.forward_token(5, &mut s1).unwrap();
    let l2 = engine.forward_token(5, &mut s2).unwrap();
    let diff: f32 = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "state must influence logits (diff={diff})");
}

#[test]
fn sparse_ffn_close_to_dense() {
    require!("rwkv-ours-small");
    // dense (no sparse) vs sparse runtime on the same checkpoint: greedy
    // continuations may diverge eventually but the first token's logits
    // should be highly correlated.
    let mut dense_cfg = ours("rwkv-ours-small");
    dense_cfg.sparse_ffn = false;
    dense_cfg.hier_head = false;
    dense_cfg.emb_cache = false;
    let mut dense = RwkvEngine::load(dense_cfg).unwrap();
    let mut cfg = ours("rwkv-ours-small");
    cfg.hier_head = false;
    cfg.emb_cache = false;
    let mut sparse = RwkvEngine::load(cfg).unwrap();
    let mut sd = dense.new_state();
    let mut ss = sparse.new_state();
    for &t in PROMPT {
        dense.forward_hidden(t, &mut sd).unwrap();
        sparse.forward_hidden(t, &mut ss).unwrap();
    }
    let ld = dense.forward_token(7, &mut sd).unwrap();
    let ls = sparse.forward_token(7, &mut ss).unwrap();
    // top-1 should survive sparsification on a trained model
    assert_eq!(rwkv_lite::util::argmax(&ld), rwkv_lite::util::argmax(&ls));
}

#[test]
fn hier_head_top1_agrees_with_dense_head() {
    require!("rwkv-ours-small");
    let mut cfg = ours("rwkv-ours-small");
    cfg.sparse_ffn = false;
    cfg.emb_cache = false;
    cfg.hier_head = false;
    let mut dense = RwkvEngine::load(cfg).unwrap();
    let mut cfg = ours("rwkv-ours-small");
    cfg.sparse_ffn = false;
    cfg.emb_cache = false;
    let mut hh = RwkvEngine::load(cfg).unwrap();
    let mut agree = 0;
    let mut total = 0;
    let mut sd = dense.new_state();
    let mut sh = hh.new_state();
    let mut last = 2u32;
    for step in 0..24u32 {
        let ld = dense.forward_token(last, &mut sd).unwrap();
        let lh = hh.forward_token(last, &mut sh).unwrap();
        let top_dense = rwkv_lite::util::argmax(&ld);
        if top_dense == rwkv_lite::util::argmax(&lh) {
            agree += 1;
        }
        total += 1;
        last = top_dense as u32 + (step % 3); // wander a little
        if last as usize >= dense.info.vocab {
            last = 5;
        }
    }
    assert!(
        agree * 10 >= total * 7,
        "hier head top-1 agreement too low: {agree}/{total}"
    );
}

#[test]
fn pseudo_logits_keep_distribution_finite() {
    require!("rwkv-ours-small");
    let mut cfg = ours("rwkv-ours-small");
    cfg.sparse_ffn = false;
    cfg.emb_cache = false;
    let mut engine = RwkvEngine::load(cfg).unwrap();
    let mut state = engine.new_state();
    let logits = engine.forward_token(5, &mut state).unwrap();
    assert!(logits.iter().all(|l| l.is_finite()), "no -inf pseudo logits");
    // softmax must be a proper distribution
    let mut p = logits.clone();
    rwkv_lite::util::softmax_inplace(&mut p);
    let sum: f32 = p.iter().sum();
    assert!((sum - 1.0).abs() < 1e-3);
}

// ---------------------------------------------------------------------------
// Memory accounting
// ---------------------------------------------------------------------------

#[test]
fn layerwise_peak_below_full_peak() {
    require!("rwkv-vanilla-small");
    let full = RwkvEngine::load(vanilla("rwkv-vanilla-small")).unwrap();
    let (_, full_peak) = {
        let mut e = full;
        let mut s = e.new_state();
        let mut smp = Sampler::greedy();
        e.generate(PROMPT, 8, &mut smp, &mut s).unwrap();
        e.memory_report()
    };
    let mut cfg = vanilla("rwkv-vanilla-small");
    cfg.strategy = LoadStrategy::Layerwise;
    // single-block residency is the §5.1 claim; the serving default
    // (prefetch on) double-buffers and is measured separately below
    cfg.prefetch = false;
    let mut e = RwkvEngine::load(cfg.clone()).unwrap();
    let mut s = e.new_state();
    let mut smp = Sampler::greedy();
    e.generate(PROMPT, 8, &mut smp, &mut s).unwrap();
    let (_, lw_peak) = e.memory_report();
    assert!(
        lw_peak * 2 < full_peak,
        "layerwise {lw_peak} should be well under full {full_peak}"
    );
    // double-buffered prefetch: at most ~one extra block resident — the
    // peak stays within 2x single-block streaming and well under full
    cfg.prefetch = true;
    let mut e = RwkvEngine::load(cfg).unwrap();
    let mut s = e.new_state();
    let mut smp = Sampler::greedy();
    e.generate(PROMPT, 8, &mut smp, &mut s).unwrap();
    let (_, pf_peak) = e.memory_report();
    assert!(
        pf_peak <= lw_peak * 2,
        "prefetch peak {pf_peak} must stay within 2x the single-block peak {lw_peak}"
    );
    assert!(
        pf_peak < full_peak,
        "prefetch peak {pf_peak} should stay under full {full_peak}"
    );
}

#[test]
fn techniques_reduce_peak_memory() {
    require!("rwkv-ours-small");
    require!("rwkv-vanilla-small");
    let run = |cfg: EngineConfig| {
        let mut e = RwkvEngine::load(cfg).unwrap();
        let mut s = e.new_state();
        let mut smp = Sampler::new(0.8, 0.95, 1);
        e.generate(PROMPT, 32, &mut smp, &mut s).unwrap();
        e.memory_report().1
    };
    let vanilla_peak = run(vanilla("rwkv-vanilla-small"));
    let ours_peak = run(ours("rwkv-ours-small"));
    assert!(
        (ours_peak as f64) < 0.5 * vanilla_peak as f64,
        "ours {ours_peak} vs vanilla {vanilla_peak}: expected >=2x reduction"
    );
}

#[test]
fn emb_cache_bounded_and_hit() {
    require!("rwkv-ours-small");
    let mut cfg = ours("rwkv-ours-small");
    cfg.emb_cache_capacity = 8;
    let mut e = RwkvEngine::load(cfg).unwrap();
    let mut s = e.new_state();
    let mut smp = Sampler::new(1.0, 0.9, 2);
    e.generate(PROMPT, 64, &mut smp, &mut s).unwrap();
    let cache = e.emb_cache.as_ref().unwrap();
    assert!(cache.len() <= 8, "capacity respected");
    assert!(cache.hits > 0, "Zipfian stream must produce hits");
}

#[test]
fn int8_checkpoint_half_the_bytes() {
    require!("rwkv-vanilla-small");
    require!("rwkv-vanilla-small-int8");
    let f16 = WeightStore::open(&artifacts().join("models/rwkv-vanilla-small.json")).unwrap();
    let i8 = WeightStore::open(&artifacts().join("models/rwkv-vanilla-small-int8.json")).unwrap();
    let r = f16.rkv.total_bytes() as f64 / i8.rkv.total_bytes() as f64;
    assert!(r > 1.6 && r < 2.4, "f16/int8 byte ratio {r}");
}

#[test]
fn int8_accuracy_close_to_f16() {
    // Token-level greedy identity is NOT expected (group-norm over the
    // near-zero initial state amplifies quantization noise — the paper
    // reports the same INT8 fragility, §B.6); task accuracy is the right
    // equivalence.
    require!("rwkv-vanilla-small");
    require!("rwkv-vanilla-small-int8");
    let tasks = rwkv_lite::evalsuite::load_tasks(&artifacts().join("data/tasks.json")).unwrap();
    let task = &tasks["lambada_syn"];
    let mut f16 = RwkvEngine::load(vanilla("rwkv-vanilla-small")).unwrap();
    let r16 = rwkv_lite::evalsuite::eval_task(&mut f16, task, 40).unwrap();
    let mut i8e = RwkvEngine::load(vanilla("rwkv-vanilla-small-int8")).unwrap();
    let r8 = rwkv_lite::evalsuite::eval_task(&mut i8e, task, 40).unwrap();
    assert!(
        (r16.acc - r8.acc).abs() <= 0.15,
        "acc f16 {} vs int8 {}",
        r16.acc,
        r8.acc
    );
    assert!(r8.ppl < r16.ppl * 3.0, "ppl f16 {} vs int8 {}", r16.ppl, r8.ppl);
}

#[test]
fn batched_decode_matches_sequential_exactly() {
    require!("rwkv-ours-small");
    let mut cfg = ours("rwkv-ours-small");
    cfg.emb_cache = false; // cache order differs between paths; isolate math
    let mut engine = RwkvEngine::load(cfg.clone()).unwrap();
    // two slots with different contexts
    let ctxs: [&[u32]; 3] = [&[2, 10, 11], &[2, 400, 401, 402], &[2, 7]];
    let mut seq_states: Vec<_> = ctxs.iter().map(|_| engine.new_state()).collect();
    for (ctx, st) in ctxs.iter().zip(seq_states.iter_mut()) {
        for &t in *ctx {
            engine.forward_hidden(t, st).unwrap();
        }
    }
    let mut batch_states = seq_states.clone();
    // sequential logits
    let toks = [5u32, 6, 7];
    let mut seq_logits = Vec::new();
    for (i, st) in seq_states.iter_mut().enumerate() {
        seq_logits.push(engine.forward_token(toks[i], st).unwrap());
    }
    // batched logits on a FRESH engine (predictor telemetry state differs
    // but outputs must not)
    let mut engine2 = RwkvEngine::load(cfg).unwrap();
    let batch_logits = engine2
        .forward_tokens_batch(&toks, &mut batch_states)
        .unwrap();
    for (a, b) in seq_logits.iter().zip(&batch_logits) {
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-5, "batched decode must equal sequential");
        }
    }
    // union accounting happened
    assert!(engine2.metrics.counter("batch_union_rows") > 0);
    assert!(
        engine2.metrics.counter("batch_union_rows")
            <= engine2.metrics.counter("batch_individual_rows"),
        "union cannot exceed the sum of individual row sets"
    );
}

#[test]
fn quant4_predictor_mode_runs() {
    require!("rwkv-ours-small");
    let mut cfg = ours("rwkv-ours-small");
    cfg.hier_head = false;
    let mut engine = RwkvEngine::load(cfg).unwrap();
    if engine
        .set_pred_mode(rwkv_lite::engine::sparse_ffn::PredMode::Quant4Only)
        .is_err()
    {
        eprintln!("SKIP: checkpoint predates 4-bit shadows");
        return;
    }
    let mut state = engine.new_state();
    let logits = engine.forward_token(5, &mut state).unwrap();
    assert!(logits.iter().all(|l| l.is_finite()));
    // 4-bit keeps roughly the (1 - t_quant) fraction
    let spars = engine.sparsity_by_layer();
    assert!(spars.iter().all(|&s| s > 0.5), "sparsity {spars:?}");
}

// ---------------------------------------------------------------------------
// Eval plumbing
// ---------------------------------------------------------------------------

#[test]
fn evalsuite_runs_on_tasks() {
    require!("rwkv-vanilla-small");
    let tasks = rwkv_lite::evalsuite::load_tasks(&artifacts().join("data/tasks.json")).unwrap();
    assert!(tasks.contains_key("lambada_syn"));
    let mut e = RwkvEngine::load(vanilla("rwkv-vanilla-small")).unwrap();
    let r = rwkv_lite::evalsuite::eval_task(&mut e, &tasks["lambada_syn"], 10).unwrap();
    assert_eq!(r.n, 10);
    assert!(r.ppl.is_finite() && r.ppl > 1.0);
    // a trained model should beat uniform-chance perplexity by far
    assert!(r.ppl < 512.0, "ppl {}", r.ppl);
}
