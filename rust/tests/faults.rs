//! Fault-injection suite: deterministic engine-round failures, client
//! disconnect races widened by slow rounds, and damaged-statefile
//! recovery — all on synthetic checkpoints (tier-1).
//!
//! Each test re-asserts the accounting invariant:
//! `requests_admitted == requests_completed + requests_cancelled +
//! requests_deadline_exceeded` (rejections are counted separately).

use std::path::PathBuf;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::coordinator::{
    batcher::BatchPolicy, AdmissionPolicy, Coordinator, CoordinatorConfig, Event, FinishReason,
    Request,
};
use rwkv_lite::engine::state_cache::{CacheConfig, StateCache};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::testutil::faults::{corrupt_byte, truncate_file, FaultPlan};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

fn synth_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rwkv-faults-{}-{}", tag, std::process::id()));
    write_synth_rwkv(&dir, "m", &SynthSpec::tiny()).expect("write synth model");
    dir
}

fn engine_cfg(dir: &PathBuf) -> EngineConfig {
    let spec = SynthSpec::tiny();
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    cfg
}

fn faulty_coordinator(dir: &PathBuf, faults: FaultPlan) -> Coordinator {
    faulty_coordinator_window(dir, faults, 1)
}

fn faulty_coordinator_window(dir: &PathBuf, faults: FaultPlan, window_ms: u64) -> Coordinator {
    let cfg = engine_cfg(dir);
    Coordinator::spawn_cfg(
        move || RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, window_ms },
            faults: Some(faults),
            ..CoordinatorConfig::default()
        },
    )
}

fn assert_accounting(c: &Coordinator) {
    let admitted = c.metrics.counter("requests_admitted");
    let terminated = c.metrics.counter("requests_completed")
        + c.metrics.counter("requests_cancelled")
        + c.metrics.counter("requests_deadline_exceeded");
    assert_eq!(admitted, terminated, "admitted={admitted} terminated={terminated}");
}

/// An injected round error is engine-global: EVERY in-flight stream gets
/// `Error` followed by a terminal `Done` (reason: cancelled) carrying the
/// final counts — and the coordinator keeps serving afterwards.
#[test]
fn injected_round_error_terminates_all_streams_then_recovers() {
    let dir = synth_dir("round-error");
    // round 0 fails; a generous batching window guarantees BOTH
    // back-to-back submissions are admitted into it together
    let c = faulty_coordinator_window(
        &dir,
        FaultPlan::new().fail_round(0).with_message("injected: io"),
        250,
    );
    let handles: Vec<_> = (0..2u64)
        .map(|i| {
            c.submit(Request {
                id: i,
                prompt: vec![2, 5 + i as u32],
                max_tokens: 4,
                ..Request::default()
            })
        })
        .collect();
    for h in handles {
        let mut saw_error = None;
        let mut saw_done = None;
        for ev in h {
            match ev {
                Event::Error { message } => saw_error = Some(message),
                Event::Done { tokens, reason, .. } => {
                    saw_done = Some((tokens, reason));
                    break;
                }
                Event::Token { .. } => {}
                Event::Rejected { reason, .. } => {
                    panic!("unexpected rejection: {}", reason.wire_name())
                }
            }
        }
        assert_eq!(saw_error.as_deref(), Some("injected: io"));
        let (tokens, reason) = saw_done.expect("error must be followed by a terminal Done");
        assert_eq!(reason, FinishReason::Cancelled);
        assert_eq!(tokens, 0, "round 0 failed before any token was produced");
    }
    assert_eq!(c.metrics.counter("requests_cancelled"), 2);
    assert_accounting(&c);
    // the loop survived the bad round: a fresh request completes
    let fresh = Request { id: 9, prompt: vec![2, 7], max_tokens: 3, ..Request::default() };
    let out = c.generate_blocking(fresh).unwrap();
    assert!(!out.is_empty());
    assert_eq!(c.metrics.counter("requests_completed"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Cancellation during an artificially slow prefill round lands at the
/// round boundary: terminal Done, zero tokens, no double-retirement.
#[test]
fn cancel_during_slow_prefill_round() {
    let dir = synth_dir("cancel-slow");
    let c = faulty_coordinator(&dir, FaultPlan::new().slow_rounds_from(0, 10_000, 30));
    let h = c.submit(Request {
        id: 1,
        prompt: (0..60).map(|i| 4 + i % 32).collect(),
        max_tokens: 100,
        ..Request::default()
    });
    // the 60-token prompt needs many 30ms rounds; cancel mid-prefill
    std::thread::sleep(std::time::Duration::from_millis(50));
    h.cancel();
    let mut tokens_seen = 0usize;
    let mut reason = None;
    for ev in &h {
        match ev {
            Event::Token { .. } => tokens_seen += 1,
            Event::Done { reason: r, .. } => {
                reason = Some(r);
                break;
            }
            other => panic!("unexpected event: {other:?}"),
        }
    }
    assert_eq!(reason, Some(FinishReason::Cancelled));
    assert_eq!(tokens_seen, 0, "cancelled during prefill: no tokens streamed");
    assert_eq!(c.metrics.counter("requests_cancelled"), 1);
    assert_eq!(c.metrics.counter("requests_completed"), 0);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// A client that disconnects mid-stream (handle dropped) is detected on
/// the next emitted token and retired as cancelled — counted exactly once.
#[test]
fn disconnect_mid_stream_is_cancelled_once() {
    let dir = synth_dir("disconnect");
    let c = faulty_coordinator(&dir, FaultPlan::new().slow_rounds_from(0, 10_000, 15));
    let h = c.submit(Request {
        id: 1,
        prompt: (0..40).map(|i| 4 + i % 32).collect(),
        max_tokens: 100_000,
        ..Request::default()
    });
    // walk away while the request is still being served
    std::thread::sleep(std::time::Duration::from_millis(40));
    drop(h);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while c.metrics.counter("requests_cancelled") == 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "coordinator never retired the orphaned session"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    // settle a few more rounds: the retirement must not double-count
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert_eq!(c.metrics.counter("requests_cancelled"), 1);
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}

/// Statefile cache round-trip under damage: a truncated or bit-flipped
/// statefile is reported and IGNORED (cold start), never fatal — and a
/// healthy restart still warm-starts from disk.
#[test]
fn damaged_statefile_recovers_cold() {
    let dir = synth_dir("statefile");
    let state_path = dir.join("cache.rwst");
    let prompt: Vec<u32> = (0..24).map(|i| (4 + 3 * i) % 90).collect();
    let spawn = |path: PathBuf| {
        let cfg = engine_cfg(&dir);
        Coordinator::spawn_cfg(
            move || RwkvEngine::load(cfg),
            CoordinatorConfig {
                policy: BatchPolicy { max_batch: 2, window_ms: 1 },
                admission: AdmissionPolicy::default(),
                cache: Some(StateCache::new(CacheConfig::with_mb(16))),
                state_file: Some(path),
                faults: None,
            },
        )
    };
    let run = |c: &Coordinator, id: u64| {
        let h = c.submit(Request {
            id,
            prompt: prompt.clone(),
            max_tokens: 2,
            seed: Some(7),
            ..Request::default()
        });
        let mut cached = usize::MAX;
        for ev in h {
            match ev {
                Event::Done { cached_tokens, .. } => {
                    cached = cached_tokens;
                    break;
                }
                Event::Error { message } => panic!("{message}"),
                _ => {}
            }
        }
        cached
    };
    // 1) seed the statefile
    let c = spawn(state_path.clone());
    run(&c, 1);
    drop(c); // saves on shutdown
    assert!(state_path.exists());
    let healthy = std::fs::read(&state_path).unwrap();
    assert!(healthy.len() > 16);

    // 2) healthy restart warm-starts (sanity for the damage cases below)
    let c = spawn(state_path.clone());
    assert!(run(&c, 2) > 0, "healthy statefile must warm-start the cache");
    drop(c);

    // 3) truncated file (crash mid-write): cold start, no crash
    std::fs::write(&state_path, &healthy).unwrap();
    truncate_file(&state_path, (healthy.len() / 2) as u64).unwrap();
    let c = spawn(state_path.clone());
    assert_eq!(run(&c, 3), 0, "truncated statefile must be ignored (cold start)");
    assert_accounting(&c);
    drop(c);

    // 4) silent single-byte corruption: cold start, no crash
    std::fs::write(&state_path, &healthy).unwrap();
    corrupt_byte(&state_path, (healthy.len() / 3) as u64).unwrap();
    let c = spawn(state_path.clone());
    assert_eq!(run(&c, 4), 0, "corrupt statefile must be ignored (cold start)");
    assert_accounting(&c);
    drop(c);
    std::fs::remove_dir_all(&dir).ok();
}
