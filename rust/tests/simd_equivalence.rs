//! SIMD dispatch equivalence: every kernel table the host can run must be
//! BITWISE identical to the scalar reference table, across every kernel
//! arm, every dtype, ragged shapes (tails shorter than the 8-lane chunk,
//! windows straddling the 32-wide Q4 group), and misaligned window starts
//! (`c0` offsets).  This is the invariant that makes `--simd` a pure
//! throughput knob: forcing any backend can never change model output.
//!
//! On x86_64 CI this exercises scalar-vs-AVX2; under `qemu-aarch64` (the
//! cross-build CI job) it exercises scalar-vs-NEON.  On a host with
//! neither, every case degenerates to scalar-vs-nothing and the forced
//! `select` error paths still run.

use rwkv_lite::tensor::{simd, Mat, SimdBackend};
use rwkv_lite::testutil::{check, ensure, Gen};
use rwkv_lite::util::f32_to_f16;

/// Every non-scalar table this host can run, alongside the reference.
fn host_tables() -> (&'static simd::Kernels, Vec<&'static simd::Kernels>) {
    let scalar = simd::kernels_for(SimdBackend::Scalar).expect("scalar is always available");
    let simds = [SimdBackend::Neon, SimdBackend::Avx2]
        .into_iter()
        .filter_map(simd::kernels_for)
        .collect();
    (scalar, simds)
}

/// Pull the packed bytes + f16 group scales out of a 1-row quantized Mat.
fn q4_row(cols: usize, data: &[f32]) -> (Vec<u8>, Vec<u16>) {
    match Mat::quantize_q4_mat(1, cols, data) {
        Mat::Q4 { data, scale, .. } => (data, scale),
        _ => unreachable!(),
    }
}

fn q4_1_row(cols: usize, data: &[f32]) -> (Vec<u8>, Vec<u16>, Vec<u16>) {
    match Mat::quantize_q4_1_mat(1, cols, data) {
        Mat::Q41 { data, scale, min, .. } => (data, scale, min),
        _ => unreachable!(),
    }
}

/// Shape sweep: everything the 8-lane chunking can get wrong — empty,
/// below one chunk, exactly one chunk, chunk+tail, straddling the Q4
/// group width (32), multiple groups with a ragged final group.
const SIZES: &[usize] = &[0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 40, 63, 64, 65, 96, 100];

#[test]
fn dots_bitwise_match_scalar_across_backends() {
    let (scalar, simds) = host_tables();
    check("simd dots == scalar", 40, |g: &mut Gen| {
        for &n in SIZES {
            let w = g.vec_normal(n.max(1))[..n].to_vec();
            let x = g.vec_normal(n.max(1))[..n].to_vec();
            let w16: Vec<u16> = w.iter().map(|&v| f32_to_f16(v)).collect();
            let w8: Vec<i8> = w.iter().map(|&v| (v * 30.0).clamp(-127.0, 127.0) as i8).collect();
            for k in &simds {
                let b = k.backend.name();
                ensure(
                    (k.dot_f32)(&w, &x).to_bits() == (scalar.dot_f32)(&w, &x).to_bits(),
                    &format!("dot_f32 {b} n={n}"),
                )?;
                ensure(
                    (k.dot_f16)(&w16, &x).to_bits() == (scalar.dot_f16)(&w16, &x).to_bits(),
                    &format!("dot_f16 {b} n={n}"),
                )?;
                ensure(
                    (k.dot_i8)(&w8, &x).to_bits() == (scalar.dot_i8)(&w8, &x).to_bits(),
                    &format!("dot_i8 {b} n={n}"),
                )?;
            }
            if n == 0 {
                continue; // quantizer requires at least one column
            }
            let (p4, s4) = q4_row(n, &w);
            let (p41, s41, m41) = q4_1_row(n, &w);
            for k in &simds {
                let b = k.backend.name();
                ensure(
                    (k.dot_q4)(&p4, &s4, &x).to_bits() == (scalar.dot_q4)(&p4, &s4, &x).to_bits(),
                    &format!("dot_q4 {b} n={n}"),
                )?;
                ensure(
                    (k.dot_q4_1)(&p41, &s41, &m41, &x).to_bits()
                        == (scalar.dot_q4_1)(&p41, &s41, &m41, &x).to_bits(),
                    &format!("dot_q4_1 {b} n={n}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn widens_bitwise_match_scalar_across_backends() {
    let (scalar, simds) = host_tables();
    check("simd widens == scalar", 40, |g: &mut Gen| {
        for &n in SIZES {
            if n == 0 {
                continue;
            }
            let w = g.vec_normal(n);
            let w16: Vec<u16> = w.iter().map(|&v| f32_to_f16(v)).collect();
            let (p4, s4) = q4_row(n, &w);
            let (p41, s41, m41) = q4_1_row(n, &w);
            for k in &simds {
                let b = k.backend.name();
                let mut got = vec![0.0f32; n];
                let mut want = vec![0.0f32; n];
                (k.widen_f16)(&w16, &mut got);
                (scalar.widen_f16)(&w16, &mut want);
                ensure(got == want, &format!("widen_f16 {b} n={n}"))?;
                // window starts that are 8-misaligned and group-straddling
                for c0 in [0usize, 1, 5, 8, 31, 33] {
                    if c0 >= n {
                        continue;
                    }
                    let len = g.usize_in(1, n - c0 + 1);
                    let mut got = vec![0.0f32; len];
                    let mut want = vec![0.0f32; len];
                    (k.widen_q4)(&p4, &s4, c0, &mut got);
                    (scalar.widen_q4)(&p4, &s4, c0, &mut want);
                    ensure(got == want, &format!("widen_q4 {b} n={n} c0={c0}"))?;
                    let mut got = vec![0.0f32; len];
                    let mut want = vec![0.0f32; len];
                    (k.widen_q4_1)(&p41, &s41, &m41, c0, &mut got);
                    (scalar.widen_q4_1)(&p41, &s41, &m41, c0, &mut want);
                    ensure(got == want, &format!("widen_q4_1 {b} n={n} c0={c0}"))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn axpys_bitwise_match_scalar_across_backends() {
    let (scalar, simds) = host_tables();
    check("simd axpys == scalar", 40, |g: &mut Gen| {
        for &n in SIZES {
            if n == 0 {
                continue;
            }
            let w = g.vec_normal(n);
            let residual = g.vec_normal(n);
            let a = g.f32_in(-2.0, 2.0);
            let w16: Vec<u16> = w.iter().map(|&v| f32_to_f16(v)).collect();
            let w8: Vec<i8> = w.iter().map(|&v| (v * 30.0).clamp(-127.0, 127.0) as i8).collect();
            let (p4, s4) = q4_row(n, &w);
            let (p41, s41, m41) = q4_1_row(n, &w);
            for k in &simds {
                let b = k.backend.name();
                let mut got = residual.clone();
                let mut want = residual.clone();
                (k.axpy_f32)(a, &w, &mut got);
                (scalar.axpy_f32)(a, &w, &mut want);
                ensure(got == want, &format!("axpy_f32 {b} n={n}"))?;
                let mut got = residual.clone();
                let mut want = residual.clone();
                (k.axpy_f16)(a, &w16, &mut got);
                (scalar.axpy_f16)(a, &w16, &mut want);
                ensure(got == want, &format!("axpy_f16 {b} n={n}"))?;
                let mut got = residual.clone();
                let mut want = residual.clone();
                (k.axpy_i8)(a, &w8, &mut got);
                (scalar.axpy_i8)(a, &w8, &mut want);
                ensure(got == want, &format!("axpy_i8 {b} n={n}"))?;
                for c0 in [0usize, 1, 5, 8, 31, 33] {
                    if c0 >= n {
                        continue;
                    }
                    let len = n - c0;
                    let mut got = residual[..len].to_vec();
                    let mut want = residual[..len].to_vec();
                    (k.axpy_q4)(a, &p4, &s4, c0, &mut got);
                    (scalar.axpy_q4)(a, &p4, &s4, c0, &mut want);
                    ensure(got == want, &format!("axpy_q4 {b} n={n} c0={c0}"))?;
                    let mut got = residual[..len].to_vec();
                    let mut want = residual[..len].to_vec();
                    (k.axpy_q4_1)(a, &p41, &s41, &m41, c0, &mut got);
                    (scalar.axpy_q4_1)(a, &p41, &s41, &m41, c0, &mut want);
                    ensure(got == want, &format!("axpy_q4_1 {b} n={n} c0={c0}"))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn forced_backend_select_contract() {
    // scalar can always be forced; the auto pick is always installable
    assert_eq!(
        simd::select(Some(SimdBackend::Scalar)).unwrap(),
        SimdBackend::Scalar
    );
    assert_eq!(simd::active(), SimdBackend::Scalar);
    // NEON and AVX2 are mutually exclusive per arch: at least one of the
    // two must refuse to install on any host, without disturbing the
    // active selection
    let unavailable: Vec<SimdBackend> = [SimdBackend::Neon, SimdBackend::Avx2]
        .into_iter()
        .filter(|&b| !simd::available(b))
        .collect();
    assert!(!unavailable.is_empty(), "no host runs both NEON and AVX2");
    for b in unavailable {
        let err = simd::select(Some(b)).unwrap_err().to_string();
        assert!(err.contains("not available"), "got: {err}");
        assert_eq!(simd::active(), SimdBackend::Scalar, "failed select must not install");
    }
    // restore auto so test execution order never leaks a forced backend
    let auto = simd::select(None).unwrap();
    assert_eq!(auto, simd::detect());
    assert!(simd::kernels_for(auto).is_some());
}
