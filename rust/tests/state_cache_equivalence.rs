//! Prefix-state cache equivalence: a warm-cache request — one that forks
//! off a cached `RwkvState` snapshot and starts prefill mid-feed — must
//! be BIT-IDENTICAL (states, logits, emitted streams) to the same
//! request run cold, across dense / sparse-FFN / hier-head /
//! f16+low-rank / layerwise configs and thread counts {1, 8}, for exact
//! and partial prefix hits, and while eviction is shredding the cache
//! under byte pressure.  Also covers the `io::statefile` persistence
//! round trip.
//!
//! The acceptance invariant: a second request with an identical prompt
//! prefix performs ZERO prefill forward passes for the matched tokens —
//! asserted via the cache's `hit_tokens` AND the per-round
//! `prefill_tokens` telemetry (warm prefill == feed length − matched).
//!
//! Runs on synthetic checkpoints (testutil::synth) — tier-1 coverage, no
//! `make artifacts` needed.

use std::path::PathBuf;

use rwkv_lite::config::{EngineConfig, LoadStrategy};
use rwkv_lite::engine::session::Session;
use rwkv_lite::engine::state::RwkvState;
use rwkv_lite::engine::state_cache::{CacheConfig, StateCache};
use rwkv_lite::engine::RwkvEngine;
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

fn synth_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rwkv-stcache-{}-{}", tag, std::process::id()))
}

/// What one request observed, for bit-exact comparison.
struct RunResult {
    stream: Vec<u32>,
    /// Feed tokens served from the cache at session creation.
    cached: usize,
    /// Sum of `RoundReport::prefill_tokens` — the forward passes the
    /// prompt actually paid.
    prefill_tokens: usize,
    state: RwkvState,
}

/// Drive one greedy session to completion through `step_round_cached`.
fn run_one(
    engine: &mut RwkvEngine,
    mut cache: Option<&mut StateCache>,
    prompt: &[u32],
    n: usize,
) -> RunResult {
    let (mut sess, cached) = match cache.as_deref_mut() {
        Some(c) => Session::new_with_cache(engine, 0, prompt, c),
        None => (Session::new(engine, 0, prompt), 0),
    };
    sess.max_tokens = n;
    let mut stream = Vec::new();
    let mut prefill_tokens = 0usize;
    while !sess.is_done() {
        let report = engine
            .step_round_cached(std::slice::from_mut(&mut sess), cache.as_deref_mut())
            .expect("round");
        stream.extend(report.emitted.iter().map(|e| e.token));
        prefill_tokens += report.prefill_tokens;
    }
    RunResult { stream, cached, prefill_tokens, state: sess.state().clone() }
}

/// The chunk boundary the cache can serve for a feed of `feed_len`
/// tokens: snapshots land at prefill chunk boundaries, and the final
/// feed position is never matched (its logits must be computed).
fn expected_match(feed_len: usize, chunk: usize) -> usize {
    let cap = feed_len - 1;
    let (mut best, mut pos) = (0usize, 0usize);
    while pos < feed_len {
        pos += chunk.min(feed_len - pos);
        if pos <= cap {
            best = pos;
        }
    }
    best
}

/// Cold-vs-warm equivalence for one config, threads {1, 8}: identical
/// prompts (exact-prefix hit) and a shared-prefix prompt (partial hit).
fn check_cache(tag: &str, spec: &SynthSpec, cfg_mut: impl Fn(&mut EngineConfig)) {
    let dir = synth_dir(tag);
    write_synth_rwkv(&dir, "m", spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg_mut(&mut cfg);
    let n = 5usize;
    let chunk = cfg.prefill_chunk.max(1);
    let shared: Vec<u32> = (0..20).map(|i| ((5 + 7 * i) % spec.vocab) as u32).collect();
    let mut extended = shared.clone();
    extended.extend([9, 12, 3].map(|t| t % spec.vocab as u32));

    for &threads in &[1usize, 8] {
        let ctx = format!("{tag} threads={threads}");
        let mut c = cfg.clone();
        c.threads = threads;
        let mut engine = RwkvEngine::load(c).expect("load engine");

        // cold references (no cache anywhere)
        let cold = run_one(&mut engine, None, &shared, n);
        let cold_ext = run_one(&mut engine, None, &extended, n);
        let feed_len = shared.len() + 1; // [BOS, prompt...]
        assert_eq!(cold.prefill_tokens, feed_len, "{ctx}: cold prefill covers the feed");

        let mut cache = StateCache::new(CacheConfig { max_bytes: 64 << 20, min_prefix: 1 });

        // request 1: populates the cache, still bit-identical to cold
        let r1 = run_one(&mut engine, Some(&mut cache), &shared, n);
        assert_eq!(r1.cached, 0, "{ctx}: first request is a miss");
        assert_eq!(r1.stream, cold.stream, "{ctx}: populating run must match cold");
        assert!(r1.state.bitwise_eq(&cold.state), "{ctx}: populating-run state diverged");

        // request 2: identical prompt — exact-prefix warm hit
        let hit_tokens_before = cache.stats().hit_tokens;
        let r2 = run_one(&mut engine, Some(&mut cache), &shared, n);
        let want_match = expected_match(feed_len, chunk);
        assert!(want_match > 0, "{ctx}: test prompt too short to cache");
        assert_eq!(r2.cached, want_match, "{ctx}: deepest chunk-boundary snapshot matches");
        assert_eq!(
            cache.stats().hit_tokens - hit_tokens_before,
            want_match as u64,
            "{ctx}: cache_hit_tokens accounts the skipped feed tokens"
        );
        // ZERO prefill forward passes for the matched tokens
        assert_eq!(
            r2.prefill_tokens,
            feed_len - want_match,
            "{ctx}: warm prefill must only run the un-matched suffix"
        );
        assert_eq!(r2.stream, cold.stream, "{ctx}: warm stream must be bit-identical");
        assert!(r2.state.bitwise_eq(&cold.state), "{ctx}: warm final state diverged");

        // request 3: longer prompt sharing the prefix — partial hit.  The
        // full shared feed (a completed-prefill snapshot) is on its path.
        let r3 = run_one(&mut engine, Some(&mut cache), &extended, n);
        assert_eq!(r3.cached, feed_len, "{ctx}: partial hit forks off the full shared feed");
        assert_eq!(
            r3.prefill_tokens,
            extended.len() + 1 - feed_len,
            "{ctx}: partial-hit prefill covers only the new suffix"
        );
        assert_eq!(r3.stream, cold_ext.stream, "{ctx}: partial-hit stream must be bit-identical");
        assert!(r3.state.bitwise_eq(&cold_ext.state), "{ctx}: partial-hit state diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn warm_cache_equals_cold_dense_f32() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    check_cache("dense-f32", &spec, |_| {});
}

#[test]
fn warm_cache_equals_cold_sparse_ffn() {
    let spec = SynthSpec::tiny();
    check_cache("sparse", &spec, |c| {
        c.sparse_ffn = true;
    });
}

#[test]
fn warm_cache_equals_cold_hier_head() {
    let spec = SynthSpec::tiny();
    check_cache("hier", &spec, |c| {
        c.hier_head = true;
    });
}

#[test]
fn warm_cache_equals_cold_all_techniques_f16_lowrank() {
    let mut spec = SynthSpec::tiny();
    spec.f16 = true;
    spec.lowrank = true;
    spec.seed = 0xBEEF;
    check_cache("all-f16-lr", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
        c.emb_cache = true;
    });
}

#[test]
fn warm_cache_equals_cold_layerwise() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    spec.seed = 0xFACE;
    check_cache("layerwise", &spec, |c| {
        c.strategy = LoadStrategy::Layerwise;
    });
}

/// Odd prefill chunks put snapshots at non-multiple-of-8 boundaries; the
/// match math and bit-identity must hold there too.
#[test]
fn warm_cache_equals_cold_chunk_3() {
    let spec = SynthSpec::tiny();
    check_cache("chunk3", &spec, |c| {
        c.sparse_ffn = true;
        c.prefill_chunk = 3;
    });
}

/// Eviction under byte pressure: a budget of ~2 snapshots while several
/// prompts stream through.  Evictions must happen, the budget must hold,
/// evicted prefixes must miss — and every stream must stay bit-identical
/// to its cold reference (an evicted prefix only costs prefill, never
/// correctness).
#[test]
fn eviction_under_pressure_keeps_streams_identical() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("evict");
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = true;
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let n = 4usize;
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|p| (0..16).map(|i| ((3 + 5 * p + 11 * i) % spec.vocab) as u32).collect())
        .collect();
    let cold: Vec<_> = prompts.iter().map(|p| run_one(&mut engine, None, p, n)).collect();

    let state_bytes = engine.new_state().nbytes();
    let mut cache = StateCache::new(CacheConfig { max_bytes: 2 * state_bytes, min_prefix: 1 });
    for (p, c) in prompts.iter().zip(&cold) {
        let warm = run_one(&mut engine, Some(&mut cache), p, n);
        assert_eq!(warm.stream, c.stream, "stream under eviction pressure diverged");
        assert!(warm.state.bitwise_eq(&c.state), "state under eviction pressure diverged");
        assert!(cache.bytes() <= 2 * state_bytes, "byte budget violated");
        assert!(cache.snapshots() <= 2, "budget admits at most 2 snapshots");
    }
    assert!(cache.stats().evictions > 0, "pressure must actually evict");
    // the last prompt's snapshots are the most recent — still resident
    let warm_last = run_one(&mut engine, Some(&mut cache), &prompts[3], n);
    assert!(warm_last.cached > 0, "most recent prompt stays warm");
    assert_eq!(warm_last.stream, cold[3].stream);
    // the first prompt's snapshots were evicted long ago — cold again,
    // but still correct
    let re0 = run_one(&mut engine, Some(&mut cache), &prompts[0], n);
    assert_eq!(re0.stream, cold[0].stream);
    assert!(re0.state.bitwise_eq(&cold[0].state));
    std::fs::remove_dir_all(&dir).ok();
}

/// Opted-out sessions (`use_cache = false` / request `"cache": false`)
/// neither read nor populate the cache.
#[test]
fn opt_out_sessions_do_not_touch_the_cache() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("optout");
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let prompt: Vec<u32> = (0..12).map(|i| ((7 + 3 * i) % spec.vocab) as u32).collect();
    let mut cache = StateCache::new(CacheConfig { max_bytes: 64 << 20, min_prefix: 1 });
    let mut sess = Session::new(&engine, 0, &prompt);
    sess.max_tokens = 3;
    sess.use_cache = false;
    while !sess.is_done() {
        engine
            .step_round_cached(std::slice::from_mut(&mut sess), Some(&mut cache))
            .expect("round");
    }
    assert_eq!(cache.snapshots(), 0, "opted-out prefill must not insert snapshots");
    assert_eq!(cache.bytes(), 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// `io::statefile` persistence: snapshots harvested from real prefill
/// survive a save/load round trip bit-exactly, and a revived cache
/// serves warm requests identical to the original's.
#[test]
fn statefile_round_trip_revives_a_warm_cache() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("persist");
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let prompt: Vec<u32> = (0..18).map(|i| ((2 + 9 * i) % spec.vocab) as u32).collect();
    let n = 5usize;

    let cold = run_one(&mut engine, None, &prompt, n);
    let mut cache = StateCache::new(CacheConfig { max_bytes: 64 << 20, min_prefix: 1 });
    run_one(&mut engine, Some(&mut cache), &prompt, n);
    assert!(cache.snapshots() > 0);

    let path = dir.join("cache.rwst");
    let saved = cache.save(&path, "synth-m").expect("save statefile");
    assert_eq!(saved, cache.snapshots());

    // a fresh process: new cache, revived from disk
    let mut revived = StateCache::new(cache.config());
    assert_eq!(revived.load(&path).expect("load statefile"), saved);
    assert_eq!(revived.snapshots(), cache.snapshots());
    assert_eq!(revived.bytes(), cache.bytes());
    // the persisted snapshots are bit-identical to the live ones
    for ((pa, sa), (pb, sb)) in cache.entries().iter().zip(revived.entries().iter()) {
        assert_eq!(pa, pb, "persisted prefix order diverged");
        assert!(sa.bitwise_eq(sb.as_ref()), "persisted snapshot payload diverged");
        assert!(sa.approx_eq(sb.as_ref(), 0.0), "approx_eq(0) must agree with bitwise_eq");
    }
    // and a warm request through the revived cache matches cold exactly
    let warm = run_one(&mut engine, Some(&mut revived), &prompt, n);
    assert!(warm.cached > 0, "revived cache must hit");
    assert_eq!(warm.stream, cold.stream, "revived-cache stream must be bit-identical");
    assert!(warm.state.bitwise_eq(&cold.state));
    std::fs::remove_dir_all(&dir).ok();
}

/// Multi-session rounds: several sessions share one cache; mixed
/// warm/cold batches stay bit-identical to their solo cold runs.
#[test]
fn shared_cache_across_batched_sessions() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("batch");
    write_synth_rwkv(&dir, "m", &spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = true;
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let n = 4usize;
    let shared: Vec<u32> = (0..14).map(|i| ((6 + 5 * i) % spec.vocab) as u32).collect();
    let other: Vec<u32> = vec![3, 40, 17, 9];
    let cold_shared = run_one(&mut engine, None, &shared, n);
    let cold_other = run_one(&mut engine, None, &other, n);

    let mut cache = StateCache::new(CacheConfig { max_bytes: 64 << 20, min_prefix: 1 });
    // warm the shared prefix
    run_one(&mut engine, Some(&mut cache), &shared, n);
    // one warm + one cold session advance together in fused rounds
    let (mut s0, cached0) = Session::new_with_cache(&engine, 0, &shared, &mut cache);
    let (mut s1, cached1) = Session::new_with_cache(&engine, 1, &other, &mut cache);
    assert!(cached0 > 0, "shared prompt must be warm");
    assert_eq!(cached1, 0, "distinct prompt must be cold");
    s0.max_tokens = n;
    s1.max_tokens = n;
    let mut sessions = vec![s0, s1];
    let mut streams: Vec<Vec<u32>> = vec![Vec::new(), Vec::new()];
    while sessions.iter().any(|s| !s.is_done()) {
        let report = engine.step_round_cached(&mut sessions, Some(&mut cache)).expect("round");
        for e in &report.emitted {
            streams[e.session].push(e.token);
        }
    }
    assert_eq!(streams[0], cold_shared.stream, "warm batched session diverged");
    assert_eq!(streams[1], cold_other.stream, "cold batched session diverged");
    assert!(sessions[0].state().bitwise_eq(&cold_shared.state));
    assert!(sessions[1].state().bitwise_eq(&cold_other.state));
    std::fs::remove_dir_all(&dir).ok();
}
