//! Parser edge-case regressions (PR 7): hand-built malformed inputs for
//! every validation branch of the untrusted-input parsers.  Where the
//! fuzz smoke suite (`tests/fuzz_smoke.rs`) sprays random mutations,
//! this file pins the *specific* shapes of badness each parser must
//! reject — truncations, oversized length fields, u64 offset overflow,
//! shape/byte-count mismatches, checksum games, and JSON numeric/depth
//! extremes.  Every case must be `Err`, never a panic or an abort.

use rwkv_lite::engine::state::RwkvState;
use rwkv_lite::io::rkv::RkvFile;
use rwkv_lite::io::statefile::{
    read_statefile_bytes, statefile_bytes, statefile_checksum, STATEFILE_MAGIC,
    STATEFILE_VERSION,
};
use rwkv_lite::io::{rkv_bytes, RkvTensor};
use rwkv_lite::json;

// ---------------------------------------------------------------- rkv --

fn rkv_header(n: u32, data_offset: u64) -> Vec<u8> {
    let mut v = b"RKV1".to_vec();
    v.extend_from_slice(&1u32.to_le_bytes());
    v.extend_from_slice(&n.to_le_bytes());
    v.extend_from_slice(&data_offset.to_le_bytes());
    v
}

/// One index entry with every field caller-controlled (`ndim` is passed
/// separately from `dims` so it can lie).
fn rkv_entry(name: &[u8], dtype: u8, ndim: u8, dims: &[u32], offset: u64, nbytes: u64) -> Vec<u8> {
    let mut v = (name.len() as u16).to_le_bytes().to_vec();
    v.extend_from_slice(name);
    v.push(dtype);
    v.push(ndim);
    for &d in dims {
        v.extend_from_slice(&d.to_le_bytes());
    }
    v.extend_from_slice(&offset.to_le_bytes());
    v.extend_from_slice(&nbytes.to_le_bytes());
    v
}

/// Assemble header + entries + payload with a consistent `data_offset`.
fn rkv_image(entries: &[Vec<u8>], payload: &[u8]) -> Vec<u8> {
    let index_len: usize = entries.iter().map(|e| e.len()).sum();
    let mut v = rkv_header(entries.len() as u32, (20 + index_len) as u64);
    for e in entries {
        v.extend_from_slice(e);
    }
    v.extend_from_slice(payload);
    v
}

#[test]
fn rkv_hand_built_baseline_parses() {
    // sanity-check the builders themselves before trusting the Err cases
    let img = rkv_image(&[rkv_entry(b"t", 0, 1, &[2], 0, 8)], &[0u8; 8]);
    let f = RkvFile::open_bytes(&img).unwrap();
    assert_eq!(f.entry("t").unwrap().numel(), 2);
}

#[test]
fn rkv_every_truncation_of_valid_image_errors() {
    let full = rkv_bytes(&[
        RkvTensor::f32("emb", vec![4, 3], &[0.25; 12]),
        RkvTensor::f16_from_f32("w", vec![2, 2], &[1.0; 4]),
        RkvTensor::u8("q", vec![3], vec![1, 2, 3]),
    ]);
    assert!(RkvFile::open_bytes(&full).is_ok());
    for cut in 0..full.len() {
        assert!(
            RkvFile::open_bytes(&full[..cut]).is_err(),
            "prefix of {cut}/{} bytes parsed as a complete checkpoint",
            full.len()
        );
    }
}

#[test]
fn rkv_header_field_corruption_errors() {
    // wrong magic
    let mut img = rkv_image(&[], &[]);
    img[0] = b'X';
    assert!(RkvFile::open_bytes(&img).is_err());
    // unsupported version
    let mut img = rkv_image(&[], &[]);
    img[4..8].copy_from_slice(&2u32.to_le_bytes());
    assert!(RkvFile::open_bytes(&img).is_err());
    // data_offset beyond the file
    let img = rkv_header(0, u64::MAX);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_oversized_name_len_errors() {
    // name_len claims 0xFFFF but only a few bytes follow
    let mut entry = 0xFFFFu16.to_le_bytes().to_vec();
    entry.extend_from_slice(b"abc");
    let img = rkv_image(&[entry], &[]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_non_utf8_name_errors() {
    let img = rkv_image(&[rkv_entry(&[0xff, 0xfe], 0, 0, &[], 0, 0)], &[]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_unknown_dtype_code_errors() {
    let img = rkv_image(&[rkv_entry(b"t", 9, 1, &[2], 0, 8)], &[0u8; 8]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_implausible_rank_errors() {
    // ndim = 255 with no dims actually present: must be rejected as
    // corruption, not read as 255 u32 dims off the end of the file
    let img = rkv_image(&[rkv_entry(b"t", 0, 255, &[], 0, 0)], &[]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_offset_arithmetic_overflow_errors() {
    // data_offset + offset + nbytes wraps u64: the checked_add chain
    // must reject it rather than wrapping to a small in-bounds value
    let img = rkv_image(&[rkv_entry(b"t", 0, 1, &[2], u64::MAX - 4, u64::MAX - 4)], &[0u8; 8]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_element_count_overflow_errors() {
    // numel = (2^32-1)^3 overflows usize; nbytes kept small and
    // in-bounds so the earlier payload-window check passes
    let dims = [u32::MAX, u32::MAX, u32::MAX];
    let img = rkv_image(&[rkv_entry(b"t", 0, 3, &dims, 0, 0)], &[]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_shape_byte_count_mismatch_errors() {
    // shape [2,2] x f32 wants 16 bytes, header claims 8: accepting this
    // would let a later typed view read past the payload
    let img = rkv_image(&[rkv_entry(b"t", 0, 2, &[2, 2], 0, 8)], &[0u8; 16]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

// -------------------------------------------------- rkv: quantized --

#[test]
fn rkv_q4_payload_length_must_be_packed_size() {
    // [3, 5] Q4 packs to 3 * ceil(5/2) = 9 bytes; every other claim is a
    // lie that would let a nibble read run off the payload
    for nbytes in [8u64, 10, 15, 30] {
        let img = rkv_image(
            &[rkv_entry(b"w", 5, 2, &[3, 5], 0, nbytes)],
            &vec![0u8; nbytes as usize],
        );
        assert!(
            RkvFile::open_bytes(&img).is_err(),
            "Q4 [3,5] with {nbytes} bytes must be rejected (want 9)"
        );
    }
    // the correct packed size parses
    let img = rkv_image(&[rkv_entry(b"w", 5, 2, &[3, 5], 0, 9)], &[0u8; 9]);
    assert!(RkvFile::open_bytes(&img).is_ok());
}

#[test]
fn rkv_q4_non_matrix_rank_errors() {
    // sub-byte packing is defined per row: 1-D and 3-D Q4/Q4_1 tensors
    // have no packed size and must fail at open, not at first access
    for (dtype, ndim, dims) in [(5u8, 1u8, vec![6u32]), (6, 1, vec![6]), (5, 3, vec![2, 2, 2])] {
        let img = rkv_image(&[rkv_entry(b"w", dtype, ndim, &dims, 0, 4)], &[0u8; 4]);
        assert!(RkvFile::open_bytes(&img).is_err(), "rank {ndim} q4 must be rejected");
    }
}

#[test]
fn rkv_q4_huge_shape_errors() {
    // maximal 2-D dims: the packed size (rows * ceil(cols/2)) is checked
    // math and cannot match a small nbytes claim
    let img = rkv_image(&[rkv_entry(b"w", 5, 2, &[u32::MAX, u32::MAX], 0, 0)], &[]);
    assert!(RkvFile::open_bytes(&img).is_err());
    // and the element-count overflow path still fires for q4 codes
    let dims = [u32::MAX, u32::MAX, u32::MAX];
    let img = rkv_image(&[rkv_entry(b"w", 6, 3, &dims, 0, 0)], &[]);
    assert!(RkvFile::open_bytes(&img).is_err());
}

#[test]
fn rkv_q4_scale_block_mismatch_rejected_by_mat() {
    // a valid Q4 [2, 40] payload (40 cols = 2 groups/row) whose .scale
    // sibling is one group short per row: mat() must Err, never index
    // past the scale block inside the fused kernels
    let packed = vec![0x88u8; 2 * 20];
    let entries = vec![
        rkv_entry(b"w", 5, 2, &[2, 40], 0, 40),
        // f16 [2, 1] = 4 bytes, placed right after the 40 packed bytes
        rkv_entry(b"w.scale", 1, 2, &[2, 1], 40, 4),
    ];
    let mut payload = packed;
    payload.extend_from_slice(&[0u8; 4]);
    let f = RkvFile::open_bytes(&rkv_image(&entries, &payload)).unwrap();
    assert!(f.mat("w").is_err(), "short scale block must be rejected");
}

#[test]
fn rkv_q4_1_missing_min_sibling_rejected_by_mat() {
    // Q4_1 needs BOTH .scale and .min; an image with only .scale (e.g. a
    // truncated re-export) must fail at mat(), not decode offsets as 0
    let entries = vec![
        rkv_entry(b"w", 6, 2, &[2, 32], 0, 32),
        rkv_entry(b"w.scale", 1, 2, &[2, 1], 32, 4),
    ];
    let mut payload = vec![0u8; 32];
    payload.extend_from_slice(&[0u8; 4]);
    let f = RkvFile::open_bytes(&rkv_image(&entries, &payload)).unwrap();
    assert!(f.mat("w").is_err(), "missing .min sibling must be rejected");
}

#[test]
fn rkv_out_of_range_row_errors_not_panics() {
    let img = rkv_bytes(&[RkvTensor::f16_from_f32("w", vec![2, 2], &[1.0; 4])]);
    let f = RkvFile::open_bytes(&img).unwrap();
    assert!(f.row_f16("w", 1).is_ok());
    assert!(f.row_f16("w", 2).is_err());
    assert!(f.row_f16("w", usize::MAX).is_err());
}

// ---------------------------------------------------------- statefile --

/// Seal an arbitrary body (starting at the magic) with a valid trailing
/// FNV word, so tests exercise the validation *behind* the checksum gate.
fn sealed(body: Vec<u8>) -> Vec<u8> {
    let mut v = body;
    let d = statefile_checksum(&v);
    v.extend_from_slice(&d.to_le_bytes());
    v
}

fn sf_body(version: u32, tag: &[u8], rest: &[u8]) -> Vec<u8> {
    let mut v = STATEFILE_MAGIC.to_vec();
    v.extend_from_slice(&version.to_le_bytes());
    v.extend_from_slice(&(tag.len() as u16).to_le_bytes());
    v.extend_from_slice(tag);
    v.extend_from_slice(rest);
    v
}

fn filled_state() -> RwkvState {
    let mut st = RwkvState::zero(2, 8, 2, 4);
    let vecs = st.att_x.iter_mut().chain(st.wkv.iter_mut()).chain(st.ffn_x.iter_mut());
    for (i, v) in vecs.enumerate() {
        for (j, x) in v.iter_mut().enumerate() {
            *x = i as f32 * 0.25 + j as f32 * 0.0625;
        }
    }
    st
}

#[test]
fn statefile_every_truncation_errors() {
    let st = filled_state();
    let full = statefile_bytes("tag:1", &[(&[3u32, 1, 4], &st)]).unwrap();
    assert!(read_statefile_bytes(&full, "t").is_ok());
    for cut in 0..full.len() {
        assert!(
            read_statefile_bytes(&full[..cut], "t").is_err(),
            "prefix of {cut}/{} bytes parsed as a complete statefile",
            full.len()
        );
    }
}

#[test]
fn statefile_corrupted_payload_fails_checksum_then_parses_resealed() {
    let st = filled_state();
    let mut img = statefile_bytes("", &[(&[1u32], &st)]).unwrap();
    let flip = img.len() - 8; // inside the final payload f32
    img[flip] ^= 0x40;
    // the flip alone must trip the integrity gate...
    let err = read_statefile_bytes(&img, "t").unwrap_err().to_string();
    assert!(err.contains("checksum"), "unexpected error: {err}");
    // ...and once resealed, the (still well-formed) body must parse —
    // this is the property the fuzzer's reseal path depends on
    let len = img.len();
    let d = statefile_checksum(&img[..len - 4]);
    img[len - 4..].copy_from_slice(&d.to_le_bytes());
    assert!(read_statefile_bytes(&img, "t").is_ok());
}

#[test]
fn statefile_unsupported_version_errors() {
    let img = sealed(sf_body(STATEFILE_VERSION + 1, b"", &0u32.to_le_bytes()));
    let err = read_statefile_bytes(&img, "t").unwrap_err().to_string();
    assert!(err.contains("version"), "unexpected error: {err}");
}

#[test]
fn statefile_oversized_tag_len_errors() {
    // tag_len = 0xFFFF with a 3-byte tag actually present
    let mut body = STATEFILE_MAGIC.to_vec();
    body.extend_from_slice(&STATEFILE_VERSION.to_le_bytes());
    body.extend_from_slice(&0xFFFFu16.to_le_bytes());
    body.extend_from_slice(b"abc");
    let img = sealed(body);
    assert!(read_statefile_bytes(&img, "t").is_err());
}

#[test]
fn statefile_huge_prefix_len_errors_without_allocating() {
    // plen = u32::MAX would be a 16 GiB Vec if trusted; the
    // bytes-remaining bound must reject it first
    let mut rest = 1u32.to_le_bytes().to_vec(); // n_entries = 1
    rest.extend_from_slice(&u32::MAX.to_le_bytes()); // plen
    let img = sealed(sf_body(STATEFILE_VERSION, b"", &rest));
    let err = read_statefile_bytes(&img, "t").unwrap_err().to_string();
    assert!(err.contains("prefix length"), "unexpected error: {err}");
}

#[test]
fn statefile_inconsistent_head_shape_errors() {
    // heads * head_size != dim (3 * 4 != 8)
    let mut rest = 1u32.to_le_bytes().to_vec();
    for v in [0u32, 1, 8, 3, 4] {
        // plen, layers, dim, heads, head_size
        rest.extend_from_slice(&v.to_le_bytes());
    }
    let img = sealed(sf_body(STATEFILE_VERSION, b"", &rest));
    let err = read_statefile_bytes(&img, "t").unwrap_err().to_string();
    assert!(err.contains("inconsistent shape"), "unexpected error: {err}");
}

#[test]
fn statefile_shape_product_overflow_errors() {
    // heads = head_size = 2^31: the u128 consistency check must reject
    // the pair (product != dim) instead of wrapping in usize math
    let mut rest = 1u32.to_le_bytes().to_vec();
    for v in [0u32, 1, u32::MAX, 1 << 31, 1 << 31] {
        rest.extend_from_slice(&v.to_le_bytes());
    }
    let img = sealed(sf_body(STATEFILE_VERSION, b"", &rest));
    assert!(read_statefile_bytes(&img, "t").is_err());
}

#[test]
fn statefile_payload_exceeding_file_errors() {
    // consistent shape (2x4 = 8) but zero payload bytes follow
    let mut rest = 1u32.to_le_bytes().to_vec();
    for v in [0u32, 1, 8, 2, 4] {
        rest.extend_from_slice(&v.to_le_bytes());
    }
    let img = sealed(sf_body(STATEFILE_VERSION, b"", &rest));
    let err = read_statefile_bytes(&img, "t").unwrap_err().to_string();
    assert!(err.contains("payload exceeds"), "unexpected error: {err}");
}

// --------------------------------------------------------------- json --

#[test]
fn json_depth_limit_is_an_error_not_a_stack_overflow() {
    let deep = "[".repeat(json::MAX_DEPTH + 1) + &"]".repeat(json::MAX_DEPTH + 1);
    let err = json::parse(&deep).unwrap_err().to_string();
    assert!(err.contains("nesting"), "unexpected error: {err}");
    // unclosed-and-deep (the fuzzer's favourite): still an Err
    let ragged = "[".repeat(100_000);
    assert!(json::parse(&ragged).is_err());
}

#[test]
fn json_overflowing_numerics_parse_and_reserialize() {
    // 1e999 overflows f64 to +inf; the parser accepts it (it is valid
    // JSON grammar) and the writer emits null, which must re-parse
    for text in ["1e999", "-1e999", r#"{"temperature":1e999}"#, "[1e-999]"] {
        let v = json::parse(text).unwrap();
        let emitted = v.to_string();
        json::parse(&emitted)
            .unwrap_or_else(|e| panic!("writer output for {text:?} failed to reparse: {e}"));
    }
}

#[test]
fn json_nan_and_inf_literals_are_rejected() {
    for text in ["NaN", "nan", "Infinity", "-Infinity", r#"{"t":NaN}"#] {
        assert!(json::parse(text).is_err(), "literal {text:?} should not parse");
    }
}
