//! Fuzz smoke suite: structure-aware mutation fuzzing of every
//! untrusted-input parser (PR 7).
//!
//! Each target parses arbitrary bytes derived from valid seed corpora;
//! the parsers' contract is `Err` on malformed input, NEVER a panic, an
//! abort, or an oversized allocation.  `FUZZ_ITERS` scales the run: the
//! default keeps `cargo test` quick, the CI `fuzz-smoke` job sets 10000.
//!
//! Crashing inputs found here get minimized and pinned as regression
//! cases in `tests/parser_robustness.rs`.

use rwkv_lite::engine::state::RwkvState;
use rwkv_lite::io::rkv::RkvFile;
use rwkv_lite::io::statefile::{read_statefile_bytes, statefile_bytes, statefile_checksum};
use rwkv_lite::io::{rkv_bytes, RkvTensor};
use rwkv_lite::json;
use rwkv_lite::testutil::fuzz::fuzz_bytes;

fn iters() -> u64 {
    std::env::var("FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000)
}

/// Seed corpus for the `.rkv` parser: several dtypes, shapes, and an
/// empty-tensor edge so mutations explore every header field.
fn rkv_seeds() -> Vec<Vec<u8>> {
    let a = rkv_bytes(&[
        RkvTensor::f32("emb", vec![4, 3], &[0.5; 12]),
        RkvTensor::f16_from_f32("b0.att.wr.w", vec![3, 3], &[1.0; 9]),
        RkvTensor::i32("hh.assign", vec![4], &[0, 1, 1, 0]),
    ]);
    let b = rkv_bytes(&[RkvTensor::u8("q", vec![2, 2], vec![7, 8, 9, 10])]);
    let c = rkv_bytes(&[]);
    // group-quantized tensors with their f16 siblings (odd cols → ragged
    // final group + pad nibble), so mutations explore the packed-size
    // validation and the sibling shape checks
    let vals: Vec<f32> = (0..3 * 37).map(|i| (i % 13) as f32 * 0.3 - 1.7).collect();
    let mut qt = RkvTensor::q4_from_f32("b0.ffn.wk_t", 3, 37, &vals);
    qt.extend(RkvTensor::q4_1_from_f32("b0.ffn.wv", 3, 37, &vals));
    let d = rkv_bytes(&qt);
    vec![a, b, c, d]
}

/// Whatever `open_bytes` accepts must survive every accessor: the parse
/// invariants (shape·dtype == payload, in-bounds ranges) are what make
/// the accessors panic-free, so exercise them all.
fn exercise_rkv(f: &RkvFile) {
    let names: Vec<String> = f.names().map(|s| s.to_string()).collect();
    let _ = f.total_bytes();
    let _ = f.bytes_where(|n| n.contains('.'));
    let _ = f.advise_prefix("b0.");
    for n in &names {
        let _ = f.entry(n);
        let _ = f.raw(n);
        let _ = f.vec_f32(n);
        let _ = f.vec_i32(n);
        if let Ok(m) = f.mat(n) {
            // decode a row: quantized payloads must dequantize without
            // panicking whenever the parse invariants accepted them
            if m.rows() > 0 {
                let mut row = vec![0.0f32; m.cols()];
                m.decode_row(0, &mut row);
            }
        }
        let _ = f.row_f16(n, 0);
        let _ = f.row_f16(n, 3);
    }
}

#[test]
fn fuzz_rkv_parser() {
    let seeds = rkv_seeds();
    let out = fuzz_bytes(&seeds, iters(), 0x52_4b56, |bytes| {
        if let Ok(f) = RkvFile::open_bytes(bytes) {
            exercise_rkv(&f);
        }
    });
    out.assert_clean("rkv");
}

fn statefile_seeds() -> Vec<Vec<u8>> {
    let mut st = RwkvState::zero(2, 8, 2, 4);
    for v in st.att_x.iter_mut().chain(st.wkv.iter_mut()).chain(st.ffn_x.iter_mut()) {
        for (j, x) in v.iter_mut().enumerate() {
            *x = j as f32 * 0.125 - 1.0;
        }
    }
    let one = statefile_bytes("m:1:2", &[(&[2u32, 5, 9], &st)]).unwrap();
    let two = statefile_bytes("", &[(&[4u32], &st), (&[4u32, 7], &st)]).unwrap();
    vec![one, two]
}

/// Recompute the trailing FNV word so a mutated body passes the
/// integrity gate — otherwise ~every mutation dies at the checksum and
/// the actual entry parser never sees fuzzed bytes.
fn reseal(bytes: &[u8]) -> Option<Vec<u8>> {
    if bytes.len() < 4 {
        return None;
    }
    let mut out = bytes[..bytes.len() - 4].to_vec();
    let digest = statefile_checksum(&out);
    out.extend_from_slice(&digest.to_le_bytes());
    Some(out)
}

#[test]
fn fuzz_statefile_parser() {
    let seeds = statefile_seeds();
    let out = fuzz_bytes(&seeds, iters(), 0x52_5753, |bytes| {
        // raw: exercises the magic/length/checksum gates
        let _ = read_statefile_bytes(bytes, "fuzz");
        // resealed: exercises the shape/count/payload validation behind
        // a valid checksum
        if let Some(sealed) = reseal(bytes) {
            let _ = read_statefile_bytes(&sealed, "fuzz-sealed");
        }
    });
    out.assert_clean("statefile");
}

fn json_seeds() -> Vec<Vec<u8>> {
    vec![
        br#"{"prompt":"the quick","max_tokens":32,"temperature":0.8,"top_p":0.95}"#.to_vec(),
        br#"{"a":[1,2.5,-3e4,true,false,null],"b":{"c":"A\n\"x\""}}"#.to_vec(),
        br#"[[[{"deep":[1]}]]]"#.to_vec(),
        br#""lone string with \\ escapes""#.to_vec(),
        b"1e308".to_vec(),
    ]
}

#[test]
fn fuzz_json_parser() {
    let seeds = json_seeds();
    let out = fuzz_bytes(&seeds, iters(), 0x4a_534f4e, |bytes| {
        let Ok(text) = std::str::from_utf8(bytes) else {
            return;
        };
        if let Ok(v) = json::parse(text) {
            // writer/parser closure: anything the parser accepts, the
            // writer must serialize to something the parser re-accepts
            // (non-finite numbers print as null — still re-parseable)
            let emitted = v.to_string();
            json::parse(&emitted).unwrap_or_else(|e| {
                panic!("writer output failed to reparse: {e}\n  emitted: {emitted}")
            });
        }
    });
    out.assert_clean("json");
}
