//! Bit-exact equivalence for the session API: chunked batched prefill
//! (the `(B', T)` segment rounds behind `RwkvEngine::step_round` /
//! `forward_sequence`) must produce IDENTICAL states and logits to the
//! sequential per-token path (`forward_hidden` + `forward_token`), for
//! chunk sizes {1, 3, 8}, across dense, sparse-FFN, hier-head, f16 +
//! low-rank and layerwise configs — including rounds that mix prefill
//! and decode sessions.
//!
//! Runs on synthetic checkpoints (testutil::synth) — no `make artifacts`
//! needed, so this is tier-1 coverage for the session engine.

use std::path::PathBuf;

use rwkv_lite::config::{EngineConfig, LoadStrategy};
use rwkv_lite::engine::session::{FinishReason, Phase, Session};
use rwkv_lite::engine::{state::RwkvState, RwkvEngine};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

const BOS: u32 = 2;

fn synth_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rwkv-prefeq-{}-{}", tag, std::process::id()))
}

fn assert_states_identical(a: &RwkvState, b: &RwkvState, ctx: &str) {
    assert_eq!(a.att_x, b.att_x, "{ctx}: att_x state diverged");
    assert_eq!(a.wkv, b.wkv, "{ctx}: wkv state diverged");
    assert_eq!(a.ffn_x, b.ffn_x, "{ctx}: ffn_x state diverged");
}

/// Sequential reference over one feed stream: per-token `forward_hidden`
/// on all but the last position, `forward_token` (with head) on the last.
fn sequential_reference(engine: &mut RwkvEngine, feed: &[u32]) -> (RwkvState, Vec<f32>) {
    let mut st = engine.new_state();
    for &t in &feed[..feed.len() - 1] {
        engine.forward_hidden(t, &mut st).unwrap();
    }
    let logits = engine.forward_token(feed[feed.len() - 1], &mut st).unwrap();
    (st, logits)
}

/// Chunked prefill (every chunk size) vs the sequential path, bit for bit.
fn check_prefill(tag: &str, spec: &SynthSpec, cfg_mut: impl Fn(&mut EngineConfig)) {
    let dir = synth_dir(tag);
    write_synth_rwkv(&dir, "m", spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg_mut(&mut cfg);
    // prompt lengths that land inside, on and across chunk boundaries
    let prompts: Vec<Vec<u32>> = vec![
        vec![5],
        vec![3, 17, 9],
        (0..9).map(|i| ((7 + 13 * i) % spec.vocab) as u32).collect(),
    ];
    let mut seq = RwkvEngine::load(cfg.clone()).expect("load seq engine");
    for &chunk in &[1usize, 3, 8] {
        let mut c2 = cfg.clone();
        c2.prefill_chunk = chunk;
        let mut fused = RwkvEngine::load(c2).expect("load fused engine");
        for (pi, prompt) in prompts.iter().enumerate() {
            let mut feed = vec![BOS];
            feed.extend_from_slice(prompt);
            let (seq_state, seq_logits) = sequential_reference(&mut seq, &feed);
            let mut st = fused.new_state();
            let logits = fused.forward_sequence(&feed, &mut st).unwrap();
            assert_eq!(
                seq_logits, logits,
                "{tag} chunk={chunk} prompt#{pi}: chunked prefill logits must be bit-identical"
            );
            assert_states_identical(&seq_state, &st, &format!("{tag} chunk={chunk} prompt#{pi}"));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prefill_equals_sequential_dense_f32() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    check_prefill("dense-f32", &spec, |_| {});
}

#[test]
fn prefill_equals_sequential_sparse_ffn() {
    let spec = SynthSpec::tiny();
    check_prefill("sparse", &spec, |c| {
        c.sparse_ffn = true;
    });
}

#[test]
fn prefill_equals_sequential_all_techniques_f16_lowrank() {
    let mut spec = SynthSpec::tiny();
    spec.f16 = true;
    spec.lowrank = true;
    spec.seed = 0xBEEF;
    check_prefill("all-f16-lr", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
        c.emb_cache = true;
    });
}

#[test]
fn prefill_equals_sequential_dense_layerwise() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    spec.seed = 0xFACE;
    check_prefill("dense-layerwise", &spec, |c| {
        c.strategy = LoadStrategy::Layerwise;
    });
}

/// Greedy reference for full session semantics: prefill `[BOS, prompt]`,
/// then sample argmax tokens until `n` are produced (no stop tokens).
fn greedy_reference(engine: &mut RwkvEngine, prompt: &[u32], n: usize) -> (Vec<u32>, RwkvState) {
    let mut st = engine.new_state();
    let mut feed = vec![BOS];
    feed.extend_from_slice(prompt);
    for &t in &feed[..feed.len() - 1] {
        engine.forward_hidden(t, &mut st).unwrap();
    }
    let mut logits = engine.forward_token(feed[feed.len() - 1], &mut st).unwrap();
    let mut out = Vec::with_capacity(n);
    loop {
        let tok = rwkv_lite::util::argmax(&logits) as u32;
        out.push(tok);
        if out.len() >= n {
            break;
        }
        logits = engine.forward_token(tok, &mut st).unwrap();
    }
    (out, st)
}

/// Rounds that MIX prefill and decode sessions (different prompt lengths,
/// chunk 3, so long prompts are still prefilling while short ones decode)
/// must emit exactly the sequential greedy streams, with bit-identical
/// final states.
#[test]
fn mixed_prefill_decode_rounds_match_sequential() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("mixed");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = true;
    cfg.hier_head = true;
    let mut seq = RwkvEngine::load(cfg.clone()).unwrap();
    cfg.prefill_chunk = 3;
    let mut fused = RwkvEngine::load(cfg).unwrap();
    let n = 5usize;
    let prompts: Vec<Vec<u32>> = vec![
        (0..9).map(|i| ((11 + 5 * i) % spec.vocab) as u32).collect(),
        vec![7],
        vec![4, 40, 4, 44],
        (0..13).map(|i| ((3 + 17 * i) % spec.vocab) as u32).collect(),
    ];
    let mut sessions: Vec<Session> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut s = Session::new(&fused, i as u64, p);
            s.max_tokens = n; // greedy sampler is the Session default
            s
        })
        .collect();
    // session 1 (prompt len 1) decodes from round 2 while session 3
    // (feed 14, chunk 3) prefills until round 5 — genuinely mixed rounds
    assert_eq!(sessions[3].phase(), Phase::Prefill { pos: 0 });
    let mut emitted: Vec<Vec<u32>> = vec![Vec::new(); sessions.len()];
    let mut rounds = 0;
    while sessions.iter().any(|s| !s.is_done()) {
        let report = fused.step_round(&mut sessions).unwrap();
        for e in &report.emitted {
            emitted[e.session].push(e.token);
        }
        rounds += 1;
        assert!(rounds < 64, "round loop did not converge");
    }
    for (i, prompt) in prompts.iter().enumerate() {
        let (want, want_state) = greedy_reference(&mut seq, prompt, n);
        assert_eq!(
            emitted[i], want,
            "session {i}: mixed-round stream must match sequential greedy"
        );
        assert_states_identical(&want_state, sessions[i].state(), &format!("session {i}"));
        assert_eq!(sessions[i].finish_reason(), Some(FinishReason::MaxTokens));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: a round with P prefill sessions (chunk T) and B decode
/// sessions streams dense-layer weights ONCE — `round_weight_bytes` is
/// constant in P and B.
#[test]
fn round_weight_bytes_constant_in_p_and_b() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    let dir = synth_dir("bytes");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let cfg = EngineConfig::vanilla("m", dir.clone()); // prefill_chunk = 8
    let mut bytes_seen = Vec::new();
    for &(p, b) in &[(1usize, 1usize), (3, 1), (1, 4), (4, 4)] {
        let mut engine = RwkvEngine::load(cfg.clone()).unwrap();
        // decode sessions: tiny prompt, one solo round puts them in Decode
        let mut decode: Vec<Session> = (0..b)
            .map(|i| {
                let mut s = Session::new(&engine, i as u64, &[5 + i as u32]);
                s.max_tokens = 4;
                s
            })
            .collect();
        engine.step_round(&mut decode).unwrap();
        assert!(decode.iter().all(|s| s.phase() == Phase::Decode));
        // prefill sessions: long prompts stay mid-prompt after one chunk
        let long: Vec<u32> = (0..40).map(|i| ((1 + 3 * i) % spec.vocab) as u32).collect();
        let mut sessions = decode;
        for j in 0..p {
            sessions.push(Session::new(&engine, (100 + j) as u64, &long));
        }
        let report = engine.step_round(&mut sessions).unwrap();
        assert_eq!(report.prefill_tokens, p * 8, "chunk-size prefill rows");
        assert_eq!(report.decode_tokens, b);
        assert_eq!(report.emitted.len(), b, "mid-prompt prefill emits nothing");
        bytes_seen.push(report.round_weight_bytes);
    }
    assert!(bytes_seen[0] > 0);
    assert!(
        bytes_seen.iter().all(|&x| x == bytes_seen[0]),
        "dense round weight bytes must be constant in P and B: {bytes_seen:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Cancelled sessions are skipped by the round and reported finished;
/// the rest of the batch is unaffected.
#[test]
fn cancelled_session_is_skipped_and_finished() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("cancel");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let mut engine = RwkvEngine::load(cfg).unwrap();
    let mut sessions: Vec<Session> = (0..3)
        .map(|i| {
            let mut s = Session::new(&engine, i, &[9, 21, 3 + i as u32]);
            s.max_tokens = 6;
            s
        })
        .collect();
    engine.step_round(&mut sessions).unwrap();
    sessions[1].cancel();
    assert_eq!(sessions[1].finish_reason(), Some(FinishReason::Cancelled));
    let report = engine.step_round(&mut sessions).unwrap();
    assert!(report.finished.contains(&1), "cancelled session reported finished");
    assert!(report.emitted.iter().all(|e| e.session != 1), "no tokens for cancelled");
    assert_eq!(report.decode_tokens, 2, "others keep decoding");
    // a finish reason is never overwritten
    sessions[1].cancel();
    assert_eq!(sessions[1].finish_reason(), Some(FinishReason::Cancelled));
    std::fs::remove_dir_all(&dir).ok();
}

/// Stop tokens end the session the round they are sampled (the stop token
/// itself is emitted, matching EOS semantics).
#[test]
fn stop_token_finishes_session() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("stop");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let mut engine = RwkvEngine::load(cfg.clone()).unwrap();
    // learn the deterministic greedy stream first; stop on the token at
    // index 2 (expecting the stream up to its FIRST occurrence — greedy
    // streams on synthetic models may repeat tokens)
    let (stream, _) = greedy_reference(&mut engine, &[8, 30], 4);
    let stop = stream[2];
    let first = stream.iter().position(|&t| t == stop).unwrap();
    let mut engine2 = RwkvEngine::load(cfg).unwrap();
    let mut sess = Session::new(&engine2, 0, &[8, 30]);
    sess.max_tokens = 64;
    sess.stop_tokens = vec![stop];
    let mut out = Vec::new();
    while !sess.is_done() {
        let report = engine2.step_round(std::slice::from_mut(&mut sess)).unwrap();
        out.extend(report.emitted.iter().map(|e| e.token));
    }
    assert_eq!(out, stream[..=first].to_vec(), "stream ends AT the stop token");
    assert_eq!(sess.finish_reason(), Some(FinishReason::Stop(stop)));
    assert_eq!(sess.tokens_produced(), first + 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Multi-token stop sequences suffix-match the EMITTED stream: the
/// session ends the round the last token of the sequence is sampled
/// (tokens of the match are emitted), and single stop tokens still win
/// when they fire first.
#[test]
fn stop_sequence_finishes_session() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("stopseq");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let cfg = EngineConfig::vanilla("m", dir.clone());
    let mut engine = RwkvEngine::load(cfg.clone()).unwrap();
    let (stream, _) = greedy_reference(&mut engine, &[8, 30], 6);
    let seq = vec![stream[1], stream[2]];
    let first_end = (1..stream.len()).find(|&e| stream[e - 1..=e] == seq[..]).unwrap();
    let mut engine2 = RwkvEngine::load(cfg.clone()).unwrap();
    let mut sess = Session::new(&engine2, 0, &[8, 30]);
    sess.max_tokens = 64;
    sess.stop_seqs = vec![vec![999_999], seq.clone()];
    let mut out = Vec::new();
    while !sess.is_done() {
        let report = engine2.step_round(std::slice::from_mut(&mut sess)).unwrap();
        out.extend(report.emitted.iter().map(|e| e.token));
    }
    assert_eq!(out, stream[..=first_end].to_vec(), "stream ends AFTER the sequence");
    assert_eq!(sess.finish_reason(), Some(FinishReason::StopSeq(1)));
    assert_eq!(sess.finish_reason().unwrap().name(), "stop");
    // a single-token match of the sequence alone must NOT stop: only the
    // full suffix does (re-run with a longer, never-matching sequence)
    let mut engine3 = RwkvEngine::load(cfg).unwrap();
    let mut sess3 = Session::new(&engine3, 0, &[8, 30]);
    sess3.max_tokens = 4;
    sess3.stop_seqs = vec![vec![stream[1], 999_999]];
    let mut out3 = Vec::new();
    while !sess3.is_done() {
        let report = engine3.step_round(std::slice::from_mut(&mut sess3)).unwrap();
        out3.extend(report.emitted.iter().map(|e| e.token));
    }
    assert_eq!(out3, stream[..4].to_vec(), "partial sequence matches never stop");
    assert_eq!(sess3.finish_reason(), Some(FinishReason::MaxTokens));
    std::fs::remove_dir_all(&dir).ok();
}
