//! Bit-exact equivalence: the weight-streaming batched decode round must
//! produce IDENTICAL logits and states to the per-slot path, for B in
//! {1, 2, 8}, across dense and sparse-FFN configs (plus hierarchical head,
//! low-rank projections, f16 storage and the layerwise strategy).
//!
//! Runs on synthetic checkpoints (testutil::synth) — no `make artifacts`
//! needed, so this is tier-1 coverage for the batched engine.

use std::path::PathBuf;

use rwkv_lite::config::{EngineConfig, LoadStrategy};
use rwkv_lite::engine::{state::RwkvState, RwkvEngine};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

fn synth_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rwkv-batcheq-{}-{}", tag, std::process::id()))
}

fn assert_states_identical(a: &RwkvState, b: &RwkvState, ctx: &str) {
    assert_eq!(a.att_x, b.att_x, "{ctx}: att_x state diverged");
    assert_eq!(a.wkv, b.wkv, "{ctx}: wkv state diverged");
    assert_eq!(a.ffn_x, b.ffn_x, "{ctx}: ffn_x state diverged");
}

/// Build per-slot contexts, then compare one decode step per slot against
/// one batched round, bit for bit (logits AND recurrent state).
fn check_equivalence(tag: &str, spec: &SynthSpec, cfg_mut: impl Fn(&mut EngineConfig)) {
    let dir = synth_dir(tag);
    write_synth_rwkv(&dir, "m", spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg_mut(&mut cfg);
    for &b in &[1usize, 2, 8] {
        let mut seq = RwkvEngine::load(cfg.clone()).expect("load seq engine");
        let mut bat = RwkvEngine::load(cfg.clone()).expect("load batch engine");
        // distinct warm contexts per slot
        let mut seq_states: Vec<RwkvState> = (0..b).map(|_| seq.new_state()).collect();
        for (s, st) in seq_states.iter_mut().enumerate() {
            for t in 0..((s % 3) + 2) {
                let tok = ((3 + 7 * s + 5 * t) % spec.vocab) as u32;
                seq.forward_hidden(tok, st).unwrap();
            }
        }
        let mut bat_states = seq_states.clone();
        let toks: Vec<u32> = (0..b).map(|s| ((5 + 11 * s) % spec.vocab) as u32).collect();
        let mut seq_logits = Vec::with_capacity(b);
        for (s, st) in seq_states.iter_mut().enumerate() {
            seq_logits.push(seq.forward_token(toks[s], st).unwrap());
        }
        let bat_logits = bat.forward_tokens_batch(&toks, &mut bat_states).unwrap();
        assert_eq!(bat_logits.len(), b);
        for s in 0..b {
            assert_eq!(
                seq_logits[s], bat_logits[s],
                "{tag} B={b} slot {s}: batched logits must be bit-identical"
            );
            assert_states_identical(
                &seq_states[s],
                &bat_states[s],
                &format!("{tag} B={b} slot {s}"),
            );
        }
        // a second round from the advanced states must stay identical too
        let toks2: Vec<u32> = (0..b).map(|s| ((23 + 3 * s) % spec.vocab) as u32).collect();
        let mut seq_logits2 = Vec::with_capacity(b);
        for (s, st) in seq_states.iter_mut().enumerate() {
            seq_logits2.push(seq.forward_token(toks2[s], st).unwrap());
        }
        let bat_logits2 = bat.forward_tokens_batch(&toks2, &mut bat_states).unwrap();
        for s in 0..b {
            assert_eq!(
                seq_logits2[s], bat_logits2[s],
                "{tag} B={b} slot {s}: round 2 diverged"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_equals_per_slot_dense_f32() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    check_equivalence("dense-f32", &spec, |_| {});
}

#[test]
fn batch_equals_per_slot_sparse_ffn() {
    let spec = SynthSpec::tiny();
    check_equivalence("sparse", &spec, |c| {
        c.sparse_ffn = true;
    });
}

#[test]
fn batch_equals_per_slot_all_techniques_f16_lowrank() {
    let mut spec = SynthSpec::tiny();
    spec.f16 = true;
    spec.lowrank = true;
    spec.seed = 0xBEEF;
    check_equivalence("all-f16-lr", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
        c.emb_cache = true;
    });
}

/// Group-quantized checkpoint: batched rounds over Q4/Q4_1 weights must
/// stay bit-identical to the per-slot path (the batched kernels decode
/// each weight element once per round and reuse it across slots — same
/// dequantized value, same accumulation order per slot).
#[test]
fn batch_equals_per_slot_quantized() {
    let mut spec = SynthSpec::tiny();
    spec.q4 = true;
    spec.seed = 0x0444;
    check_equivalence("q4", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
    });
}

#[test]
fn batch_equals_per_slot_dense_layerwise() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    spec.seed = 0xFACE;
    check_equivalence("dense-layerwise", &spec, |c| {
        c.strategy = LoadStrategy::Layerwise;
    });
}

#[test]
fn batch_round_telemetry_and_union_accounting() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("telemetry");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = true;
    let mut e = RwkvEngine::load(cfg).unwrap();
    let mut states: Vec<RwkvState> = (0..4).map(|_| e.new_state()).collect();
    let toks = [1u32, 9, 17, 33];
    e.forward_tokens_batch(&toks, &mut states).unwrap();
    assert_eq!(e.metrics.counter("batch_rounds"), 1);
    assert_eq!(e.metrics.counter("batch_slot_tokens"), 4);
    assert!(e.last_round_weight_bytes > 0, "round weight bytes recorded");
    let union = e.metrics.counter("batch_union_rows");
    let indiv = e.metrics.counter("batch_individual_rows");
    assert!(union > 0, "sparse rounds must select rows");
    assert!(union <= indiv, "union {union} cannot exceed per-slot sum {indiv}");
    // dense-layer weight bytes must not grow with B: a 1-slot round on a
    // dense config streams the same layer bytes as an 8-slot round
    let dir2 = synth_dir("telemetry-dense");
    let mut spec2 = SynthSpec::tiny();
    spec2.predictors = false;
    spec2.hier_head = false;
    write_synth_rwkv(&dir2, "m", &spec2).unwrap();
    let cfg2 = EngineConfig::vanilla("m", dir2.clone());
    let mut e2 = RwkvEngine::load(cfg2).unwrap();
    let mut bytes_by_b = Vec::new();
    for b in [1usize, 8] {
        let mut states: Vec<RwkvState> = (0..b).map(|_| e2.new_state()).collect();
        let toks: Vec<u32> = (0..b as u32).collect();
        e2.forward_tokens_batch(&toks, &mut states).unwrap();
        bytes_by_b.push(e2.last_round_weight_bytes);
    }
    assert_eq!(
        bytes_by_b[0], bytes_by_b[1],
        "dense round weight bytes must be constant in B"
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// The point of Q4 streaming: a quantized checkpoint's decode round must
/// move at most 0.55x the weight bytes of the same model stored in f16,
/// through the UNCHANGED engine paths (packed nibbles ~0.25x + per-group
/// f16 scales ~0.0625x on the quantized matrices; vectors stay float).
#[test]
fn quantized_round_streams_at_most_55_percent_of_f16_bytes() {
    let mut bytes = Vec::new();
    for q4 in [false, true] {
        let mut spec = SynthSpec::tiny();
        // pure dense rounds so round bytes == the streamed matrices
        spec.predictors = false;
        spec.hier_head = false;
        spec.f16 = true;
        spec.q4 = q4;
        let dir = synth_dir(if q4 { "ratio-q4" } else { "ratio-f16" });
        write_synth_rwkv(&dir, "m", &spec).unwrap();
        let cfg = EngineConfig::vanilla("m", dir.clone());
        let mut e = RwkvEngine::load(cfg).unwrap();
        let mut states: Vec<RwkvState> = (0..2).map(|_| e.new_state()).collect();
        e.forward_tokens_batch(&[3u32, 19], &mut states).unwrap();
        assert!(e.last_round_weight_bytes > 0);
        bytes.push(e.last_round_weight_bytes);
        std::fs::remove_dir_all(&dir).ok();
    }
    let (f16b, q4b) = (bytes[0] as f64, bytes[1] as f64);
    assert!(
        q4b <= 0.55 * f16b,
        "quantized round streams {q4b} bytes, f16 streams {f16b} — ratio {:.3} > 0.55",
        q4b / f16b
    );
}
