//! Bit-exact equivalence for intra-round parallelism: `step_round` with
//! `threads ∈ {1, 2, 8}` must produce IDENTICAL emitted token streams,
//! recurrent states, logits and per-round weight-byte accounting
//! (`round_weight_bytes`) across dense, sparse-FFN, hier-head and
//! f16 + low-rank synthetic checkpoints.
//!
//! The sharded kernels never split a floating-point reduction across
//! lanes and the WKV/predictor work is independent per slot/row, so the
//! thread count may only change WHICH core computes an output range —
//! never its value.  This test is the end-to-end enforcement of that
//! contract (the per-kernel enforcement lives in `tensor::matmat` tests).
//!
//! Runs on synthetic checkpoints (testutil::synth) — no `make artifacts`
//! needed, so this is tier-1 coverage.

use std::path::PathBuf;

use rwkv_lite::config::EngineConfig;
use rwkv_lite::engine::session::Session;
use rwkv_lite::engine::{state::RwkvState, RwkvEngine};
use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

const THREADS: [usize; 3] = [1, 2, 8];

fn synth_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rwkv-threq-{}-{}", tag, std::process::id()))
}

/// Everything one serving run produces that must not depend on `threads`.
#[derive(Debug, PartialEq)]
struct RunTrace {
    /// Emitted tokens per session, in emission order.
    emitted: Vec<Vec<u32>>,
    /// `round_weight_bytes` of every round, in order.
    round_bytes: Vec<u64>,
    /// Final logits of a standalone chunked prefill per prompt.
    logits: Vec<Vec<f32>>,
}

fn final_states(sessions: &[Session]) -> Vec<RwkvState> {
    sessions.iter().map(|s| s.state().clone()).collect()
}

fn assert_states_identical(a: &RwkvState, b: &RwkvState, ctx: &str) {
    assert_eq!(a.att_x, b.att_x, "{ctx}: att_x state diverged");
    assert_eq!(a.wkv, b.wkv, "{ctx}: wkv state diverged");
    assert_eq!(a.ffn_x, b.ffn_x, "{ctx}: ffn_x state diverged");
}

/// Drive a mixed prefill/decode serving run + standalone prefills with
/// `threads` compute lanes and record everything observable.
fn run_with_threads(
    cfg: &EngineConfig,
    prompts: &[Vec<u32>],
    threads: usize,
) -> (RunTrace, Vec<RwkvState>) {
    let mut cfg = cfg.clone();
    cfg.threads = threads;
    let mut engine = RwkvEngine::load(cfg).expect("load engine");
    let mut sessions: Vec<Session> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut s = Session::new(&engine, i as u64, p);
            s.max_tokens = 5; // greedy sampler is the Session default
            s
        })
        .collect();
    let mut emitted: Vec<Vec<u32>> = vec![Vec::new(); sessions.len()];
    let mut round_bytes = Vec::new();
    let mut rounds = 0;
    while sessions.iter().any(|s| !s.is_done()) {
        let report = engine.step_round(&mut sessions).expect("round");
        for e in &report.emitted {
            emitted[e.session].push(e.token);
        }
        round_bytes.push(report.round_weight_bytes);
        rounds += 1;
        assert!(rounds < 64, "round loop did not converge");
    }
    // standalone chunked prefill: logits must be bit-identical too
    let logits = prompts
        .iter()
        .map(|p| {
            let mut feed = vec![2u32]; // BOS
            feed.extend_from_slice(p);
            let mut st = engine.new_state();
            engine.forward_sequence(&feed, &mut st).expect("prefill")
        })
        .collect();
    (RunTrace { emitted, round_bytes, logits }, final_states(&sessions))
}

/// The core check: every thread count yields the same trace and states.
fn check_thread_equivalence(tag: &str, spec: &SynthSpec, cfg_mut: impl Fn(&mut EngineConfig)) {
    let dir = synth_dir(tag);
    write_synth_rwkv(&dir, "m", spec).expect("write synth model");
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.prefill_chunk = 3; // long prompts still prefill while short decode
    cfg_mut(&mut cfg);
    // mixed lengths: genuinely mixed prefill+decode rounds under chunk 3
    let prompts: Vec<Vec<u32>> = vec![
        (0..9).map(|i| ((11 + 5 * i) % spec.vocab) as u32).collect(),
        vec![7],
        vec![4, 40, 4, 44],
        (0..13).map(|i| ((3 + 17 * i) % spec.vocab) as u32).collect(),
    ];
    let (want, want_states) = run_with_threads(&cfg, &prompts, THREADS[0]);
    assert!(want.round_bytes.iter().any(|&b| b > 0), "{tag}: rounds stream weight bytes");
    for &threads in &THREADS[1..] {
        let (got, got_states) = run_with_threads(&cfg, &prompts, threads);
        assert_eq!(
            got.emitted, want.emitted,
            "{tag} threads={threads}: emitted streams must be bit-identical"
        );
        assert_eq!(
            got.round_bytes, want.round_bytes,
            "{tag} threads={threads}: round_weight_bytes must not depend on threads"
        );
        assert_eq!(
            got.logits, want.logits,
            "{tag} threads={threads}: prefill logits must be bit-identical"
        );
        for (i, (a, b)) in want_states.iter().zip(&got_states).enumerate() {
            assert_states_identical(a, b, &format!("{tag} threads={threads} session {i}"));
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn threads_equivalent_dense_f32() {
    let mut spec = SynthSpec::tiny();
    spec.predictors = false;
    spec.hier_head = false;
    check_thread_equivalence("dense-f32", &spec, |_| {});
}

#[test]
fn threads_equivalent_sparse_ffn() {
    let spec = SynthSpec::tiny();
    check_thread_equivalence("sparse", &spec, |c| {
        c.sparse_ffn = true;
    });
}

#[test]
fn threads_equivalent_hier_head() {
    let spec = SynthSpec::tiny();
    check_thread_equivalence("hier", &spec, |c| {
        c.hier_head = true;
    });
}

#[test]
fn threads_equivalent_all_techniques_f16_lowrank() {
    let mut spec = SynthSpec::tiny();
    spec.f16 = true;
    spec.lowrank = true;
    spec.seed = 0xBEEF;
    check_thread_equivalence("all-f16-lr", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
        c.emb_cache = true;
    });
}

/// Group-quantized checkpoint (Q4 dense/row tensors, Q4_1 ffn.wv): the
/// in-register dequant kernels shard over output ranges exactly like the
/// float kernels — mid-group column splits included — so thread count
/// must stay invisible here too.
#[test]
fn threads_equivalent_quantized() {
    let mut spec = SynthSpec::tiny();
    spec.q4 = true;
    spec.seed = 0x0444;
    check_thread_equivalence("q4", &spec, |c| {
        c.sparse_ffn = true;
        c.hier_head = true;
    });
}

/// The threaded round must also match the SINGLE-SLOT sequential path
/// (forward_hidden per token), tying thread equivalence back to the
/// per-slot reference the other equivalence suites use.
#[test]
fn threaded_round_matches_sequential_reference() {
    let spec = SynthSpec::tiny();
    let dir = synth_dir("seqref");
    write_synth_rwkv(&dir, "m", &spec).unwrap();
    let mut cfg = EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = true;
    let feed: Vec<u32> = vec![2, 9, 21, 3, 15, 40];
    // sequential per-token reference, single-threaded engine
    cfg.threads = 1;
    let mut seq = RwkvEngine::load(cfg.clone()).unwrap();
    let mut st_ref = seq.new_state();
    for &t in &feed[..feed.len() - 1] {
        seq.forward_hidden(t, &mut st_ref).unwrap();
    }
    let want = seq.forward_token(feed[feed.len() - 1], &mut st_ref).unwrap();
    // fused chunked prefill on an 8-lane engine
    cfg.threads = 8;
    let mut fused = RwkvEngine::load(cfg).unwrap();
    let mut st = fused.new_state();
    let got = fused.forward_sequence(&feed, &mut st).unwrap();
    assert_eq!(got, want, "threaded fused prefill == sequential per-token logits");
    assert_states_identical(&st_ref, &st, "seqref");
    std::fs::remove_dir_all(&dir).ok();
}
