//! Benchmark-task evaluation through the engine (Table 5 / Figure 5
//! accuracy numbers are produced HERE, by the rust inference stack with
//! the techniques active — not by the python trainer).
//!
//! Tasks come from `artifacts/data/tasks.json` (corpus.py): cloze tasks
//! score the final-word prediction (accuracy + gold perplexity); choice
//! tasks score candidate continuations by total log-probability.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::engine::transformer::TransformerEngine;
use crate::engine::RwkvEngine;
use crate::json::{self, Value};
use crate::util::logsumexp;

#[derive(Clone, Debug)]
pub struct ClozeExample {
    pub ctx: Vec<u32>,
    pub gold: u32,
}

#[derive(Clone, Debug)]
pub struct ChoiceExample {
    pub ctx: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub label: usize,
}

#[derive(Clone, Debug)]
pub enum Task {
    Cloze(Vec<ClozeExample>),
    Choice(Vec<ChoiceExample>),
}

pub fn load_tasks(path: &Path) -> Result<BTreeMap<String, Task>> {
    let v = json::parse_file(path)?;
    let obj = match &v {
        Value::Obj(m) => m,
        _ => anyhow::bail!("tasks.json: expected object"),
    };
    let mut out = BTreeMap::new();
    for (name, arr) in obj {
        let arr = arr.as_arr().context("task examples")?;
        if arr.is_empty() {
            continue;
        }
        if arr[0].get("choices").is_some() {
            let mut ex = Vec::new();
            for e in arr {
                ex.push(ChoiceExample {
                    ctx: ids(e.get("ctx").context("ctx")?)?,
                    choices: e
                        .get("choices")
                        .and_then(|c| c.as_arr())
                        .context("choices")?
                        .iter()
                        .map(ids)
                        .collect::<Result<_>>()?,
                    label: e.f64_at(&["label"]).context("label")? as usize,
                });
            }
            out.insert(name.clone(), Task::Choice(ex));
        } else {
            let mut ex = Vec::new();
            for e in arr {
                ex.push(ClozeExample {
                    ctx: ids(e.get("ctx").context("ctx")?)?,
                    gold: e.f64_at(&["gold"]).context("gold")? as u32,
                });
            }
            out.insert(name.clone(), Task::Cloze(ex));
        }
    }
    Ok(out)
}

fn ids(v: &Value) -> Result<Vec<u32>> {
    Ok(v.as_arr()
        .context("token array")?
        .iter()
        .filter_map(|x| x.as_f64().map(|n| n as u32))
        .collect())
}

#[derive(Clone, Copy, Debug, Default)]
pub struct TaskResult {
    pub acc: f64,
    pub ppl: f64, // 0 for choice tasks
    pub n: usize,
}

/// A model that can score sequences token-by-token.
pub trait Scorer {
    /// Log-probabilities of each `targets[i]` given `ctx + targets[..i]`.
    fn score(&mut self, ctx: &[u32], targets: &[u32]) -> Result<Vec<f64>>;
    /// Full next-token logits after consuming `ctx`.
    fn next_logits(&mut self, ctx: &[u32]) -> Result<Vec<f32>>;

    /// Total log-prob of each choice continuation after `ctx`.  Default
    /// replays the context per choice; RWKV overrides with state cloning
    /// (O(1) state makes shared prefill trivial — a transformer would
    /// need KV-cache forking).
    fn score_choices(&mut self, ctx: &[u32], choices: &[Vec<u32>]) -> Result<Vec<f64>> {
        choices
            .iter()
            .map(|c| Ok(self.score(ctx, c)?.iter().sum()))
            .collect()
    }
}

impl Scorer for RwkvEngine {
    fn score(&mut self, ctx: &[u32], targets: &[u32]) -> Result<Vec<f64>> {
        let mut state = self.new_state();
        let mut last = crate::text::BOS;
        for &t in ctx {
            self.forward_hidden(last, &mut state)?;
            last = t;
        }
        let mut lps = Vec::with_capacity(targets.len());
        for &t in targets {
            let logits = self.forward_token(last, &mut state)?;
            let lse = logsumexp(&logits);
            lps.push((logits[t as usize] - lse) as f64);
            last = t;
        }
        Ok(lps)
    }

    fn next_logits(&mut self, ctx: &[u32]) -> Result<Vec<f32>> {
        let mut state = self.new_state();
        let mut last = crate::text::BOS;
        for &t in ctx {
            self.forward_hidden(last, &mut state)?;
            last = t;
        }
        self.forward_token(last, &mut state)
    }

    fn score_choices(&mut self, ctx: &[u32], choices: &[Vec<u32>]) -> Result<Vec<f64>> {
        // shared prefill, cloned O(1) state per choice
        let mut state = self.new_state();
        let mut last = crate::text::BOS;
        for &t in ctx {
            self.forward_hidden(last, &mut state)?;
            last = t;
        }
        let mut out = Vec::with_capacity(choices.len());
        for choice in choices {
            let mut st = state.clone();
            let mut lp = 0.0f64;
            let mut prev = last;
            for &t in choice {
                let logits = self.forward_token(prev, &mut st)?;
                lp += (logits[t as usize] - logsumexp(&logits)) as f64;
                prev = t;
            }
            out.push(lp);
        }
        Ok(out)
    }
}

impl Scorer for TransformerEngine {
    fn score(&mut self, ctx: &[u32], targets: &[u32]) -> Result<Vec<f64>> {
        self.reset();
        let mut last = crate::text::BOS;
        for &t in ctx {
            self.forward_token(last)?;
            last = t;
        }
        let mut lps = Vec::with_capacity(targets.len());
        for &t in targets {
            let logits = self.forward_token(last)?;
            let lse = logsumexp(&logits);
            lps.push((logits[t as usize] - lse) as f64);
            last = t;
        }
        Ok(lps)
    }

    fn next_logits(&mut self, ctx: &[u32]) -> Result<Vec<f32>> {
        self.reset();
        let mut last = crate::text::BOS;
        for &t in ctx {
            self.forward_token(last)?;
            last = t;
        }
        self.forward_token(last)
    }
}

/// Evaluate one task; `limit` caps examples (0 = all).
pub fn eval_task<S: Scorer>(scorer: &mut S, task: &Task, limit: usize) -> Result<TaskResult> {
    match task {
        Task::Cloze(examples) => {
            let take = if limit == 0 { examples.len() } else { limit.min(examples.len()) };
            let mut correct = 0usize;
            let mut nll = 0.0f64;
            for e in &examples[..take] {
                let logits = scorer.next_logits(&e.ctx)?;
                let lse = logsumexp(&logits);
                if crate::util::argmax(&logits) == e.gold as usize {
                    correct += 1;
                }
                nll += (lse - logits[e.gold as usize]) as f64;
            }
            Ok(TaskResult {
                acc: correct as f64 / take as f64,
                ppl: (nll / take as f64).exp(),
                n: take,
            })
        }
        Task::Choice(examples) => {
            let take = if limit == 0 { examples.len() } else { limit.min(examples.len()) };
            let mut correct = 0usize;
            for e in &examples[..take] {
                let lps = scorer.score_choices(&e.ctx, &e.choices)?;
                let mut best = (f64::NEG_INFINITY, 0usize);
                for (ci, &lp) in lps.iter().enumerate() {
                    if lp > best.0 {
                        best = (lp, ci);
                    }
                }
                if best.1 == e.label {
                    correct += 1;
                }
            }
            Ok(TaskResult { acc: correct as f64 / take as f64, ppl: 0.0, n: take })
        }
    }
}
