//! Edge-device roofline models (substrate S26, DESIGN.md §2 substitution).
//!
//! The paper measures TPS on a Raspberry Pi 5 (4x Cortex-A76 @2.4GHz) and
//! an Orange Pi Zero 2W (4x Cortex-A53 @1.5GHz).  We do not have those
//! boards; token-at-a-time LLM inference is overwhelmingly *memory-
//! bandwidth bound* (every resident weight byte is touched once per
//! token), so a bandwidth+compute roofline projects host measurements
//! onto each device:
//!
//! ```text
//! t_token(device) = max(bytes_per_token / BW, flops_per_token / F)
//! ```
//!
//! The *ratios* between models/variants — what Figures 8, 10, 12 compare —
//! are preserved by construction; EXPERIMENTS.md reports both host-measured
//! and projected numbers.

/// Sustained streaming characteristics of a CPU platform.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub description: &'static str,
    /// Sustained memory bandwidth, bytes/sec.
    pub mem_bw: f64,
    /// Sustained f32 multiply-add throughput, FLOP/s (all cores).
    pub flops: f64,
    /// Active inference power draw, watts (paper §B.2: ~6.5 W on rpi5).
    pub watts: f64,
}

/// Raspberry Pi 5: LPDDR4X-4267 (~17 GB/s theoretical, ~10 GB/s sustained
/// from a single NEON stream mix), 4x A76 @ 2.4 GHz, 2x128-bit NEON FMA
/// => ~76 GFLOP/s peak, ~38 sustained.
pub const RPI5: DeviceProfile = DeviceProfile {
    name: "rpi5",
    description: "Raspberry Pi 5B, 2.4GHz 4x Cortex-A76; 8GB",
    mem_bw: 10.0e9,
    flops: 38.0e9,
    watts: 6.5,
};

/// Orange Pi Zero 2W: LPDDR4 (~4 GB/s sustained), 4x A53 @ 1.5 GHz,
/// 64-bit NEON => ~12 GFLOP/s peak, ~6 sustained.
pub const OPI2W: DeviceProfile = DeviceProfile {
    name: "opi2w",
    description: "Orange Pi Zero 2W, 1.5GHz 4x Cortex-A53; 4GB",
    mem_bw: 4.0e9,
    flops: 6.0e9,
    watts: 3.2,
};

pub fn by_name(name: &str) -> Option<DeviceProfile> {
    match name {
        "rpi5" => Some(RPI5),
        "opi2w" => Some(OPI2W),
        _ => None,
    }
}

impl DeviceProfile {
    /// Projected seconds per generated token.
    pub fn token_seconds(&self, bytes_per_token: f64, flops_per_token: f64) -> f64 {
        (bytes_per_token / self.mem_bw).max(flops_per_token / self.flops)
    }

    /// Projected tokens/second.
    pub fn tps(&self, bytes_per_token: f64, flops_per_token: f64) -> f64 {
        1.0 / self.token_seconds(bytes_per_token, flops_per_token)
    }

    /// Energy (joules) to generate `n` tokens (paper §B.2 methodology:
    /// constant device power x wall time).
    pub fn energy_joules(&self, n_tokens: usize, bytes_per_token: f64, flops_per_token: f64) -> f64 {
        self.watts * self.token_seconds(bytes_per_token, flops_per_token) * n_tokens as f64
    }
}

/// Analytic FLOPs per generated token for an RWKV variant.
/// Dominated by the matvecs: 2 flops per weight element touched.
pub fn rwkv_flops_per_token(dim: usize, layers: usize, ffn: usize, vocab: usize, svd_rank: usize, sparsity_kept: f64) -> f64 {
    let d = dim as f64;
    let f = ffn as f64;
    let l = layers as f64;
    let proj = if svd_rank > 0 {
        // 5 decomposed projections (4 att + 1 ffn-r): 2 * (D*r + r*D)
        5.0 * 2.0 * 2.0 * d * svd_rank as f64
    } else {
        5.0 * 2.0 * d * d
    };
    let wo = 2.0 * d * d;
    let wkv_state = 2.0 * 3.0 * d * (dim / layers.max(1)) as f64; // small; state ops
    let ffn_flops = 2.0 * 2.0 * d * f * sparsity_kept;
    let head = 2.0 * d * vocab as f64;
    l * (proj + wo + wkv_state + ffn_flops) + head
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_bound_regime() {
        // 100 MB/token at 10 GB/s => 10 ms/token => 100 TPS
        let t = RPI5.tps(100e6, 1e6);
        assert!((t - 100.0).abs() < 1.0, "tps={t}");
    }

    #[test]
    fn compute_bound_when_flops_dominate() {
        let secs = RPI5.token_seconds(1.0, 38.0e9); // exactly 1 s of flops
        assert!((secs - 1.0).abs() < 1e-9);
    }

    #[test]
    fn opi_slower_than_rpi() {
        let b = 10e6;
        let f = 50e6;
        assert!(OPI2W.tps(b, f) < RPI5.tps(b, f));
    }

    #[test]
    fn svd_reduces_flops() {
        let dense = rwkv_flops_per_token(1024, 24, 3584, 65536, 0, 1.0);
        let svd = rwkv_flops_per_token(1024, 24, 3584, 65536, 128, 1.0);
        assert!(svd < dense);
    }
}
