//! Runtime configuration: which techniques are enabled, loading strategy,
//! backend.  Built from CLI flags + manifest defaults; serializable for
//! the launcher (`rwkv-lite serve --config <file.json>`).

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::json::{self, Value};

/// How weights enter memory (paper §5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadStrategy {
    /// Everything resident before the first token (minus technique-managed
    /// groups: embeddings, sparse FFN rows, hierarchical-head rows).
    Full,
    /// Layer N+1 streams in while layer N executes; per-layer weights are
    /// dropped afterwards.  Smallest footprint, disk-IO latency per token.
    Layerwise,
}

impl LoadStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "full" => LoadStrategy::Full,
            "layerwise" => LoadStrategy::Layerwise,
            _ => bail!("unknown load strategy '{s}' (full|layerwise)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            LoadStrategy::Full => "full",
            LoadStrategy::Layerwise => "layerwise",
        }
    }
}

/// Compute backend for the dense per-layer math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust kernels (tensor::matvec) — the edge-device path.
    Native,
    /// AOT-compiled HLO components executed through PJRT (runtime::).
    Xla,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => Backend::Native,
            "xla" => Backend::Xla,
            _ => bail!("unknown backend '{s}' (native|xla)"),
        })
    }
}

/// SIMD kernel backend selection ([`crate::tensor::simd`]): `Auto` probes
/// the host at engine load (aarch64 → NEON, x86_64 with AVX2 → AVX2, else
/// scalar); forcing a backend the host lacks fails at load.  Every
/// backend is bit-identical to scalar — this knob trades throughput only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    Auto,
    Scalar,
    Neon,
    Avx2,
}

impl SimdMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => SimdMode::Auto,
            "scalar" => SimdMode::Scalar,
            "neon" => SimdMode::Neon,
            "avx2" => SimdMode::Avx2,
            _ => bail!("unknown simd mode '{s}' (auto|scalar|neon|avx2)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Scalar => "scalar",
            SimdMode::Neon => "neon",
            SimdMode::Avx2 => "avx2",
        }
    }

    /// The forced backend this mode requests (`None` = auto-detect).
    pub fn requested(self) -> Option<crate::tensor::SimdBackend> {
        match self {
            SimdMode::Auto => None,
            SimdMode::Scalar => Some(crate::tensor::SimdBackend::Scalar),
            SimdMode::Neon => Some(crate::tensor::SimdBackend::Neon),
            SimdMode::Avx2 => Some(crate::tensor::SimdBackend::Avx2),
        }
    }
}

/// Full engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub model: String,
    pub artifacts: PathBuf,
    pub strategy: LoadStrategy,
    pub backend: Backend,
    /// §3.2 sparse FFN via the predictor ensemble.
    pub sparse_ffn: bool,
    /// §3.3 hierarchical head.
    pub hier_head: bool,
    /// §3.3 embedding LRU cache (off => full embedding table resident).
    pub emb_cache: bool,
    /// Override the manifest's cache capacity (0 = manifest default).
    pub emb_cache_capacity: usize,
    /// Override hierarchical-head p_min (0 = manifest default).
    pub hh_p_min: f32,
    /// Max prompt tokens a prefill session advances per scheduling round
    /// (the `(B', T)` fused-prefill chunk; clamped to >= 1 at use).
    pub prefill_chunk: usize,
    /// Double-buffered block prefetch for [`LoadStrategy::Layerwise`]:
    /// while the round computes block N, a background I/O task streams
    /// block N+1 so the layer boundary only pays the (usually tiny)
    /// remaining wait instead of a full block load.  Bit-identical either
    /// way; costs ~one extra resident block (§5.1 accounting reports the
    /// double-buffered peak).  Ignored for `Full` loading and the XLA
    /// backend.  On by default.
    pub prefetch: bool,
    /// Intra-round compute lanes (sharded kernels + per-slot WKV /
    /// predictor): `0` = one lane per available core, `1` =
    /// single-threaded, `k` = `k` lanes.  Rounds are bit-identical for
    /// every value — this knob only trades cores for latency.
    pub threads: usize,
    /// SIMD kernel backend for the tensor inner loops (`--simd`).
    pub simd: SimdMode,
    /// Prefix-state cache budget in MiB (`0` = disabled).  The serve path
    /// builds one `engine::state_cache::StateCache` the coordinator owns
    /// across all requests: shared prompt prefixes fork from a cached
    /// `RwkvState` snapshot instead of re-running prefill.  Warm-cache
    /// output is bit-identical to cold prefill.
    pub state_cache_mb: usize,
    /// Persist the prefix-state cache here (`io::statefile`): snapshots
    /// load at startup and save back at shutdown, so a fixed system
    /// prompt stays warm across process restarts.  Ignored when
    /// `state_cache_mb == 0`.
    pub state_file: Option<PathBuf>,
    /// Bounded admission: max requests waiting for a session slot.  A
    /// submission arriving with the queue full is shed IMMEDIATELY with a
    /// structured `overloaded` reply (429 semantics) instead of queueing
    /// forever.  `0` = unbounded (legacy behaviour).
    pub max_queue: usize,
    /// Max sessions in flight at once (the round's multiplexing cap).
    /// `0` = follow the batch policy's `max_batch`.
    pub max_concurrency: usize,
    /// Reject prompts longer than this many tokens at admission (`0` =
    /// unlimited) — one multi-MB prompt cannot monopolize prefill rounds.
    pub max_prompt_tokens: usize,
    /// Default per-request deadline in milliseconds (`0` = none).  A
    /// request's own `deadline_ms` field overrides; expired sessions
    /// retire at the next round boundary with `reason: "deadline"`.
    pub deadline_ms: u64,
    /// Graceful-shutdown drain budget in milliseconds: after
    /// SIGINT/SIGTERM the coordinator stops admitting and keeps stepping
    /// in-flight sessions for up to this long before cancelling the rest
    /// and saving the statefile.
    pub drain_ms: u64,
    /// Serve `GET /metrics` (Prometheus text exposition) and `GET /stats`
    /// (JSON summary) on the serving port (`--metrics`).  On by default
    /// for the serve path; scrapes read shared atomics/short locks, never
    /// the engine, so the round loop is unaffected.
    pub metrics_endpoint: bool,
    /// Export the coordinator's per-round trace ring as JSONL to this
    /// path at shutdown (`--trace-out`; `None` = no tracing).
    pub trace_out: Option<PathBuf>,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            model: String::new(),
            artifacts: PathBuf::from("artifacts"),
            strategy: LoadStrategy::Full,
            backend: Backend::Native,
            sparse_ffn: false,
            hier_head: false,
            emb_cache: false,
            emb_cache_capacity: 0,
            hh_p_min: 0.0,
            prefill_chunk: 8,
            prefetch: true,
            threads: 0,
            simd: SimdMode::Auto,
            state_cache_mb: 0,
            state_file: None,
            max_queue: 64,
            max_concurrency: 0,
            max_prompt_tokens: 0,
            deadline_ms: 0,
            drain_ms: 5000,
            metrics_endpoint: true,
            trace_out: None,
            seed: 0,
        }
    }
}

impl EngineConfig {
    /// The paper's "RWKV-ours" runtime: all techniques on.
    pub fn all_techniques(model: &str, artifacts: PathBuf) -> Self {
        Self {
            model: model.to_string(),
            artifacts,
            sparse_ffn: true,
            hier_head: true,
            emb_cache: true,
            ..Self::default()
        }
    }

    /// Vanilla runtime: nothing managed, everything dense.
    pub fn vanilla(model: &str, artifacts: PathBuf) -> Self {
        Self {
            model: model.to_string(),
            artifacts,
            ..Self::default()
        }
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("artifacts", json::s(&self.artifacts.display().to_string())),
            ("strategy", json::s(self.strategy.name())),
            (
                "backend",
                json::s(match self.backend {
                    Backend::Native => "native",
                    Backend::Xla => "xla",
                }),
            ),
            ("sparse_ffn", Value::Bool(self.sparse_ffn)),
            ("hier_head", Value::Bool(self.hier_head)),
            ("emb_cache", Value::Bool(self.emb_cache)),
            ("emb_cache_capacity", json::num(self.emb_cache_capacity as f64)),
            ("hh_p_min", json::num(self.hh_p_min as f64)),
            ("prefill_chunk", json::num(self.prefill_chunk as f64)),
            ("prefetch", Value::Bool(self.prefetch)),
            ("threads", json::num(self.threads as f64)),
            ("simd", json::s(self.simd.name())),
            ("state_cache_mb", json::num(self.state_cache_mb as f64)),
            (
                "state_file",
                json::s(
                    &self
                        .state_file
                        .as_ref()
                        .map(|p| p.display().to_string())
                        .unwrap_or_default(),
                ),
            ),
            ("max_queue", json::num(self.max_queue as f64)),
            ("max_concurrency", json::num(self.max_concurrency as f64)),
            ("max_prompt_tokens", json::num(self.max_prompt_tokens as f64)),
            ("deadline_ms", json::num(self.deadline_ms as f64)),
            ("drain_ms", json::num(self.drain_ms as f64)),
            ("metrics_endpoint", Value::Bool(self.metrics_endpoint)),
            (
                "trace_out",
                json::s(
                    &self
                        .trace_out
                        .as_ref()
                        .map(|p| p.display().to_string())
                        .unwrap_or_default(),
                ),
            ),
            ("seed", json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let mut c = Self::default();
        if let Some(s) = v.str_at(&["model"]) {
            c.model = s.to_string();
        }
        if let Some(s) = v.str_at(&["artifacts"]) {
            c.artifacts = PathBuf::from(s);
        }
        if let Some(s) = v.str_at(&["strategy"]) {
            c.strategy = LoadStrategy::parse(s)?;
        }
        if let Some(s) = v.str_at(&["backend"]) {
            c.backend = Backend::parse(s)?;
        }
        let b = |k: &str, d: bool| v.get(k).and_then(|x| x.as_bool()).unwrap_or(d);
        c.sparse_ffn = b("sparse_ffn", false);
        c.hier_head = b("hier_head", false);
        c.emb_cache = b("emb_cache", false);
        c.emb_cache_capacity = v.f64_at(&["emb_cache_capacity"]).unwrap_or(0.0) as usize;
        c.hh_p_min = v.f64_at(&["hh_p_min"]).unwrap_or(0.0) as f32;
        c.prefill_chunk = v.f64_at(&["prefill_chunk"]).unwrap_or(8.0) as usize;
        c.prefetch = b("prefetch", true);
        c.threads = v.f64_at(&["threads"]).unwrap_or(0.0) as usize;
        if let Some(s) = v.str_at(&["simd"]) {
            c.simd = SimdMode::parse(s)?;
        }
        c.state_cache_mb = v.f64_at(&["state_cache_mb"]).unwrap_or(0.0) as usize;
        c.state_file = v
            .str_at(&["state_file"])
            .filter(|s| !s.is_empty())
            .map(PathBuf::from);
        c.max_queue = v.f64_at(&["max_queue"]).unwrap_or(64.0) as usize;
        c.max_concurrency = v.f64_at(&["max_concurrency"]).unwrap_or(0.0) as usize;
        c.max_prompt_tokens = v.f64_at(&["max_prompt_tokens"]).unwrap_or(0.0) as usize;
        c.deadline_ms = v.f64_at(&["deadline_ms"]).unwrap_or(0.0) as u64;
        c.drain_ms = v.f64_at(&["drain_ms"]).unwrap_or(5000.0) as u64;
        c.metrics_endpoint = b("metrics_endpoint", true);
        c.trace_out = v
            .str_at(&["trace_out"])
            .filter(|s| !s.is_empty())
            .map(PathBuf::from);
        c.seed = v.f64_at(&["seed"]).unwrap_or(0.0) as u64;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let mut c = EngineConfig::all_techniques("rwkv-ours-small", PathBuf::from("artifacts"));
        c.strategy = LoadStrategy::Layerwise;
        c.threads = 4;
        c.prefetch = false;
        c.state_cache_mb = 64;
        c.state_file = Some(PathBuf::from("cache.rwst"));
        c.max_queue = 7;
        c.max_concurrency = 3;
        c.max_prompt_tokens = 4096;
        c.deadline_ms = 1500;
        c.drain_ms = 250;
        c.simd = SimdMode::Scalar;
        c.metrics_endpoint = false;
        c.trace_out = Some(PathBuf::from("trace.jsonl"));
        let v = c.to_json();
        let c2 = EngineConfig::from_json(&v).unwrap();
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.strategy, c.strategy);
        assert_eq!(c2.threads, 4);
        assert!(!c2.prefetch, "prefetch=false must survive the round trip");
        assert!(c2.sparse_ffn && c2.hier_head && c2.emb_cache);
        assert_eq!(c2.state_cache_mb, 64);
        assert_eq!(c2.state_file, Some(PathBuf::from("cache.rwst")));
        assert_eq!(c2.max_queue, 7);
        assert_eq!(c2.max_concurrency, 3);
        assert_eq!(c2.max_prompt_tokens, 4096);
        assert_eq!(c2.deadline_ms, 1500);
        assert_eq!(c2.drain_ms, 250);
        assert_eq!(c2.simd, SimdMode::Scalar);
        assert!(!c2.metrics_endpoint, "metrics_endpoint=false must survive the round trip");
        assert_eq!(c2.trace_out, Some(PathBuf::from("trace.jsonl")));
    }

    #[test]
    fn observability_defaults() {
        let c = EngineConfig::default();
        assert!(c.metrics_endpoint, "/metrics is on by default for the serve path");
        assert!(c.trace_out.is_none());
        // absent keys (older config JSON) keep the defaults; an empty
        // trace_out string means "none"
        let c = EngineConfig::from_json(&json::obj(vec![])).unwrap();
        assert!(c.metrics_endpoint);
        assert!(c.trace_out.is_none());
    }

    #[test]
    fn simd_defaults_auto() {
        assert_eq!(EngineConfig::default().simd, SimdMode::Auto);
        // absent key (older config JSON) keeps the default
        let c = EngineConfig::from_json(&json::obj(vec![])).unwrap();
        assert_eq!(c.simd, SimdMode::Auto);
        for (s, m) in [
            ("auto", SimdMode::Auto),
            ("scalar", SimdMode::Scalar),
            ("neon", SimdMode::Neon),
            ("avx2", SimdMode::Avx2),
        ] {
            assert_eq!(SimdMode::parse(s).unwrap(), m);
            assert_eq!(m.name(), s);
        }
        assert!(SimdMode::Auto.requested().is_none());
        assert_eq!(
            SimdMode::Neon.requested(),
            Some(crate::tensor::SimdBackend::Neon)
        );
    }

    #[test]
    fn admission_defaults() {
        let c = EngineConfig::default();
        assert_eq!(c.max_queue, 64, "bounded admission is on by default");
        assert_eq!(c.max_concurrency, 0);
        assert_eq!(c.max_prompt_tokens, 0);
        assert_eq!(c.deadline_ms, 0);
        assert_eq!(c.drain_ms, 5000);
        // absent keys (older config JSON) keep the defaults
        let c = EngineConfig::from_json(&json::obj(vec![])).unwrap();
        assert_eq!(c.max_queue, 64);
        assert_eq!(c.deadline_ms, 0);
        assert_eq!(c.drain_ms, 5000);
    }

    #[test]
    fn state_cache_defaults_off() {
        let c = EngineConfig::default();
        assert_eq!(c.state_cache_mb, 0);
        assert!(c.state_file.is_none());
        // absent keys (older config JSON) keep the defaults; an empty
        // state_file string means "none"
        let c = EngineConfig::from_json(&json::obj(vec![])).unwrap();
        assert_eq!(c.state_cache_mb, 0);
        assert!(c.state_file.is_none());
    }

    #[test]
    fn prefetch_defaults_on() {
        assert!(EngineConfig::default().prefetch);
        // absent key (older config JSON) keeps the default
        let c = EngineConfig::from_json(&json::obj(vec![])).unwrap();
        assert!(c.prefetch);
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(LoadStrategy::parse("bogus").is_err());
        assert!(Backend::parse("gpu").is_err());
        assert!(SimdMode::parse("sse2").is_err());
    }
}
