//! TCP serving front-end (S22): newline-delimited JSON protocol.
//!
//! Request:  {"prompt": "<text>", "max_tokens": 32, "temperature": 0.8,
//!            "top_p": 0.95, "stop": ["word", ...],
//!            "stop_seqs": ["multi word phrase", ...], "seed": 7,
//!            "cache": true, "deadline_ms": 2000}
//!           (`stop` words / `stop_seqs` phrases are vocab-encoded into
//!           stop token ids / sequences; unknown words are rejected with
//!           an error line.  `seed` pins the sampler for cross-run
//!           determinism — omitted, the request id seeds it; valid seeds
//!           are integers in [0, 2^53), anything else is treated as
//!           absent since JSON numbers are f64.  `cache: false` opts the
//!           request out of the prefix-state cache when the server runs
//!           one — see `--state-cache-mb`.  `deadline_ms` bounds the
//!           request's wall time from admission; `--deadline-ms` sets the
//!           server default.  Numeric fields are validated: negative/NaN
//!           `max_tokens`/`temperature`/`top_p`/`deadline_ms` get a
//!           structured error line instead of silently casting.)
//! Response: {"token": "<word>"} per generated token, then ONE terminal
//!           line —
//!           {"done": true, "tokens": n, "seconds": s, "tps": r,
//!            "reason": "length"|"stop"|"cancelled"|"deadline",
//!            "cached_tokens": c, "queue_secs": q, "ttft_secs": t}
//!           on success (`cached_tokens` = prompt feed tokens whose
//!           prefill was skipped by forking a cached prefix state;
//!           `queue_secs` = admission queue wait; `ttft_secs` = time to
//!           first token, omitted when the request retired before
//!           emitting), or
//!           {"error": "overloaded", "retry_after_ms": m}
//!           when bounded admission sheds the request (429 semantics;
//!           also "prompt_too_long" / "shutting_down"), or
//!           {"error": msg, "tokens": n, "seconds": s, "reason": r}
//!           when the engine failed mid-request — the error line carries
//!           the final token/latency accounting.
//!
//! The full protocol (request fields, response lines, error shapes) is
//! documented in `docs/serving.md` together with every CLI flag.
//!
//! Thread-per-connection feeding the single coordinator (which owns the
//! engine and advances all connections' sessions in fused rounds; the
//! engine's compute pool — the `--threads` knob, `"threads"` in the
//! serialized `EngineConfig` JSON — parallelizes each round across
//! cores).  Connection threads are reaped as they finish (no JoinHandle
//! leak on long-running servers) and `--max-connections` caps concurrent
//! clients — excess connections get a structured `too_many_connections`
//! line and are closed before touching the engine.  A dropped
//! connection cancels its session: the coordinator sees the dead stream
//! and retires the slot instead of decoding into the void.  A shutdown
//! flag ([`ServeOptions::shutdown`], flipped by the CLI's SIGINT/SIGTERM
//! handler) stops the accept loop so the coordinator can drain.
//!
//! Observability scrape ([`ServeOptions::metrics_endpoint`], the
//! `--metrics` CLI knob): a connection whose FIRST line is an HTTP GET is
//! answered over the same port with a minimal HTTP/1.0 response and
//! closed — `GET /metrics` returns the coordinator registry in Prometheus
//! text exposition format (counters, gauges, latency histograms), `GET
//! /stats` returns the same registry as a JSON summary (p50/p90/p99/max
//! per histogram).  With the knob off (the default for embedded uses),
//! GETs get a 404 and the line protocol is unchanged.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, Event, RejectReason, Request};
use crate::json::{self, Value};
use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::Arc;
use crate::text::Vocab;

/// Hard per-request line cap: a client (or garbage traffic) streaming an
/// endless line without a newline would otherwise grow the read buffer
/// unboundedly.  Past the cap the connection gets a structured error and
/// is closed (the line has no frame boundary left to resynchronize on).
const MAX_LINE_BYTES: u64 = 1 << 20;

/// Accept-loop knobs for [`Server::serve`].
#[derive(Clone, Default)]
pub struct ServeOptions {
    /// Stop after accepting this many connections in total (used by
    /// tests/examples for clean shutdown); `None` = serve forever.
    pub max_total_conns: Option<usize>,
    /// Concurrent connection cap (`0` = unlimited): connections past the
    /// cap receive `{"error":"too_many_connections",...}` and are closed.
    pub max_connections: usize,
    /// Cooperative shutdown: when the flag flips true the accept loop
    /// stops taking connections and `serve` returns after joining the
    /// in-flight connection threads.
    pub shutdown: Option<Arc<AtomicBool>>,
    /// Serve `GET /metrics` (Prometheus text) + `GET /stats` (JSON) on
    /// this port (the `--metrics` knob; `false` = GETs get a 404).
    pub metrics_endpoint: bool,
}

pub struct Server {
    pub coordinator: Arc<Coordinator>,
    pub vocab: Arc<Vocab>,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(coordinator: Coordinator, vocab: Vocab) -> Self {
        Self {
            coordinator: Arc::new(coordinator),
            vocab: Arc::new(vocab),
            next_id: AtomicU64::new(1),
        }
    }

    /// Accept connections until the shutdown flag flips (or
    /// `max_total_conns` is reached).  Finished connection threads are
    /// reaped continuously — a long-running server holds one JoinHandle
    /// per LIVE connection, not per connection ever served.
    pub fn serve(self: Arc<Self>, addr: &str, opts: ServeOptions) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        // non-blocking accept so the loop can observe the shutdown flag
        listener.set_nonblocking(true).context("listener nonblocking")?;
        eprintln!("[server] listening on {addr}");
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let active = Arc::new(AtomicUsize::new(0));
        let mut served = 0usize;
        loop {
            if let Some(flag) = opts.shutdown.as_ref() {
                if flag.load(Ordering::Acquire) {
                    eprintln!("[server] shutdown: no longer accepting connections");
                    break;
                }
            }
            // reap: drop handles of connections that already hung up
            handles.retain(|h| !h.is_finished());
            match listener.accept() {
                Ok((mut stream, _peer)) => {
                    // the accepted socket must block: per-connection
                    // threads read/write it synchronously
                    stream.set_nonblocking(false).context("stream blocking")?;
                    if opts.max_connections > 0
                        && active.load(Ordering::Acquire) >= opts.max_connections
                    {
                        // over the concurrent cap: structured refusal,
                        // closed without touching the engine
                        let _ = writeln!(
                            stream,
                            r#"{{"error":"too_many_connections","retry_after_ms":1000}}"#
                        );
                        continue;
                    }
                    let me = Arc::clone(&self);
                    let counter = Arc::clone(&active);
                    let metrics_endpoint = opts.metrics_endpoint;
                    counter.fetch_add(1, Ordering::AcqRel);
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = me.handle_conn(stream, metrics_endpoint) {
                            eprintln!("[server] connection error: {e:#}");
                        }
                        counter.fetch_sub(1, Ordering::AcqRel);
                    }));
                    served += 1;
                    if let Some(m) = opts.max_total_conns {
                        if served >= m {
                            break;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    fn handle_conn(&self, stream: TcpStream, metrics_endpoint: bool) -> Result<()> {
        let _peer = stream.peer_addr()?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut line = String::new();
        loop {
            line.clear();
            // bounded read: `take` stops a newline-less flood at the cap
            let n = (&mut reader).take(MAX_LINE_BYTES).read_line(&mut line)?;
            if n == 0 {
                return Ok(()); // client closed
            }
            if n as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
                writeln!(writer, r#"{{"error":"request line exceeds {} bytes"}}"#, MAX_LINE_BYTES)?;
                return Ok(());
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(path) = trimmed.strip_prefix("GET ") {
                // HTTP scrape sharing the line-protocol port: answer one
                // request, close (HTTP/1.0 semantics — curl and
                // Prometheus both handle close-delimited bodies)
                let path = path.split_whitespace().next().unwrap_or("/");
                return self.handle_scrape(&mut reader, &mut writer, path, metrics_endpoint);
            }
            let v = match json::parse(trimmed) {
                Ok(v) => v,
                Err(e) => {
                    writeln!(writer, r#"{{"error":"bad request: {e}"}}"#)?;
                    continue;
                }
            };
            let req = match self.build_request(&v) {
                Ok(r) => r,
                Err(msg) => {
                    let msg = json::obj(vec![("error", json::s(&msg))]);
                    writeln!(writer, "{}", msg.to_string())?;
                    continue;
                }
            };
            let rx = self.coordinator.submit(req);
            // Wire contract: EVERY request's stream ends with exactly one
            // terminal line.  A mid-request engine failure arrives as
            // Error followed by a Done carrying the final counts; the two
            // merge into one terminal error line so clients never lose
            // the token/latency accounting.
            let mut pending_err: Option<String> = None;
            let mut terminal = false;
            for ev in rx {
                match ev {
                    Event::Token { token } => {
                        let msg = json::obj(vec![("token", json::s(self.vocab.word(token)))]);
                        writeln!(writer, "{}", msg.to_string())?;
                    }
                    Event::Done { tokens, seconds, reason, cached_tokens, queue_secs, ttft_secs } => {
                        let msg = match pending_err.take() {
                            Some(err) => json::obj(vec![
                                ("error", json::s(&err)),
                                ("tokens", json::num(tokens as f64)),
                                ("seconds", json::num(seconds)),
                                ("reason", json::s(reason.name())),
                            ]),
                            None => {
                                let mut fields = vec![
                                    ("done", Value::Bool(true)),
                                    ("tokens", json::num(tokens as f64)),
                                    ("seconds", json::num(seconds)),
                                    ("tps", json::num(tokens as f64 / seconds.max(1e-9))),
                                    ("reason", json::s(reason.name())),
                                    ("cached_tokens", json::num(cached_tokens as f64)),
                                    ("queue_secs", json::num(queue_secs)),
                                ];
                                // omitted (not null) when nothing was
                                // emitted — absence == "no first token"
                                if let Some(t) = ttft_secs {
                                    fields.push(("ttft_secs", json::num(t)));
                                }
                                json::obj(fields)
                            }
                        };
                        writeln!(writer, "{}", msg.to_string())?;
                        terminal = true;
                        break;
                    }
                    Event::Error { message } => {
                        // hold it: the coordinator follows with a Done
                        // carrying final counts (merged above)
                        pending_err = Some(message);
                    }
                    Event::Rejected { reason, retry_after_ms } => {
                        let mut fields = vec![
                            ("error", json::s(reason.wire_name())),
                            ("retry_after_ms", json::num(retry_after_ms as f64)),
                        ];
                        if let RejectReason::PromptTooLong { tokens, limit } = &reason {
                            fields.push((
                                "detail",
                                json::s(&format!("prompt {tokens} tokens > limit {limit}")),
                            ));
                        }
                        let msg = json::obj(fields);
                        writeln!(writer, "{}", msg.to_string())?;
                        terminal = true;
                        break;
                    }
                }
            }
            if !terminal {
                // the stream closed without a Done (e.g. the engine never
                // loaded): still emit one terminal line
                let err = pending_err.unwrap_or_else(|| "stream closed".into());
                let msg = json::obj(vec![("error", json::s(&err))]);
                writeln!(writer, "{}", msg.to_string())?;
            }
        }
    }

    /// Answer one HTTP GET on the line-protocol port and close.  The
    /// remaining request headers are drained (bounded) so the client's
    /// write never sees a reset before the response lands.
    fn handle_scrape(
        &self,
        reader: &mut BufReader<TcpStream>,
        writer: &mut TcpStream,
        path: &str,
        enabled: bool,
    ) -> Result<()> {
        let mut hdr = String::new();
        loop {
            hdr.clear();
            let n = (&mut *reader).take(MAX_LINE_BYTES).read_line(&mut hdr)?;
            if n == 0 || hdr.trim().is_empty() {
                break; // end of headers (or client half-closed)
            }
        }
        let (status, content_type, body) = if !enabled {
            ("404 Not Found", "text/plain; charset=utf-8", "metrics endpoint disabled\n".to_string())
        } else {
            match path {
                "/metrics" => (
                    "200 OK",
                    // the Prometheus text exposition content type
                    "text/plain; version=0.0.4; charset=utf-8",
                    self.coordinator.metrics.render_prometheus(),
                ),
                "/stats" => (
                    "200 OK",
                    "application/json",
                    {
                        let mut b = self.coordinator.metrics.stats_json().to_string();
                        b.push('\n');
                        b
                    },
                ),
                _ => ("404 Not Found", "text/plain; charset=utf-8", "unknown path\n".to_string()),
            }
        };
        write!(
            writer,
            "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )?;
        writer.flush()?;
        Ok(())
    }

    /// Parse + validate one request line.  `Err(message)` becomes a
    /// structured `{"error": message}` reply — out-of-range numerics are
    /// rejected here instead of silently casting through `as usize` /
    /// `as f32`.
    fn build_request(&self, v: &Value) -> std::result::Result<Request, String> {
        let prompt_text = v.str_at(&["prompt"]).unwrap_or("").to_string();
        let max_tokens = match v.f64_at(&["max_tokens"]) {
            None => 32,
            Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= 1e9 => x as usize,
            Some(x) => return Err(format!("invalid max_tokens {x}: need an integer in [0, 1e9]")),
        };
        let temperature = match v.f64_at(&["temperature"]) {
            None => 0.0,
            Some(x) if x.is_finite() && x >= 0.0 => x as f32,
            Some(x) => return Err(format!("invalid temperature {x}: need a finite number >= 0")),
        };
        let top_p = match v.f64_at(&["top_p"]) {
            None => 1.0,
            Some(x) if x.is_finite() && x > 0.0 && x <= 1.0 => x as f32,
            Some(x) => return Err(format!("invalid top_p {x}: need a number in (0, 1]")),
        };
        let deadline_ms = match v.f64_at(&["deadline_ms"]) {
            None => None,
            Some(x) if x.is_finite() && x > 0.0 && x.fract() == 0.0 && x <= 1e12 => {
                Some(x as u64)
            }
            Some(x) => {
                return Err(format!("invalid deadline_ms {x}: need an integer in (0, 1e12]"))
            }
        };
        let stop_words: Vec<&str> = v
            .get("stop")
            .and_then(|s| s.as_arr())
            .map(|ws| ws.iter().filter_map(|w| w.as_str()).collect())
            .unwrap_or_default();
        let stop_tokens = self.vocab.stop_token_ids(stop_words).map_err(|e| e.to_string())?;
        // multi-token stop sequences: each phrase encodes to a token
        // sequence; rejection policy matches single stop words
        let stop_phrases: Vec<&str> = v
            .get("stop_seqs")
            .and_then(|s| s.as_arr())
            .map(|ps| ps.iter().filter_map(|p| p.as_str()).collect())
            .unwrap_or_default();
        let stop_sequences = stop_phrases
            .iter()
            .map(|p| self.vocab.stop_seq_ids(p))
            .collect::<anyhow::Result<Vec<_>>>()
            .map_err(|e| e.to_string())?;
        Ok(Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            prompt: self.vocab.encode(&prompt_text),
            max_tokens,
            temperature,
            top_p,
            stop_tokens,
            stop_sequences,
            // only integers in [0, 2^53) round-trip exactly through
            // JSON f64; anything else is treated as absent rather than
            // silently saturating/truncating into seed collisions
            seed: v
                .f64_at(&["seed"])
                .filter(|&s| s >= 0.0 && s < 9007199254740992.0 && s.fract() == 0.0)
                .map(|s| s as u64),
            // per-request opt-out of the prefix-state cache (a no-op
            // when the server runs without one)
            cache: v.get("cache").and_then(|c| c.as_bool()).unwrap_or(true),
            deadline_ms,
        })
    }
}

/// Minimal blocking HTTP GET against the server's scrape endpoints —
/// returns `(status_code, body)`.  Tests, the CI smoke, and ad-hoc
/// debugging use this instead of needing curl on the box.
pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    write!(stream, "GET {path} HTTP/1.0\r\n\r\n")?;
    stream.flush()?;
    let mut raw = String::new();
    BufReader::new(stream).read_to_string(&mut raw)?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response (no header terminator)"))?;
    let status = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP status line"))?;
    Ok((status, body.to_string()))
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: TcpStream,
}

#[derive(Debug, Default, Clone)]
pub struct Completion {
    pub text: String,
    pub tokens: usize,
    pub seconds: f64,
    pub tps: f64,
    /// Finish reason wire name ("length" | "stop" | "cancelled" |
    /// "deadline").
    pub reason: String,
    /// Prompt feed tokens served from the prefix-state cache (0 when the
    /// server runs without one or the prefix was cold).
    pub cached_tokens: usize,
    /// Admission queue wait in seconds.
    pub queue_secs: f64,
    /// Time to first token (`None` when the request retired before
    /// emitting anything).
    pub ttft_secs: Option<f64>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    pub fn complete(&mut self, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Completion> {
        let req = json::obj(vec![
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ]);
        let lines = self.request_raw(&req.to_string())?;
        let mut out = Completion::default();
        for line in &lines {
            let v = json::parse(line)?;
            if let Some(tok) = v.str_at(&["token"]) {
                if !out.text.is_empty() {
                    out.text.push(' ');
                }
                out.text.push_str(tok);
            } else if v.get("done").is_some() {
                out.tokens = v.f64_at(&["tokens"]).unwrap_or(0.0) as usize;
                out.seconds = v.f64_at(&["seconds"]).unwrap_or(0.0);
                out.tps = v.f64_at(&["tps"]).unwrap_or(0.0);
                out.reason = v.str_at(&["reason"]).unwrap_or("").to_string();
                out.cached_tokens = v.f64_at(&["cached_tokens"]).unwrap_or(0.0) as usize;
                out.queue_secs = v.f64_at(&["queue_secs"]).unwrap_or(0.0);
                out.ttft_secs = v.f64_at(&["ttft_secs"]);
            } else if let Some(e) = v.str_at(&["error"]) {
                anyhow::bail!("server error: {e}");
            }
        }
        Ok(out)
    }

    /// Send one raw request line and collect raw response lines through
    /// the terminal line (one carrying `done` or `error`) — the overload
    /// / deadline / fault tests inspect wire shapes directly.
    pub fn request_raw(&mut self, req_line: &str) -> Result<Vec<String>> {
        writeln!(self.stream, "{req_line}")?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut lines = Vec::new();
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let text = line.trim().to_string();
            if text.is_empty() {
                continue;
            }
            let terminal = json::parse(&text)
                .map(|v| v.get("done").is_some() || v.get("error").is_some())
                .unwrap_or(false);
            lines.push(text);
            if terminal {
                break;
            }
        }
        Ok(lines)
    }
}
