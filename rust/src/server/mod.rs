//! TCP serving front-end (S22): newline-delimited JSON protocol.
//!
//! Request:  {"prompt": "<text>", "max_tokens": 32, "temperature": 0.8,
//!            "top_p": 0.95, "stop": ["word", ...],
//!            "stop_seqs": ["multi word phrase", ...], "seed": 7,
//!            "cache": true}
//!           (`stop` words / `stop_seqs` phrases are vocab-encoded into
//!           stop token ids / sequences; unknown words are rejected with
//!           an error line.  `seed` pins the sampler for cross-run
//!           determinism — omitted, the request id seeds it; valid seeds
//!           are integers in [0, 2^53), anything else is treated as
//!           absent since JSON numbers are f64.  `cache: false` opts the
//!           request out of the prefix-state cache when the server runs
//!           one — see `--state-cache-mb`)
//! Response: {"token": "<word>"} per generated token, then
//!           {"done": true, "tokens": n, "seconds": s, "tps": r,
//!            "reason": "length"|"stop"|"cancelled", "cached_tokens": c}
//!           (`cached_tokens` = prompt feed tokens whose prefill was
//!           skipped by forking a cached prefix state)
//!
//! The full protocol (request fields, response lines, error shapes) is
//! documented in `docs/serving.md` together with every CLI flag.
//!
//! Thread-per-connection feeding the single coordinator (which owns the
//! engine and advances all connections' sessions in fused rounds; the
//! engine's compute pool — the `--threads` knob, `"threads"` in the
//! serialized `EngineConfig` JSON — parallelizes each round across
//! cores).  A dropped
//! connection cancels its session: the coordinator sees the dead stream
//! and retires the slot instead of decoding into the void.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, Event, Request};
use crate::json::{self, Value};
use crate::text::Vocab;

pub struct Server {
    pub coordinator: Arc<Coordinator>,
    pub vocab: Arc<Vocab>,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(coordinator: Coordinator, vocab: Vocab) -> Self {
        Self {
            coordinator: Arc::new(coordinator),
            vocab: Arc::new(vocab),
            next_id: AtomicU64::new(1),
        }
    }

    /// Serve forever (or until `max_conns` connections when Some — used by
    /// tests/examples for clean shutdown).
    pub fn serve(self: Arc<Self>, addr: &str, max_conns: Option<usize>) -> Result<()> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        eprintln!("[server] listening on {addr}");
        let mut handles = Vec::new();
        let mut served = 0usize;
        for stream in listener.incoming() {
            let stream = stream?;
            let me = Arc::clone(&self);
            handles.push(std::thread::spawn(move || {
                if let Err(e) = me.handle_conn(stream) {
                    eprintln!("[server] connection error: {e:#}");
                }
            }));
            served += 1;
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    fn handle_conn(&self, stream: TcpStream) -> Result<()> {
        let _peer = stream.peer_addr()?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Ok(()); // client closed
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let v = match json::parse(trimmed) {
                Ok(v) => v,
                Err(e) => {
                    writeln!(writer, r#"{{"error":"bad request: {e}"}}"#)?;
                    continue;
                }
            };
            let prompt_text = v.str_at(&["prompt"]).unwrap_or("").to_string();
            let stop_words: Vec<&str> = v
                .get("stop")
                .and_then(|s| s.as_arr())
                .map(|ws| ws.iter().filter_map(|w| w.as_str()).collect())
                .unwrap_or_default();
            let stop_tokens = match self.vocab.stop_token_ids(stop_words) {
                Ok(t) => t,
                Err(e) => {
                    let msg = json::obj(vec![("error", json::s(&e.to_string()))]);
                    writeln!(writer, "{}", msg.to_string())?;
                    continue;
                }
            };
            // multi-token stop sequences: each phrase encodes to a token
            // sequence; rejection policy matches single stop words
            let stop_phrases: Vec<&str> = v
                .get("stop_seqs")
                .and_then(|s| s.as_arr())
                .map(|ps| ps.iter().filter_map(|p| p.as_str()).collect())
                .unwrap_or_default();
            let stop_sequences = match stop_phrases
                .iter()
                .map(|p| self.vocab.stop_seq_ids(p))
                .collect::<anyhow::Result<Vec<_>>>()
            {
                Ok(seqs) => seqs,
                Err(e) => {
                    let msg = json::obj(vec![("error", json::s(&e.to_string()))]);
                    writeln!(writer, "{}", msg.to_string())?;
                    continue;
                }
            };
            let req = Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                prompt: self.vocab.encode(&prompt_text),
                max_tokens: v.f64_at(&["max_tokens"]).unwrap_or(32.0) as usize,
                temperature: v.f64_at(&["temperature"]).unwrap_or(0.0) as f32,
                top_p: v.f64_at(&["top_p"]).unwrap_or(1.0) as f32,
                stop_tokens,
                stop_sequences,
                // only integers in [0, 2^53) round-trip exactly through
                // JSON f64; anything else is treated as absent rather than
                // silently saturating/truncating into seed collisions
                seed: v
                    .f64_at(&["seed"])
                    .filter(|&s| s >= 0.0 && s < 9007199254740992.0 && s.fract() == 0.0)
                    .map(|s| s as u64),
                // per-request opt-out of the prefix-state cache (a no-op
                // when the server runs without one)
                cache: v.get("cache").and_then(|c| c.as_bool()).unwrap_or(true),
            };
            let rx = self.coordinator.submit(req);
            for ev in rx {
                match ev {
                    Event::Token { token } => {
                        let msg = json::obj(vec![("token", json::s(self.vocab.word(token)))]);
                        writeln!(writer, "{}", msg.to_string())?;
                    }
                    Event::Done { tokens, seconds, reason, cached_tokens } => {
                        let msg = json::obj(vec![
                            ("done", Value::Bool(true)),
                            ("tokens", json::num(tokens as f64)),
                            ("seconds", json::num(seconds)),
                            ("tps", json::num(tokens as f64 / seconds.max(1e-9))),
                            ("reason", json::s(reason.name())),
                            ("cached_tokens", json::num(cached_tokens as f64)),
                        ]);
                        writeln!(writer, "{}", msg.to_string())?;
                        break;
                    }
                    Event::Error { message } => {
                        let msg = json::obj(vec![("error", json::s(&message))]);
                        writeln!(writer, "{}", msg.to_string())?;
                        break;
                    }
                }
            }
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: TcpStream,
}

#[derive(Debug, Default, Clone)]
pub struct Completion {
    pub text: String,
    pub tokens: usize,
    pub seconds: f64,
    pub tps: f64,
    /// Finish reason wire name ("length" | "stop" | "cancelled").
    pub reason: String,
    /// Prompt feed tokens served from the prefix-state cache (0 when the
    /// server runs without one or the prefix was cold).
    pub cached_tokens: usize,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    pub fn complete(&mut self, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Completion> {
        let req = json::obj(vec![
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ]);
        writeln!(self.stream, "{}", req.to_string())?;
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut out = Completion::default();
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            let v = json::parse(line.trim())?;
            if let Some(tok) = v.str_at(&["token"]) {
                if !out.text.is_empty() {
                    out.text.push(' ');
                }
                out.text.push_str(tok);
            } else if v.get("done").is_some() {
                out.tokens = v.f64_at(&["tokens"]).unwrap_or(0.0) as usize;
                out.seconds = v.f64_at(&["seconds"]).unwrap_or(0.0);
                out.tps = v.f64_at(&["tps"]).unwrap_or(0.0);
                out.reason = v.str_at(&["reason"]).unwrap_or("").to_string();
                out.cached_tokens = v.f64_at(&["cached_tokens"]).unwrap_or(0.0) as usize;
                break;
            } else if let Some(e) = v.str_at(&["error"]) {
                anyhow::bail!("server error: {e}");
            }
        }
        Ok(out)
    }
}
