//! RWKV-Lite: deeply compressed RWKV inference for resource-constrained
//! devices — rust coordinator + runtime (L3 of the three-layer stack).
//!
//! Reproduction of: Choe, Ji, Lin, *"RWKV-Lite: Deeply Compressed RWKV for
//! Resource-Constrained Devices"* (2024).  See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for the paper-vs-measured results.
//!
//! Layer map:
//! * [`tensor`] — f32/f16/int8/1-bit matvec + multi-vector matmat kernels
//!   (the ARM-NEON-kernel analog; §4 of the paper) and small math ops.
//! * [`io`] — the `.rkv` checkpoint format (mmap reader) + JSON manifests.
//! * [`engine`] — the inference engine: weight store with loading
//!   strategies, sparse FFN (§3.2), hierarchical head (§3.3), embedding
//!   cache (§3.3), native and XLA/PJRT backends.
//! * [`engine::session`] — the serving surface: a `Session` owns state +
//!   sampler + generation params; `RwkvEngine::step_round` advances any
//!   mix of chunked-prefill and decode sessions through ONE
//!   weight-streaming pass, sampling and stop-checking inside the round.
//! * [`runtime`] — PJRT wrapper executing the AOT-lowered HLO components
//!   (L2 jax + L1 Pallas, compiled at `make artifacts` time).
//! * [`coordinator`] — request router + dynamic batcher + the round loop
//!   over sessions; `submit` returns a cancellable `RequestHandle`.
//! * [`server`] — a small TCP serving front-end (edge deployment demo).
//! * [`exp`] — drivers that regenerate every table/figure of the paper,
//!   riding the same session rounds as the serving stack.

// Kernel-style code: indexed loops are deliberate (they are the shapes
// LLVM auto-vectorizes) and hot-path functions thread several scratch
// buffers to stay allocation-free.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]
// Unsafe discipline (docs/correctness.md): every `unsafe` block carries a
// `// SAFETY:` contract, unsafe fns may not silently nest unsafe ops, and
// raw `std::sync` primitives are forbidden outside `crate::sync`
// (clippy.toml) so the loom build models the real code.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]
#![warn(clippy::disallowed_types)]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod engine;
pub mod evalsuite;
pub mod exp;
pub mod io;
pub mod json;
pub mod metrics;
pub mod pool;
pub mod runtime;
pub mod server;
pub mod sync;
pub mod tensor;
pub mod testutil;
pub mod text;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
