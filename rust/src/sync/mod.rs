//! Synchronization shim: the ONE place the crate names `std::sync`
//! primitives (enforced by `clippy.toml`'s `disallowed-types` list).
//!
//! Under the normal build this module is a zero-cost re-export of
//! `std::sync`.  Under `RUSTFLAGS="--cfg loom"` (the CI `loom` job) the
//! lock/condvar/atomic types come from [loom], whose model checker
//! exhaustively explores thread interleavings of the unit tests named
//! `loom_*` — see `docs/correctness.md`.  Code that wants to be
//! model-checked must go through `crate::sync`, never `std::sync`.
//!
//! Deliberate exceptions (documented here so the shim's boundary is the
//! whole story):
//!
//! * **`Arc`** is always `std::sync::Arc`.  The modeled protocols (latch,
//!   task slot, admission gate) do not rely on `Arc`'s reclamation
//!   ordering, and keeping one `Arc` type means the engine's pervasive
//!   `Arc<WeightStore>` / `Arc<Mat>` plumbing is identical under both
//!   cfgs.
//! * **`mpsc`** is always `std::sync::mpsc` — loom has no channel model.
//!   The pool's worker dispatch channel is therefore *not* model-checked;
//!   the latch/task-slot protocols layered on top of it are, and they are
//!   where the raw-pointer hand-offs live.
//! * **`OnceLock`** (the f16 decode table) stays `std::sync::OnceLock`:
//!   pure lazily-computed data, no cross-thread protocol.
//! * **`tensor::simd`'s backend selector** is a `std::sync::atomic`
//!   `AtomicU8` under loom too (loom atomics cannot const-initialize a
//!   `static`): a single configuration byte written once at engine load,
//!   read by kernels — no cross-thread protocol to model, and every
//!   backend it can select is bit-identical anyway.
//!
//! The loom build only compiles the library's unit-test target
//! (`cargo test --lib` with `--cfg loom`); the binaries keep using the
//! std types via this same path, which is why `static` atomics in
//! `main.rs` still const-initialize.

#![allow(clippy::disallowed_types)]

pub use std::sync::mpsc;
pub use std::sync::Arc;

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, RwLock};

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, RwLock};

/// Atomic integer/bool types plus `Ordering`, swapped wholesale under
/// loom.  Import as `crate::sync::atomic::{AtomicUsize, Ordering}`.
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };

    #[cfg(loom)]
    pub use loom::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
    };
}
