//! Hand-rolled CLI argument parser (substrate S24 — no clap here).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Default, Debug)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }
}

/// Parse argv against option specs. Unknown `--options` are rejected.
pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args> {
    let mut out = Args::default();
    for s in specs {
        if let (true, Some(d)) = (s.takes_value, s.default) {
            out.values.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            let spec = specs.iter().find(|s| s.name == key);
            match spec {
                None => bail!("unknown option --{key}\n{}", usage(specs)),
                Some(s) if s.takes_value => {
                    let val = if let Some(v) = inline_val {
                        v
                    } else {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                    };
                    out.values.insert(key, val);
                }
                Some(_) => {
                    if inline_val.is_some() {
                        bail!("--{key} does not take a value");
                    }
                    out.flags.push(key);
                }
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

pub fn usage(specs: &[OptSpec]) -> String {
    let mut s = String::from("options:\n");
    for o in specs {
        let v = if o.takes_value { " <v>" } else { "" };
        let d = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{v:<8} {}{d}\n", o.name, o.help));
    }
    s
}

/// Convenience macro-free spec builder.
pub const fn opt(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, takes_value: true, default: None }
}

pub const fn opt_def(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
    OptSpec { name, help, takes_value: true, default: Some(default) }
}

pub const fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, help, takes_value: false, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positional() {
        let specs = [opt("model", "m"), flag("verbose", "v"), opt_def("n", "count", "10")];
        let a = parse(&sv(&["gen", "--model", "x", "--verbose", "--n=5", "p2"]), &specs).unwrap();
        assert_eq!(a.positional, vec!["gen", "p2"]);
        assert_eq!(a.get("model"), Some("x"));
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
    }

    #[test]
    fn default_applies() {
        let specs = [opt_def("n", "count", "10")];
        let a = parse(&sv(&[]), &specs).unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 10);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&sv(&["--bogus"]), &[]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        let specs = [opt("model", "m")];
        assert!(parse(&sv(&["--model"]), &specs).is_err());
    }
}
