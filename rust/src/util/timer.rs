//! Wall-clock stopwatch + simple stat aggregation for the bench harness.

use std::time::Instant;

pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Run `f` repeatedly for at least `min_secs` (after `warmup` calls) and
/// report per-iteration stats — the criterion-less bench substrate (S28).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_secs: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let total = Stopwatch::start();
    while total.elapsed_secs() < min_secs || samples.len() < 5 {
        let t = Stopwatch::start();
        f();
        samples.push(t.elapsed_secs());
        if samples.len() > 100_000 {
            break;
        }
    }
    let stats = BenchStats::from_samples(name, &samples);
    println!("{}", stats.row());
    stats
}

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchStats {
    pub fn from_samples(name: &str, samples: &[f64]) -> Self {
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Self {
            name: name.to_string(),
            iters: v.len(),
            mean_s: mean,
            p50_s: v[v.len() / 2],
            p95_s: v[((v.len() as f64 * 0.95) as usize).min(v.len() - 1)],
            min_s: v[0],
        }
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>10}  p50 {:>10}  p95 {:>10}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}
