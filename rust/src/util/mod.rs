//! Small shared utilities: deterministic RNG, f16 conversion, timers,
//! checked byte casts.

pub mod cast;
pub mod f16;
pub mod rng;
pub mod timer;

pub use f16::{f16_slice_to_f32, f16_to_f32, f32_to_f16};
pub use rng::XorShift;
pub use timer::Stopwatch;

/// Numerically-stable log-sum-exp.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax; returns nothing, `xs` becomes the distribution.
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of a slice (0.0 when empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Human-readable byte count (MiB with 1 decimal).
pub fn fmt_bytes(n: u64) -> String {
    if n >= 1 << 20 {
        format!("{:.1} MiB", n as f64 / (1u64 << 20) as f64)
    } else if n >= 1 << 10 {
        format!("{:.1} KiB", n as f64 / 1024.0)
    } else {
        format!("{} B", n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_matches_naive() {
        let xs = [0.5f32, -1.0, 2.0, 0.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, -5.0];
        softmax_inplace(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0] && xs[0] > xs[3]);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 0.0]), 1);
    }

    #[test]
    fn percentile_bounds() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
    }
}
