//! Checked reinterpretation of checkpoint bytes as typed slices — the ONE
//! place the crate turns `&[u8]` into `&[T]`.
//!
//! Every weight/state load used to open-code `from_raw_parts` with a
//! shape-derived length; [`cast_slice`] instead derives the element count
//! from the byte buffer itself and verifies alignment, so a corrupt or
//! truncated checkpoint can produce an `Err` but never an out-of-bounds
//! slice.  [`AlignedBytes`] backs owned copies (the Miri-friendly `Mmap`
//! double, fuzz inputs) with `u64` storage so the alignment check always
//! passes regardless of allocator behavior.

use anyhow::{bail, Result};

/// Marker for plain-old-data element types that may be reinterpreted from
/// raw little-endian checkpoint bytes.
///
/// # Safety
/// Implementors must be primitive types with no padding, no niches/invalid
/// bit patterns, and no drop glue: every `size_of::<Self>()`-byte pattern
/// is a valid value.
pub unsafe trait Pod: Copy + 'static {}

// SAFETY: u8 is a 1-byte primitive; all bit patterns are valid.
unsafe impl Pod for u8 {}
// SAFETY: i8 is a 1-byte primitive; all bit patterns are valid.
unsafe impl Pod for i8 {}
// SAFETY: u16 is a padding-free primitive; all bit patterns are valid.
unsafe impl Pod for u16 {}
// SAFETY: u32 is a padding-free primitive; all bit patterns are valid.
unsafe impl Pod for u32 {}
// SAFETY: i32 is a padding-free primitive; all bit patterns are valid.
unsafe impl Pod for i32 {}
// SAFETY: f32 is a padding-free primitive; all bit patterns are valid
// (NaN payloads included).
unsafe impl Pod for f32 {}
// SAFETY: u64 is a padding-free primitive; all bit patterns are valid.
unsafe impl Pod for u64 {}

/// View `bytes` as `&[T]`.  Errors (never UB, never a panic) if the
/// buffer is misaligned for `T` or not a whole number of elements.
pub fn cast_slice<T: Pod>(bytes: &[u8]) -> Result<&[T]> {
    let size = std::mem::size_of::<T>();
    if bytes.len() % size != 0 {
        bail!(
            "byte length {} is not a multiple of the {}-byte element size",
            bytes.len(),
            size
        );
    }
    let align = std::mem::align_of::<T>();
    if bytes.as_ptr() as usize % align != 0 {
        bail!("buffer is not {align}-byte aligned");
    }
    // SAFETY: T: Pod (any bit pattern valid, no padding, no drop glue);
    // the pointer is aligned (checked above) and the element count covers
    // exactly bytes.len() bytes inside the borrowed allocation.  The
    // returned lifetime is tied to `bytes`.
    Ok(unsafe { std::slice::from_raw_parts(bytes.as_ptr().cast::<T>(), bytes.len() / size) })
}

/// [`cast_slice`] plus a shape-derived element-count check, for callers
/// that know how many elements the tensor header promised.
pub fn cast_slice_len<T: Pod>(bytes: &[u8], expect: usize) -> Result<&[T]> {
    let s = cast_slice::<T>(bytes)?;
    if s.len() != expect {
        bail!("element count {} != expected {}", s.len(), expect);
    }
    Ok(s)
}

/// Owned byte buffer stored as `u64` words, so `cast_slice` to any
/// primitive dtype (max alignment 8) always passes the alignment check.
/// `Vec<u8>` from `fs::read` only guarantees 1-byte alignment — enough
/// for `mmap`-replacement *storage* but not for typed views.
pub struct AlignedBytes {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBytes {
    pub fn from_slice(b: &[u8]) -> Self {
        let mut words = Vec::with_capacity(b.len().div_ceil(8));
        for chunk in b.chunks(8) {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            words.push(u64::from_ne_bytes(w));
        }
        Self { words, len: b.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn bytes(&self) -> &[u8] {
        // SAFETY: `words` owns at least `len` bytes (ceil(len/8) u64s);
        // u64 has no padding and every byte of it is a valid u8; u8's
        // alignment of 1 is always satisfied.  Lifetime tied to &self.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>(), self.len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn casts_aligned_f32() {
        let raw = AlignedBytes::from_slice(&1.5f32.to_le_bytes());
        let s = cast_slice::<f32>(raw.bytes()).unwrap();
        assert_eq!(s, &[1.5]);
    }

    #[test]
    fn rejects_ragged_length() {
        let raw = AlignedBytes::from_slice(&[0u8; 7]);
        assert!(cast_slice::<f32>(raw.bytes()).is_err());
        assert!(cast_slice::<u16>(raw.bytes()).is_err());
        // u8 always works
        assert_eq!(cast_slice::<u8>(raw.bytes()).unwrap().len(), 7);
    }

    #[test]
    fn rejects_misaligned_buffer() {
        let raw = AlignedBytes::from_slice(&[0u8; 9]);
        // offset by one byte: 8 bytes remain, but the pointer is odd
        let view = &raw.bytes()[1..];
        assert!(cast_slice::<f32>(view).is_err());
    }

    #[test]
    fn length_check_catches_shape_mismatch() {
        let raw = AlignedBytes::from_slice(&[0u8; 16]);
        assert!(cast_slice_len::<f32>(raw.bytes(), 4).is_ok());
        assert!(cast_slice_len::<f32>(raw.bytes(), 5).is_err());
    }

    #[test]
    fn aligned_bytes_round_trips() {
        for n in [0usize, 1, 7, 8, 9, 64, 65] {
            let src: Vec<u8> = (0..n as u32).map(|i| (i * 37) as u8).collect();
            let a = AlignedBytes::from_slice(&src);
            assert_eq!(a.bytes(), &src[..]);
            assert_eq!(a.len(), n);
            assert_eq!(a.is_empty(), n == 0);
        }
    }
}
