//! IEEE-754 binary16 <-> binary32 conversion (no `half` crate in this
//! environment — substrate S13).  Decoding uses a lazily-built 64K lookup
//! table: the f16 matvec hot loop (engine weights are stored f16, §5.1)
//! becomes one table load per weight.

use std::sync::OnceLock;

/// Bit-exact f16 (as u16) -> f32, branch full decode.
pub fn f16_to_f32_slow(h: u16) -> f32 {
    let sign = (h >> 15) as u32;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 31 // signed zero
        } else {
            // subnormal: renormalize
            let mut e: i32 = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3ff;
            (sign << 31) | ((e as u32) << 23) | (f << 13)
        }
    } else if exp == 31 {
        (sign << 31) | (0xff << 23) | (frac << 13) // inf / nan
    } else {
        (sign << 31) | ((exp + 112) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

static TABLE: OnceLock<Vec<f32>> = OnceLock::new();

fn table() -> &'static [f32] {
    TABLE.get_or_init(|| (0..=u16::MAX).map(f16_to_f32_slow).collect())
}

/// Table-based decode (reference path; exact for all 65536 encodings).
#[inline(always)]
pub fn f16_to_f32_table(h: u16) -> f32 {
    // SAFETY: table has exactly 65536 entries; u16 cannot index out of range.
    unsafe { *table().get_unchecked(h as usize) }
}

/// Branch-free decode via the power-of-two-multiply trick — the hot-path
/// conversion (§Perf L3 iteration 1).  Exact for zeros, subnormals, and
/// normals: `from_bits((h & 0x7fff) << 13) * 2^112` scales the rebased
/// exponent exactly (multiplying by a power of two is exact in IEEE-754),
/// and f16 subnormals land in the f32 normal range.  Inf/NaN take a
/// (predictable, never-taken-for-weights) fallback branch.  Unlike the
/// table, this compiles to integer ops + one fp multiply, so LLVM can
/// vectorize matvec inner loops through it.
#[inline(always)]
pub fn f16_to_f32(h: u16) -> f32 {
    let mag = (h & 0x7fff) as u32;
    let sign = ((h & 0x8000) as u32) << 16;
    let val = f32::from_bits(mag << 13) * f32::from_bits(0x7780_0000); // * 2^112
    // inf/nan: force exponent 0xff, keep the (shifted) mantissa — selected
    // branchlessly so the conversion stays vectorizable.
    let special = 0x7f80_0000 | ((mag & 0x3ff) << 13);
    let bits = if mag >= 0x7c00 { special } else { val.to_bits() };
    f32::from_bits(bits | sign)
}

/// Weight-path decode: exact for zero/subnormal/normal, UNDEFINED for
/// inf/nan (which trained weights never contain — export clamps to f16
/// range).  Pure integer ops + one fp multiply, no select: this is the
/// form LLVM auto-vectorizes into full-width SIMD in the matvec loops.
#[inline(always)]
pub fn f16_to_f32_fast(h: u16) -> f32 {
    let mag = (h & 0x7fff) as u32;
    let sign = ((h & 0x8000) as u32) << 16;
    let val = f32::from_bits(mag << 13) * f32::from_bits(0x7780_0000); // * 2^112
    f32::from_bits(val.to_bits() | sign)
}

/// Decode a whole slice (e.g. one weight row) into `out`.
#[inline]
pub fn f16_slice_to_f32(src: &[u16], out: &mut [f32]) {
    for (o, &h) in out.iter_mut().zip(src.iter()) {
        *o = f16_to_f32(h);
    }
}

/// f32 -> f16 with round-to-nearest-even (used by tests and the embedding
/// cache write-back path).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if frac != 0 { 0x200 } else { 0 };
    }
    exp -= 127;
    if exp > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp >= -14 {
        // normal: round mantissa to 10 bits
        let mant = frac | 0x80_0000;
        let shift = 13;
        let halfway = 1u32 << (shift - 1);
        let mut m = mant >> shift;
        let rem = mant & ((1 << shift) - 1);
        if rem > halfway || (rem == halfway && (m & 1) == 1) {
            m += 1;
        }
        // m includes the implicit bit at position 10
        let e = (exp + 15) as u32;
        let out = (e << 10) + (m - (1 << 10));
        return sign | out as u16;
    }
    if exp >= -24 {
        // subnormal
        let mant = frac | 0x80_0000;
        let shift = (13 - (exp + 14)) as u32 + 1;
        let halfway = 1u32 << (shift - 1);
        let mut m = mant >> shift;
        let rem = mant & ((1 << shift) - 1);
        if rem > halfway || (rem == halfway && (m & 1) == 1) {
            m += 1;
        }
        return sign | m as u16;
    }
    sign // underflow -> zero
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_values() {
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert_eq!(f16_to_f32(0x8000), -0.0);
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0xc000), -2.0);
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert!(f16_to_f32(0x7e00).is_nan());
        // largest subnormal
        assert!((f16_to_f32(0x03ff) - 6.097555e-5).abs() < 1e-9);
    }

    #[test]
    fn table_matches_slow() {
        for h in (0..=u16::MAX).step_by(7) {
            let a = f16_to_f32_slow(h);
            let b = f16_to_f32_table(h);
            assert!(a == b || (a.is_nan() && b.is_nan()), "mismatch at {h:#06x}");
        }
    }

    #[test]
    fn fast_decode_exact_for_all_encodings() {
        // exhaustive: the multiply-trick decode must be bit-exact vs the
        // full branch decode for every one of the 65536 encodings
        for h in 0..=u16::MAX {
            let slow = f16_to_f32_slow(h);
            let fast = f16_to_f32(h);
            assert!(
                slow.to_bits() == fast.to_bits() || (slow.is_nan() && fast.is_nan()),
                "mismatch at {h:#06x}: {slow} vs {fast}"
            );
        }
    }

    #[test]
    fn round_trip_exactly_representable() {
        for x in [0.0f32, 1.0, -1.5, 0.25, 1024.0, -0.099975586] {
            let h = f32_to_f16(x);
            assert_eq!(f16_to_f32(h), x, "x={x}");
        }
    }

    #[test]
    fn round_trip_error_bounded() {
        // relative error for normal range must be <= 2^-11
        let mut x = 1e-3f32;
        while x < 1e3 {
            let back = f16_to_f32(f32_to_f16(x));
            assert!(((back - x) / x).abs() < 1.0 / 2048.0, "x={x} back={back}");
            x *= 1.37;
        }
    }

    #[test]
    fn overflow_to_inf_underflow_to_zero() {
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e9)), f32::NEG_INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e-9)), 0.0);
    }
}
