//! Deterministic xorshift64* RNG (substrate — no `rand` crate here).
//! Used by the sampler, the property-test harness, and workload generators.

#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed.max(1).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(7);
        let mut b = XorShift::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_rough_mean() {
        let mut r = XorShift::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_rough_moments() {
        let mut r = XorShift::new(2);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
