//! Memory experiments: Figure 3 (FFN sparsity), Figure 5 (accuracy vs
//! footprint), Figure 6 (breakdown by component), Table 7 (inhouse).

use anyhow::Result;

use crate::cli::Args;
use crate::config::LoadStrategy;
use crate::engine::sampler::Sampler;
use crate::engine::transformer::TransformerEngine;
use crate::engine::RwkvEngine;
use crate::evalsuite;
use crate::json::{self, Value};
use crate::metrics::Group;

use super::*;

/// Figure 3: layer-wise FFN activation sparsity of the (dense) small model
/// over a 200-token generation.
pub fn fig3(args: &Args) -> Result<()> {
    let model = args.get_or("model", "rwkv-vanilla-small");
    let n = args.usize_or("n", 200)?;
    let mut engine = RwkvEngine::load(cfg_vanilla(args, model))?;
    let prompt = corpus_prompt(args, 32)?;
    let mut sampler = Sampler::new(0.8, 0.95, 3);
    let mut state = engine.new_state();
    engine.generate(&prompt, n, &mut sampler, &mut state)?;
    title(&format!("Figure 3: FFN sparsity by layer ({model}, {n} tokens)"));
    let mut rows = Vec::new();
    for l in 0..engine.info.layers {
        let total = engine.ffn_count_by_layer[l].max(1);
        let sparsity = 1.0 - engine.ffn_active_by_layer[l] as f64 / total as f64;
        println!("layer {:>2}: sparsity {:>5.1}%  {}", l, 100.0 * sparsity,
                 "#".repeat((sparsity * 40.0) as usize));
        rows.push(json::obj(vec![
            ("layer", json::num(l as f64)),
            ("sparsity", json::num(sparsity)),
        ]));
    }
    println!("paper: 83% (bottom layers) -> 67% (top layers), small RWKV");
    save_result(args, "fig3", &Value::Arr(rows))
}

/// Figure 5: accuracy vs peak memory, RWKV-vanilla / RWKV-ours /
/// transformer baselines, full + layerwise loading.
pub fn fig5(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 60)?;
    let gen_n = args.usize_or("n", 32)?;
    title("Figure 5: accuracy & memory footprint (FP16, lambada_syn)");
    println!(
        "{:<22} {:<10} {:>9} {:>11} {:>7}",
        "model", "strategy", "acc", "peak (MiB)", "ppl"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        for (kind, ours) in [("rwkv-vanilla", false), ("rwkv-ours", true)] {
            let name = format!("{kind}-{size}");
            if !model_exists(args, &name) {
                continue;
            }
            for strategy in [LoadStrategy::Full, LoadStrategy::Layerwise] {
                let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
                let (peak, mut engine) = peak_after_generation(args, cfg, strategy, gen_n)?;
                let (acc, ppl) = lambada_acc(&mut engine, args, limit)?;
                println!(
                    "{:<22} {:<10} {:>9.3} {:>11.2} {:>7.2}",
                    name,
                    strategy.name(),
                    acc,
                    mb(peak),
                    ppl
                );
                rows.push(json::obj(vec![
                    ("model", json::s(&name)),
                    ("strategy", json::s(strategy.name())),
                    ("acc", json::num(acc)),
                    ("ppl", json::num(ppl)),
                    ("peak_bytes", json::num(peak as f64)),
                ]));
            }
        }
        // transformer baseline (full loading; KV cache excluded per paper)
        let tname = format!("gpt-{size}");
        if model_exists(args, &tname) {
            let cfg = cfg_vanilla(args, &tname);
            let mut tf = TransformerEngine::load(&cfg)?;
            let tasks = evalsuite::load_tasks(&tasks_path(args))?;
            let r = evalsuite::eval_task(&mut tf, &tasks["lambada_syn"], limit)?;
            let peak = tf.weight_bytes();
            println!(
                "{:<22} {:<10} {:>9.3} {:>11.2} {:>7.2}   (KV cache excluded)",
                tname, "full", r.acc, mb(peak), r.ppl
            );
            rows.push(json::obj(vec![
                ("model", json::s(&tname)),
                ("strategy", json::s("full")),
                ("acc", json::num(r.acc)),
                ("ppl", json::num(r.ppl)),
                ("peak_bytes", json::num(peak as f64)),
            ]));
        }
    }
    println!("\npaper: ours vs vanilla = 4x less (full), 5x less (layerwise), ~1pp acc drop");
    save_result(args, "fig5", &Value::Arr(rows))
}

/// Figure 6: peak-memory breakdown by component, full loading.
pub fn fig6(args: &Args) -> Result<()> {
    let gen_n = args.usize_or("n", 32)?;
    title("Figure 6: memory breakdown by component (full loading, MiB)");
    println!(
        "{:<22} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "model", "emb", "time-mix", "chan-mix", "head", "pred", "hh"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        for (kind, ours) in [("rwkv-vanilla", false), ("rwkv-ours", true)] {
            let name = format!("{kind}-{size}");
            if !model_exists(args, &name) {
                continue;
            }
            let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
            let (_, engine) = peak_after_generation(args, cfg, LoadStrategy::Full, gen_n)?;
            let groups = engine.tracker().peak_by_group();
            let g = |g: Group| groups.get(&g).copied().unwrap_or(0);
            println!(
                "{:<22} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
                name,
                mb(g(Group::Emb)),
                mb(g(Group::TimeMix)),
                mb(g(Group::ChanMix)),
                mb(g(Group::Head)),
                mb(g(Group::Predictor)),
                mb(g(Group::HierHead)),
            );
            rows.push(json::obj(vec![
                ("model", json::s(&name)),
                ("emb", json::num(g(Group::Emb) as f64)),
                ("timemix", json::num(g(Group::TimeMix) as f64)),
                ("chanmix", json::num(g(Group::ChanMix) as f64)),
                ("head", json::num(g(Group::Head) as f64)),
                ("predictor", json::num(g(Group::Predictor) as f64)),
                ("hier_head", json::num(g(Group::HierHead) as f64)),
            ]));
        }
    }
    println!("\npaper: SVD+sparsity shrink blocks 2.5x/3.6x; HH 6.7x on head; cache >10x on emb");
    save_result(args, "fig6", &Value::Arr(rows))
}

/// Table 7: inhouse-vanilla vs inhouse-ours (enhanced-SVD pretrain),
/// accuracy + peak memory under both strategies.
pub fn table7(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 60)?;
    let gen_n = args.usize_or("n", 32)?;
    title("Table 7: inhouse models — accuracy & peak memory (MiB)");
    println!(
        "{:<24} {:>7} {:>11} {:>11}",
        "model", "acc", "full", "layerwise"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        // inhouse-vanilla == our from-scratch vanilla checkpoints
        for (label, name, ours) in [
            ("inhouse-vanilla", format!("rwkv-vanilla-{size}"), false),
            ("inhouse-ours", format!("rwkv-pre-{size}"), true),
        ] {
            if !model_exists(args, &name) {
                continue;
            }
            let mk = |strategy| -> Result<(u64, RwkvEngine)> {
                let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
                peak_after_generation(args, cfg, strategy, gen_n)
            };
            let (peak_full, mut engine) = mk(LoadStrategy::Full)?;
            let (acc, _) = lambada_acc(&mut engine, args, limit)?;
            let (peak_lw, _) = mk(LoadStrategy::Layerwise)?;
            println!(
                "{:<15} {:<8} {:>7.3} {:>11.2} {:>11.2}",
                label, size, acc, mb(peak_full), mb(peak_lw)
            );
            rows.push(json::obj(vec![
                ("label", json::s(label)),
                ("size", json::s(size)),
                ("acc", json::num(acc)),
                ("peak_full", json::num(peak_full as f64)),
                ("peak_layerwise", json::num(peak_lw as f64)),
            ]));
        }
    }
    println!("\npaper: ours 3.5-4.8x smaller total, accuracy within ~1.5pp (slight gains)");
    save_result(args, "table7", &Value::Arr(rows))
}
