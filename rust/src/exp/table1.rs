//! Table 1 — parameter distribution of RWKV variants (square / non-square
//! / head / emb), computed from the actual checkpoint tensors.

use anyhow::Result;

use crate::cli::Args;
use crate::engine::weights::WeightStore;
use crate::json::{self, Value};

use super::{artifacts_dir, model_exists, save_result, title, SIZES};

struct Dist {
    square: u64,
    non_square: u64,
    head: u64,
    emb: u64,
    other: u64,
}

fn numel_where(store: &WeightStore, pred: impl Fn(&str) -> bool) -> u64 {
    store
        .rkv
        .names()
        .filter(|n| pred(n) && !n.ends_with(".scale"))
        .map(|n| store.rkv.entry(n).map(|e| e.numel() as u64).unwrap_or(0))
        .sum()
}

fn distribution(store: &WeightStore) -> Dist {
    let square = numel_where(store, |n| {
        (n.contains(".att.w") || n.contains(".ffn.wr")) && !n.contains(".pred.")
    });
    let non_square = numel_where(store, |n| n.contains(".ffn.wk_t") || n.contains(".ffn.wv"));
    let head = numel_where(store, |n| n == "head");
    let emb = numel_where(store, |n| n == "emb");
    let total: u64 = numel_where(store, |n| !n.contains(".pred.") && !n.starts_with("hh."));
    Dist {
        square,
        non_square,
        head,
        emb,
        other: total - square - non_square - head - emb,
    }
}

pub fn run(args: &Args) -> Result<()> {
    title("Table 1: parameter distribution of RWKV models (scaled variants)");
    println!(
        "{:<24} {:>10} | {:>8} {:>10} {:>6} {:>6} {:>6}",
        "model", "params", "square", "non-square", "head", "emb", "other"
    );
    let mut rows = Vec::new();
    for size in SIZES.iter().chain(["regular"].iter()) {
        let name = format!("rwkv-vanilla-{size}");
        if !model_exists(args, &name) {
            continue;
        }
        let store = WeightStore::open(
            &artifacts_dir(args).join("models").join(format!("{name}.json")),
        )?;
        let d = distribution(&store);
        let total = d.square + d.non_square + d.head + d.emb + d.other;
        let pct = |x: u64| 100.0 * x as f64 / total as f64;
        println!(
            "{:<24} {:>10} | {:>7.0}% {:>9.0}% {:>5.0}% {:>5.0}% {:>5.0}%",
            name,
            total,
            pct(d.square),
            pct(d.non_square),
            pct(d.head),
            pct(d.emb),
            pct(d.other)
        );
        rows.push(json::obj(vec![
            ("model", json::s(&name)),
            ("total", json::num(total as f64)),
            ("square_pct", json::num(pct(d.square))),
            ("non_square_pct", json::num(pct(d.non_square))),
            ("head_pct", json::num(pct(d.head))),
            ("emb_pct", json::num(pct(d.emb))),
        ]));
    }
    println!(
        "\npaper (Table 1): square 22-39%, non-square 25-51%, head+emb 52%->12%\n\
         (falling from tiny to medium) — the distribution REGIME to match."
    );
    save_result(args, "table1", &Value::Arr(rows))
}
