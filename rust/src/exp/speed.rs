//! Speed experiments: Figure 7 (time breakdown), Figure 8 (inhouse TPS),
//! Figure 10 (acc/mem/TPS vs transformers), Figure 12 (FP16 vs INT8 TPS),
//! §B.2 energy.

use anyhow::Result;

use crate::cli::Args;
use crate::device::{self, DeviceProfile, OPI2W, RPI5};
use crate::engine::sampler::Sampler;
use crate::engine::transformer::TransformerEngine;
use crate::engine::RwkvEngine;
use crate::evalsuite;
use crate::json::{self, Value};

use super::*;

/// Bytes/token + flops/token estimates for device projection: static
/// resident bytes are touched once per token (matvec streaming); streamed
/// groups (sparse rows, HH rows, emb) add their per-token traffic.
fn per_token_costs(engine: &RwkvEngine, n_tokens: u64) -> (f64, f64) {
    let resident = engine.tracker().current() as f64;
    let streamed_total = engine
        .tracker()
        .bytes_loaded_total()
        .saturating_sub(engine.tracker().current()) as f64;
    let streamed_per_tok = if n_tokens > 0 { streamed_total / n_tokens as f64 } else { 0.0 };
    let bytes = resident + streamed_per_tok;
    let m = engine.info;
    let svd_rank = if engine.store.manifest.svd_rank_div > 0 {
        m.dim / engine.store.manifest.svd_rank_div
    } else {
        0
    };
    let kept = if engine.cfg.sparse_ffn {
        let s: f64 = engine.sparsity_by_layer().iter().sum::<f64>()
            / engine.info.layers.max(1) as f64;
        1.0 - s
    } else {
        1.0
    };
    let flops = device::rwkv_flops_per_token(m.dim, m.layers, m.ffn, m.vocab, svd_rank, kept);
    (bytes, flops)
}

fn project(dev: &DeviceProfile, bytes: f64, flops: f64) -> f64 {
    dev.tps(bytes, flops)
}

/// Figure 7: per-component inference time breakdown (vanilla vs ours).
pub fn fig7(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 100)?;
    title("Figure 7: inference time breakdown per token (host, ms)");
    println!(
        "{:<22} {:>8} {:>10} {:>10} {:>8} {:>8}",
        "model", "emb", "time-mix", "chan-mix", "head", "total"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        for (kind, ours) in [("rwkv-vanilla", false), ("rwkv-ours", true)] {
            let name = format!("{kind}-{size}");
            if !model_exists(args, &name) {
                continue;
            }
            let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
            let mut engine = RwkvEngine::load(cfg)?;
            let prompt = corpus_prompt(args, 16)?;
            let mut sampler = Sampler::new(0.8, 0.95, 9);
            let mut state = engine.new_state();
            let (mut emb_s, mut tm_s, mut cm_s, mut hd_s) = (0.0, 0.0, 0.0, 0.0);
            let mut last = crate::text::BOS;
            for &t in &prompt {
                engine.forward_hidden(last, &mut state)?;
                last = t;
            }
            for _ in 0..n {
                let mut logits = engine.forward_token(last, &mut state)?;
                emb_s += engine.last_stats.emb_secs;
                tm_s += engine.last_stats.timemix_secs;
                cm_s += engine.last_stats.chanmix_secs;
                hd_s += engine.last_stats.head_secs;
                last = sampler.sample(&mut logits);
            }
            let k = 1e3 / n as f64;
            println!(
                "{:<22} {:>8.3} {:>10.3} {:>10.3} {:>8.3} {:>8.3}",
                name,
                emb_s * k,
                tm_s * k,
                cm_s * k,
                hd_s * k,
                (emb_s + tm_s + cm_s + hd_s) * k
            );
            rows.push(json::obj(vec![
                ("model", json::s(&name)),
                ("emb_ms", json::num(emb_s * k)),
                ("timemix_ms", json::num(tm_s * k)),
                ("chanmix_ms", json::num(cm_s * k)),
                ("head_ms", json::num(hd_s * k)),
            ]));
        }
    }
    println!("\npaper: head dominates the vanilla-vs-ours gap on tiny; dwarfed on medium");
    save_result(args, "fig7", &Value::Arr(rows))
}

/// Figure 8: TPS of inhouse-vanilla vs inhouse-ours on rpi5/opi2w.
pub fn fig8(args: &Args) -> Result<()> {
    tps_table(
        args,
        "fig8",
        "Figure 8: TPS inhouse-vanilla vs inhouse-ours (enhanced SVD)",
        &|size| vec![
            (format!("rwkv-vanilla-{size}"), false),
            (format!("rwkv-pre-{size}"), true),
        ],
        "paper: inhouse-ours 13.7% slower on rpi5, 20% on opi2w (tiny worst)",
    )
}

/// Figure 12: TPS FP16 vs INT8, vanilla and ours, both devices.
pub fn fig12(args: &Args) -> Result<()> {
    tps_table(
        args,
        "fig12",
        "Figure 12: TPS FP16 vs INT8 (fused dequant kernels)",
        &|size| vec![
            (format!("rwkv-vanilla-{size}"), false),
            (format!("rwkv-vanilla-{size}-int8"), false),
            (format!("rwkv-ours-{size}"), true),
            (format!("rwkv-ours-{size}-int8"), true),
        ],
        "paper: INT8 costs 5-9% TPS on ours, ~10% on vanilla (40% on tiny vanilla)",
    )
}

fn tps_table(
    args: &Args,
    id: &str,
    heading: &str,
    models_for: &dyn Fn(&str) -> Vec<(String, bool)>,
    paper_note: &str,
) -> Result<()> {
    let n = args.usize_or("n", 100)?;
    title(heading);
    println!(
        "{:<26} {:>10} {:>11} {:>11}",
        "model", "host TPS", "rpi5 TPS*", "opi2w TPS*"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        for (name, ours) in models_for(size) {
            if !model_exists(args, &name) {
                continue;
            }
            let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
            let engine = RwkvEngine::load(cfg)?;
            let (host_tps, engine) = measure_tps(engine, args, n)?;
            let (bytes, flops) = per_token_costs(&engine, n as u64);
            let rpi = project(&RPI5, bytes, flops);
            let opi = project(&OPI2W, bytes, flops);
            println!(
                "{:<26} {:>10.1} {:>11.1} {:>11.1}",
                name, host_tps, rpi, opi
            );
            rows.push(json::obj(vec![
                ("model", json::s(&name)),
                ("host_tps", json::num(host_tps)),
                ("rpi5_tps", json::num(rpi)),
                ("opi2w_tps", json::num(opi)),
                ("bytes_per_token", json::num(bytes)),
                ("flops_per_token", json::num(flops)),
            ]));
        }
    }
    println!("\n* device TPS projected via bandwidth/compute roofline (DESIGN.md §2)");
    println!("{paper_note}");
    save_result(args, id, &Value::Arr(rows))
}

/// Figure 10: accuracy / peak memory / TPS, RWKV vs transformer, per device.
pub fn fig10(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 60)?;
    let limit = args.usize_or("limit", 60)?;
    title("Figure 10: transformer vs RWKV — acc, peak memory, TPS");
    println!(
        "{:<22} {:>7} {:>11} {:>10} {:>10} {:>10}",
        "model", "acc", "peak (MiB)", "host TPS", "rpi5*", "opi2w*"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        for (kind, ours) in [("rwkv-vanilla", false), ("rwkv-ours", true)] {
            let name = format!("{kind}-{size}");
            if !model_exists(args, &name) {
                continue;
            }
            let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
            let engine = RwkvEngine::load(cfg)?;
            let (host_tps, mut engine) = measure_tps(engine, args, n)?;
            let (bytes, flops) = per_token_costs(&engine, n as u64);
            let (_, peak) = engine.memory_report();
            let (acc, _) = lambada_acc(&mut engine, args, limit)?;
            println!(
                "{:<22} {:>7.3} {:>11.2} {:>10.1} {:>10.1} {:>10.1}",
                name,
                acc,
                mb(peak),
                host_tps,
                project(&RPI5, bytes, flops),
                project(&OPI2W, bytes, flops)
            );
            rows.push(json::obj(vec![
                ("model", json::s(&name)),
                ("acc", json::num(acc)),
                ("peak_bytes", json::num(peak as f64)),
                ("host_tps", json::num(host_tps)),
            ]));
        }
        let tname = format!("gpt-{size}");
        if model_exists(args, &tname) {
            let cfg = cfg_vanilla(args, &tname);
            let mut tf = TransformerEngine::load(&cfg)?;
            let tasks = evalsuite::load_tasks(&tasks_path(args))?;
            let r = evalsuite::eval_task(&mut tf, &tasks["lambada_syn"], limit)?;
            // transformer TPS on host
            tf.reset();
            let mut sampler = Sampler::new(0.8, 0.95, 11);
            let prompt = corpus_prompt(args, 16)?;
            let t = crate::util::Stopwatch::start();
            tf.generate(&prompt, n, &mut sampler)?;
            let tps = n as f64 / t.elapsed_secs();
            let bytes = tf.weight_bytes() as f64;
            let flops = 2.0 * bytes / 2.0; // ~2 flops per f16 weight
            println!(
                "{:<22} {:>7.3} {:>11.2} {:>10.1} {:>10.1} {:>10.1}   (KV excluded)",
                tname,
                r.acc,
                mb(tf.weight_bytes()),
                tps,
                project(&RPI5, bytes, flops),
                project(&OPI2W, bytes, flops)
            );
            rows.push(json::obj(vec![
                ("model", json::s(&tname)),
                ("acc", json::num(r.acc)),
                ("peak_bytes", json::num(tf.weight_bytes() as f64)),
                ("host_tps", json::num(tps)),
            ]));
        }
    }
    println!("\npaper: RWKV-ours optimal across acc/memory/TPS jointly");
    save_result(args, "fig10", &Value::Arr(rows))
}

/// §B.2: energy per 200 tokens (device power x projected wall time).
pub fn energy(args: &Args) -> Result<()> {
    let n = 200;
    title("Energy per 200 generated tokens (projected, J)");
    println!("{:<26} {:>10} {:>10}", "model", "rpi5 (J)", "opi2w (J)");
    let mut rows = Vec::new();
    for (name, ours) in [
        ("rwkv-vanilla-small".to_string(), false),
        ("rwkv-ours-small".to_string(), true),
    ] {
        if !model_exists(args, &name) {
            continue;
        }
        let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
        let engine = RwkvEngine::load(cfg)?;
        let (_tps, engine) = measure_tps(engine, args, 50)?;
        let (bytes, flops) = per_token_costs(&engine, 50);
        let r = RPI5.energy_joules(n, bytes, flops);
        let o = OPI2W.energy_joules(n, bytes, flops);
        println!("{:<26} {:>10.1} {:>10.1}", name, r, o);
        rows.push(json::obj(vec![
            ("model", json::s(&name)),
            ("rpi5_joules", json::num(r)),
            ("opi2w_joules", json::num(o)),
        ]));
    }
    println!("\npaper: 214J (ours) vs 195J (vanilla) per 200 tokens on rpi5 (~10% more)");
    save_result(args, "energy", &Value::Arr(rows))
}
