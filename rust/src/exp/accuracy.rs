//! Accuracy experiments: Table 5 (full benchmark suite), Table 6
//! (ablations), Figure 9 (predictor study), Figure 11 (FP16 vs INT8),
//! §B.4 SVD-factor and cluster-threshold sweeps.

use anyhow::Result;

use crate::cli::Args;
use crate::engine::sparse_ffn::PredMode;
use crate::engine::transformer::TransformerEngine;
use crate::engine::RwkvEngine;
use crate::evalsuite::{self, Task};
use crate::json::{self, Value};

use super::*;

/// Table 5: every benchmark task for every model.
pub fn table5(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 40)?;
    let tasks = evalsuite::load_tasks(&tasks_path(args))?;
    let task_names: Vec<&String> = tasks.keys().collect();
    title("Table 5: benchmark results (acc; ppl for cloze tasks)");
    print!("{:<24}", "model");
    for t in &task_names {
        print!(" {:>13}", truncate(t, 13));
    }
    println!();
    let mut rows = Vec::new();
    let mut eval_model = |name: &str, ours: bool| -> Result<()> {
        if !model_exists(args, name) {
            return Ok(());
        }
        let mut results = Vec::new();
        if name.starts_with("gpt") {
            let cfg = cfg_vanilla(args, name);
            let mut tf = TransformerEngine::load(&cfg)?;
            for t in &task_names {
                results.push(evalsuite::eval_task(&mut tf, &tasks[*t], limit)?);
            }
        } else {
            let cfg = if ours { cfg_ours(args, name) } else { cfg_vanilla(args, name) };
            let mut engine = RwkvEngine::load(cfg)?;
            for t in &task_names {
                results.push(evalsuite::eval_task(&mut engine, &tasks[*t], limit)?);
            }
        }
        print!("{:<24}", name);
        let mut obj = vec![("model", json::s(name))];
        let mut cells = Vec::new();
        for (tn, r) in task_names.iter().zip(&results) {
            if matches!(tasks[*tn], Task::Cloze(_)) {
                print!(" {:>6.2}/{:>6.1}", r.acc, r.ppl);
            } else {
                print!(" {:>13.2}", r.acc);
            }
            cells.push(json::obj(vec![
                ("task", json::s(tn)),
                ("acc", json::num(r.acc)),
                ("ppl", json::num(r.ppl)),
            ]));
        }
        println!();
        obj.push(("results", Value::Arr(cells)));
        rows.push(json::obj(obj));
        Ok(())
    };
    for size in SIZES {
        eval_model(&format!("rwkv-vanilla-{size}"), false)?;
        eval_model(&format!("rwkv-ours-{size}"), true)?;
        eval_model(&format!("rwkv-pre-{size}"), true)?;
        eval_model(&format!("gpt-{size}"), false)?;
    }
    save_result(args, "table5", &Value::Arr(rows))
}

/// Table 6: ablations — each technique removed from the full stack.
pub fn table6(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 60)?;
    title("Table 6: ablation accuracy (lambada_syn)");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "size", "vanilla", "-SVD", "-HH", "-Sparse", "All"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        let vname = format!("rwkv-vanilla-{size}");
        let oname = format!("rwkv-ours-{size}");
        if !model_exists(args, &vname) || !model_exists(args, &oname) {
            continue;
        }
        // vanilla: no techniques at all
        let mut e = RwkvEngine::load(cfg_vanilla(args, &vname))?;
        let (acc_vanilla, _) = lambada_acc(&mut e, args, limit)?;
        // -SVD: vanilla weights + HH + sparse + cache
        let mut e = RwkvEngine::load(cfg_ours(args, &vname))?;
        let (acc_no_svd, _) = lambada_acc(&mut e, args, limit)?;
        // -HH: ours weights, hier head off
        let mut cfg = cfg_ours(args, &oname);
        cfg.hier_head = false;
        let mut e = RwkvEngine::load(cfg)?;
        let (acc_no_hh, _) = lambada_acc(&mut e, args, limit)?;
        // -Sparse: ours weights, sparse off
        let mut cfg = cfg_ours(args, &oname);
        cfg.sparse_ffn = false;
        let mut e = RwkvEngine::load(cfg)?;
        let (acc_no_sp, _) = lambada_acc(&mut e, args, limit)?;
        // All
        let mut e = RwkvEngine::load(cfg_ours(args, &oname))?;
        let (acc_all, _) = lambada_acc(&mut e, args, limit)?;
        println!(
            "{:<12} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            size, acc_vanilla, acc_no_svd, acc_no_hh, acc_no_sp, acc_all
        );
        rows.push(json::obj(vec![
            ("size", json::s(size)),
            ("vanilla", json::num(acc_vanilla)),
            ("no_svd", json::num(acc_no_svd)),
            ("no_hh", json::num(acc_no_hh)),
            ("no_sparse", json::num(acc_no_sp)),
            ("all", json::num(acc_all)),
        ]));
    }
    println!("\npaper: ablated models within ~1-2pp of vanilla; SVD costs most, sparse least");
    save_result(args, "table6", &Value::Arr(rows))
}

/// Figure 9: sparsity-predictor study on the small model.
pub fn fig9(args: &Args) -> Result<()> {
    let model = args.get_or("model", "rwkv-ours-small").to_string();
    let limit = args.usize_or("limit", 60)?;
    if !model_exists(args, &model) {
        anyhow::bail!("{model} not built (run make artifacts)");
    }
    title(&format!("Figure 9: predictor study ({model})"));
    println!(
        "{:<12} {:>9} {:>12} {:>14}",
        "predictor", "acc", "sparsity", "bytes/tok FFN"
    );
    let mut rows = Vec::new();
    for (label, mode) in [
        ("GT", PredMode::GroundTruth),
        ("MLP", PredMode::MlpOnly),
        ("1-bit", PredMode::QuantOnly),
        ("4-bit", PredMode::Quant4Only),
        ("ensemble", PredMode::Ensemble),
    ] {
        let mut cfg = cfg_ours(args, &model);
        cfg.hier_head = false; // isolate the FFN predictor effect
        let mut engine = RwkvEngine::load(cfg)?;
        if engine.set_pred_mode(mode).is_err() {
            println!("{:<12} (unavailable in this checkpoint)", label);
            continue;
        }
        let (acc, _) = lambada_acc(&mut engine, args, limit)?;
        let spars: f64 = engine.sparsity_by_layer().iter().sum::<f64>()
            / engine.info.layers as f64;
        // bytes/token for the FFN rows at this sparsity
        let row_bytes = 2.0 * 2.0 * engine.info.dim as f64; // wk_t + wv rows, f16
        let bytes = (1.0 - spars) * engine.info.ffn as f64 * row_bytes
            * engine.info.layers as f64;
        println!(
            "{:<12} {:>9.3} {:>11.1}% {:>14.0}",
            label,
            acc,
            100.0 * spars,
            bytes
        );
        rows.push(json::obj(vec![
            ("predictor", json::s(label)),
            ("acc", json::num(acc)),
            ("sparsity", json::num(spars)),
            ("ffn_bytes_per_token", json::num(bytes)),
        ]));
    }
    println!("\npaper: GT 85% sparsity; 1-bit alone poor; MLP+1-bit ensemble ~GT accuracy");
    save_result(args, "fig9", &Value::Arr(rows))
}

/// Figure 11: FP16 vs INT8 accuracy & memory.
pub fn fig11(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 60)?;
    let gen_n = args.usize_or("n", 32)?;
    title("Figure 11: FP16 vs INT8 — accuracy & peak memory");
    println!(
        "{:<26} {:>9} {:>9} {:>12}",
        "model", "prec", "acc", "peak (MiB)"
    );
    let mut rows = Vec::new();
    for size in SIZES {
        for (kind, ours) in [("rwkv-vanilla", false), ("rwkv-ours", true)] {
            for prec in ["f16", "int8"] {
                let name = if prec == "f16" {
                    format!("{kind}-{size}")
                } else {
                    format!("{kind}-{size}-int8")
                };
                if !model_exists(args, &name) {
                    continue;
                }
                let cfg = if ours { cfg_ours(args, &name) } else { cfg_vanilla(args, &name) };
                let (peak, mut engine) =
                    peak_after_generation(args, cfg, crate::config::LoadStrategy::Full, gen_n)?;
                let (acc, _) = lambada_acc(&mut engine, args, limit)?;
                println!(
                    "{:<26} {:>9} {:>9.3} {:>12.2}",
                    name,
                    prec,
                    acc,
                    mb(peak)
                );
                rows.push(json::obj(vec![
                    ("model", json::s(&name)),
                    ("precision", json::s(prec)),
                    ("acc", json::num(acc)),
                    ("peak_bytes", json::num(peak as f64)),
                ]));
            }
        }
    }
    println!("\npaper: INT8 ~2x memory cut, <1pp acc loss on ours; 10x total vs vanilla FP16");
    save_result(args, "fig11", &Value::Arr(rows))
}

/// §B.4: SVD decomposition factor sweep (k in 4/8/16) on the small model.
pub fn svd_k(args: &Args) -> Result<()> {
    let limit = args.usize_or("limit", 60)?;
    title("SVD factor sweep (small model, lambada_syn)");
    println!("{:<26} {:>6} {:>9} {:>12}", "model", "k", "acc", "ckpt (MiB)");
    let mut rows = Vec::new();
    for (name, k) in [
        ("rwkv-ours-k4-small", 4usize),
        ("rwkv-ours-small", 8),
        ("rwkv-ours-k16-small", 16),
    ] {
        if !model_exists(args, name) {
            continue;
        }
        let mut engine = RwkvEngine::load(cfg_ours(args, name))?;
        let (acc, _) = lambada_acc(&mut engine, args, limit)?;
        let bytes = engine.store.rkv.total_bytes();
        println!("{:<26} {:>6} {:>9.3} {:>12.2}", name, k, acc, mb(bytes));
        rows.push(json::obj(vec![
            ("model", json::s(name)),
            ("k", json::num(k as f64)),
            ("acc", json::num(acc)),
            ("ckpt_bytes", json::num(bytes as f64)),
        ]));
    }
    println!("\npaper: k=16 detrimental (up to -29pp); k=4 ~= k=8 (<1pp)");
    save_result(args, "svd-k", &Value::Arr(rows))
}

/// §B.4: hierarchical-head cluster threshold sweep.
pub fn hh_sweep(args: &Args) -> Result<()> {
    let model = args.get_or("model", "rwkv-ours-small").to_string();
    let limit = args.usize_or("limit", 60)?;
    if !model_exists(args, &model) {
        anyhow::bail!("{model} not built");
    }
    title(&format!("Hierarchical-head p_min sweep ({model})"));
    println!(
        "{:<8} {:>9} {:>16} {:>14}",
        "p_min", "acc", "tokens loaded/tok", "head bytes/tok"
    );
    let mut rows = Vec::new();
    for p_min in [0.85f32, 0.95, 0.99] {
        let mut cfg = cfg_ours(args, &model);
        cfg.hh_p_min = p_min;
        let mut engine = RwkvEngine::load(cfg)?;
        let (acc, _) = lambada_acc(&mut engine, args, limit)?;
        let loaded = engine.hier.as_ref().map(|h| h.mean_tokens_loaded()).unwrap_or(0.0);
        let bytes = loaded * 2.0 * engine.info.dim as f64;
        println!(
            "{:<8.2} {:>9.3} {:>16.1} {:>14.0}",
            p_min, acc, loaded, bytes
        );
        rows.push(json::obj(vec![
            ("p_min", json::num(p_min as f64)),
            ("acc", json::num(acc)),
            ("tokens_loaded", json::num(loaded)),
            ("head_bytes_per_token", json::num(bytes)),
        ]));
    }
    println!("\npaper: 0.85 halves memory but -10pp acc; 0.99 doubles memory, +1.5pp");
    save_result(args, "hh-sweep", &Value::Arr(rows))
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        s[..n].to_string()
    }
}
