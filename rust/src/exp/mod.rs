//! Experiment drivers: one per paper table/figure (`rwkv-lite exp <id>`).
//!
//! Each driver prints the paper-shaped rows AND appends machine-readable
//! JSON under `artifacts/results/<id>.json` (consumed by EXPERIMENTS.md).
//! See DESIGN.md §5 for the experiment index.

pub mod accuracy;
pub mod memory;
pub mod speed;
pub mod table1;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::config::{EngineConfig, LoadStrategy};
use crate::engine::sampler::Sampler;
use crate::engine::session::Session;
use crate::engine::RwkvEngine;
use crate::json::Value;

pub const SIZES: [&str; 3] = ["tiny", "small", "medium"];

pub fn run(exp_id: &str, args: &Args) -> Result<()> {
    match exp_id {
        "table1" => table1::run(args),
        "fig3" => memory::fig3(args),
        "fig5" => memory::fig5(args),
        "fig6" => memory::fig6(args),
        "table7" => memory::table7(args),
        "fig7" => speed::fig7(args),
        "fig8" => speed::fig8(args),
        "fig10" => speed::fig10(args),
        "fig12" => speed::fig12(args),
        "energy" => speed::energy(args),
        "table5" => accuracy::table5(args),
        "table6" => accuracy::table6(args),
        "fig9" => accuracy::fig9(args),
        "fig11" => accuracy::fig11(args),
        "svd-k" => accuracy::svd_k(args),
        "hh-sweep" => accuracy::hh_sweep(args),
        "all" => {
            for id in [
                "table1", "fig3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
                "fig11", "fig12", "table5", "table6", "table7", "svd-k", "hh-sweep",
                "energy",
            ] {
                println!("\n================ exp {id} ================");
                if let Err(e) = run(id, args) {
                    println!("[exp {id}] FAILED: {e:#}");
                }
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (see DESIGN.md §5)"),
    }
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

pub fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

pub fn results_dir(args: &Args) -> Result<PathBuf> {
    let d = artifacts_dir(args).join("results");
    std::fs::create_dir_all(&d)?;
    Ok(d)
}

pub fn save_result(args: &Args, id: &str, v: &Value) -> Result<()> {
    let path = results_dir(args)?.join(format!("{id}.json"));
    std::fs::write(&path, v.to_string())?;
    println!("[saved] {}", path.display());
    Ok(())
}

/// Does a model exist in artifacts?
pub fn model_exists(args: &Args, name: &str) -> bool {
    artifacts_dir(args)
        .join("models")
        .join(format!("{name}.json"))
        .exists()
}

/// Engine config for "vanilla runtime" (dense everything).
pub fn cfg_vanilla(args: &Args, model: &str) -> EngineConfig {
    EngineConfig::vanilla(model, artifacts_dir(args))
}

/// Engine config with the paper's full technique stack.
pub fn cfg_ours(args: &Args, model: &str) -> EngineConfig {
    EngineConfig::all_techniques(model, artifacts_dir(args))
}

/// Prompt tokens from the corpus stream.
pub fn corpus_prompt(args: &Args, len: usize) -> Result<Vec<u32>> {
    let path = artifacts_dir(args).join("data").join("corpus.bin");
    let bytes = std::fs::read(&path)?;
    let n = (bytes.len() / 4).min(len);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(u32::from_le_bytes([
            bytes[4 * i],
            bytes[4 * i + 1],
            bytes[4 * i + 2],
            bytes[4 * i + 3],
        ]));
    }
    Ok(out)
}

pub fn tasks_path(args: &Args) -> PathBuf {
    artifacts_dir(args).join("data").join("tasks.json")
}

/// Drive one session to completion through the serving entry point
/// (`RwkvEngine::step_round`) — the exp drivers measure the same fused
/// prefill + decode rounds the coordinator runs.
pub fn run_session(engine: &mut RwkvEngine, prompt: &[u32], n: usize, seed: u64) -> Result<Vec<u32>> {
    let mut sess = Session::new(engine, seed, prompt);
    sess.max_tokens = n;
    sess.sampler = Sampler::new(0.8, 0.95, seed);
    engine.run_session(&mut sess)
}

/// Generate `n` tokens after a short prompt; returns (tps, engine).
pub fn measure_tps(mut engine: RwkvEngine, args: &Args, n: usize) -> Result<(f64, RwkvEngine)> {
    let prompt = corpus_prompt(args, 16)?;
    // warmup + prefill
    run_session(&mut engine, &prompt, 4, 42)?;
    let t = crate::util::Stopwatch::start();
    run_session(&mut engine, &prompt, n, 42)?;
    let secs = t.elapsed_secs();
    Ok(((n as f64) / secs, engine))
}

/// Measured accuracy on lambada_syn through the engine (limit examples).
pub fn lambada_acc(engine: &mut RwkvEngine, args: &Args, limit: usize) -> Result<(f64, f64)> {
    let tasks = crate::evalsuite::load_tasks(&tasks_path(args))?;
    let t = tasks
        .get("lambada_syn")
        .ok_or_else(|| anyhow::anyhow!("lambada_syn missing from tasks.json"))?;
    let r = crate::evalsuite::eval_task(engine, t, limit)?;
    Ok((r.acc, r.ppl))
}

/// Peak weight-residency after generating `n` tokens (fresh engine).
///
/// The §5.1 figures report SINGLE-block layerwise streaming, so the
/// double-buffered prefetcher (a serving-latency default that keeps a
/// second block resident) is disabled here; `exp speed` keeps the
/// serving default.
pub fn peak_after_generation(
    args: &Args,
    mut cfg: EngineConfig,
    strategy: LoadStrategy,
    n: usize,
) -> Result<(u64, RwkvEngine)> {
    cfg.strategy = strategy;
    cfg.prefetch = false;
    let mut engine = RwkvEngine::load(cfg)?;
    let prompt = corpus_prompt(args, 16)?;
    run_session(&mut engine, &prompt, n, 7)?;
    let (_, peak) = engine.memory_report();
    Ok((peak, engine))
}

pub fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1 << 20) as f64
}

/// Print a separator-framed table title.
pub fn title(s: &str) {
    println!("\n{s}");
    println!("{}", "-".repeat(s.len().min(100)));
}
