//! Word-level tokenizer over the corpus vocabulary (substrate S15).
//!
//! The vocabulary is fixed by the corpus generator (python writes
//! `artifacts/data/vocab.json`; token id == index).  Tokenization is
//! whitespace splitting + exact lookup, with `<unk>` fallback — matching
//! the python side exactly, which is what keeps rust-vs-python eval
//! numbers comparable.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json;

pub const PAD: u32 = 0;
pub const UNK: u32 = 1;
pub const BOS: u32 = 2;
pub const EOS: u32 = 3;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub words: Vec<String>,
    index: HashMap<String, u32>,
}

impl Vocab {
    pub fn from_words(words: Vec<String>) -> Self {
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Self { words, index }
    }

    pub fn load(path: &Path) -> Result<Self> {
        let v = json::parse_file(path)?;
        let arr = v.get("words").and_then(|w| w.as_arr()).context("vocab.json: words[]")?;
        let words: Vec<String> = arr
            .iter()
            .filter_map(|w| w.as_str().map(String::from))
            .collect();
        Ok(Self::from_words(words))
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn id(&self, word: &str) -> u32 {
        self.index.get(word).copied().unwrap_or(UNK)
    }

    pub fn word(&self, id: u32) -> &str {
        self.words
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Encode stop words into stop token ids, rejecting words not in the
    /// vocabulary — those would encode to [`UNK`] and end a stream on ANY
    /// out-of-vocab emission.  Literal `<unk>` is allowed.  Shared by the
    /// server protocol and the CLI `--stop` flag so the policy cannot
    /// drift between front-ends.
    pub fn stop_token_ids<'a, I>(&self, words: I) -> Result<Vec<u32>>
    where
        I: IntoIterator<Item = &'a str>,
    {
        let mut out = Vec::new();
        for w in words {
            let id = self.id(w);
            if id == UNK && w != "<unk>" {
                anyhow::bail!("stop word '{w}' is not in the vocabulary");
            }
            out.push(id);
        }
        Ok(out)
    }

    /// Encode a multi-word stop phrase into its token sequence, with the
    /// same strictness as [`Vocab::stop_token_ids`] (out-of-vocab words
    /// are rejected — they would otherwise silently become [`UNK`] and
    /// match any unknown emission).  Empty phrases are rejected: an empty
    /// sequence would never (or, naively, always) match.  Shared by the
    /// server's `stop_seqs` field and the CLI `--stop-seq` flag.
    pub fn stop_seq_ids(&self, phrase: &str) -> Result<Vec<u32>> {
        let toks = self.stop_token_ids(phrase.split_whitespace())?;
        anyhow::ensure!(!toks.is_empty(), "empty stop sequence");
        Ok(toks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Vocab {
        Vocab::from_words(
            ["<pad>", "<unk>", "<bos>", "<eos>", "the", "cat", "sat"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        )
    }

    #[test]
    fn encode_decode_round_trip() {
        let v = toy();
        let ids = v.encode("the cat sat");
        assert_eq!(ids, vec![4, 5, 6]);
        assert_eq!(v.decode(&ids), "the cat sat");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = toy();
        assert_eq!(v.encode("the dog"), vec![4, UNK]);
        assert_eq!(v.word(999), "<unk>");
    }

    #[test]
    fn stop_seq_ids_strict() {
        let v = toy();
        assert_eq!(v.stop_seq_ids("the cat sat").unwrap(), vec![4, 5, 6]);
        assert!(v.stop_seq_ids("the dog").is_err(), "OOV word rejected");
        assert!(v.stop_seq_ids("").is_err(), "empty phrase rejected");
    }
}
