//! Weight store: tracked, strategy-aware access to `.rkv` tensors.
//!
//! Every copy of weight bytes from the mmap into RAM goes through here and
//! is registered with the [`MemTracker`] under its component group — this
//! is what makes the Figure 5/6 memory numbers auditable.  Technique-
//! managed tensors (embedding rows, sparse FFN rows, hierarchical-head
//! rows) are *not* loaded as whole matrices; they are streamed per token
//! via [`RowView`] and accounted as transient bytes.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;

use anyhow::{bail, Result};

use crate::io::{Manifest, RkvFile};
use crate::metrics::{Group, MemTracker};
use crate::pool::{Par, Task, ThreadPool};
use crate::sync::{Arc, Mutex};
use crate::tensor::q4::{q4_groups, q4_row_packed_bytes};
use crate::tensor::{matmat_in_out, matvec_in_out, simd, DType, Kernels, Mat};
use crate::util::cast::cast_slice_len;
use crate::util::f16::f16_to_f32_fast as f16_to_f32;

/// Component group of a tensor, by naming convention (export.py).
pub fn group_of(name: &str) -> Group {
    if name.starts_with("emb") {
        Group::Emb
    } else if name.starts_with("head") {
        Group::Head
    } else if name.starts_with("hh.") {
        Group::HierHead
    } else if name.contains(".pred.") {
        Group::Predictor
    } else if name.contains(".att.") || name.contains(".ln1.") {
        Group::TimeMix
    } else if name.contains(".ffn.") || name.contains(".ln2.") {
        Group::ChanMix
    } else {
        Group::Other
    }
}

pub struct WeightStore {
    pub rkv: RkvFile,
    pub manifest: Manifest,
    pub tracker: Arc<MemTracker>,
    mats: Mutex<HashMap<String, Arc<Mat>>>,
    vecs: Mutex<HashMap<String, Arc<Vec<f32>>>>,
}

impl WeightStore {
    pub fn open(manifest_path: &Path) -> Result<Self> {
        let manifest = Manifest::load(manifest_path)?;
        let rkv = RkvFile::open(&manifest.rkv_path())?;
        Ok(Self {
            rkv,
            manifest,
            tracker: Arc::new(MemTracker::new()),
            mats: Mutex::new(HashMap::new()),
            vecs: Mutex::new(HashMap::new()),
        })
    }

    /// Load (or fetch cached) a matrix; bytes tracked on first load.
    pub fn mat(&self, name: &str) -> Result<Arc<Mat>> {
        if let Some(m) = self.mats.lock().unwrap().get(name) {
            return Ok(Arc::clone(m));
        }
        let m = Arc::new(self.rkv.mat(name)?);
        self.tracker.load(group_of(name), m.nbytes());
        self.mats.lock().unwrap().insert(name.to_string(), Arc::clone(&m));
        Ok(m)
    }

    pub fn vec(&self, name: &str) -> Result<Arc<Vec<f32>>> {
        if let Some(v) = self.vecs.lock().unwrap().get(name) {
            return Ok(Arc::clone(v));
        }
        let v = Arc::new(self.rkv.vec_f32(name)?);
        self.tracker.load(group_of(name), 4 * v.len() as u64);
        self.vecs.lock().unwrap().insert(name.to_string(), Arc::clone(&v));
        Ok(v)
    }

    /// Drop all cached tensors whose name starts with `prefix`, returning
    /// the bytes released (layerwise strategy).
    pub fn unload_prefix(&self, prefix: &str) -> u64 {
        let mut released = 0u64;
        {
            let mut mats = self.mats.lock().unwrap();
            let keys: Vec<String> = mats.keys().filter(|k| k.starts_with(prefix)).cloned().collect();
            for k in keys {
                if let Some(m) = mats.remove(&k) {
                    self.tracker.unload(group_of(&k), m.nbytes());
                    released += m.nbytes();
                }
            }
        }
        let mut vecs = self.vecs.lock().unwrap();
        let keys: Vec<String> = vecs.keys().filter(|k| k.starts_with(prefix)).cloned().collect();
        for k in keys {
            if let Some(v) = vecs.remove(&k) {
                let b = 4 * v.len() as u64;
                self.tracker.unload(group_of(&k), b);
                released += b;
            }
        }
        released
    }

    /// Decode embedding row `token` into `out`; returns bytes touched.
    pub fn emb_row(&self, token: u32, out: &mut [f32]) -> Result<u64> {
        let e = self.rkv.entry("emb")?;
        let cols = e.shape[1];
        if out.len() != cols {
            bail!("emb row buffer size mismatch");
        }
        match e.dtype {
            DType::F16 => {
                let row = self.rkv.row_f16("emb", token as usize)?;
                for (o, &h) in out.iter_mut().zip(row) {
                    *o = f16_to_f32(h);
                }
                Ok(2 * cols as u64)
            }
            DType::F32 => {
                let all = self.rkv.typed::<f32>("emb")?;
                let row = token as usize;
                let r = all
                    .get(row * cols..(row + 1) * cols)
                    .ok_or_else(|| anyhow::anyhow!("emb row {row} out of range"))?;
                out.copy_from_slice(r);
                Ok(4 * cols as u64)
            }
            DType::I8 => {
                let all = self.rkv.typed::<i8>("emb")?;
                let scale = self.vec("emb.scale")?;
                let row = token as usize;
                let r = all
                    .get(row * cols..(row + 1) * cols)
                    .ok_or_else(|| anyhow::anyhow!("emb row {row} out of range"))?;
                for ((o, &qv), &s) in out.iter_mut().zip(r).zip(scale.iter()) {
                    *o = qv as f32 * s;
                }
                Ok(cols as u64)
            }
            other => bail!("emb dtype {:?} unsupported", other),
        }
    }

    /// A row-per-output view over a matrix that stays in the mmap
    /// (sparse FFN §3.2 and hierarchical head §3.3 consume these).
    pub fn row_view(&self, name: &str) -> Result<RowView<'_>> {
        let e = self.rkv.entry(name)?;
        if e.shape.len() != 2 {
            bail!("row_view on non-2D tensor {name}");
        }
        let (rows, cols) = (e.shape[0], e.shape[1]);
        let scale = if e.dtype == DType::I8 {
            Some(self.rkv.vec_f32(&format!("{name}.scale"))?)
        } else {
            None
        };
        // Typed ONCE here through the checked cast helpers (length is
        // `rows * cols` by the `.rkv` parse invariant, alignment by the
        // writer's 64-byte payload alignment); every later row access is
        // safe indexed slicing — no unsafe on the per-token hot path.
        let raw = self.rkv.raw(name)?;
        let data = match e.dtype {
            DType::F16 => RowData::F16(cast_slice_len::<u16>(raw, rows * cols)?),
            DType::F32 => RowData::F32(cast_slice_len::<f32>(raw, rows * cols)?),
            DType::I8 => RowData::I8(cast_slice_len::<i8>(raw, rows * cols)?),
            // Q4/Q4_1 group scales live inside RowData (per-row slices of
            // the f16 sibling tensors) and are folded in per element by
            // `dot`/`accum`, so `RowView::scale` stays None and
            // `apply_col_scale` is a no-op for these dtypes.
            DType::Q4 => RowData::Q4 {
                packed: raw,
                scale: self.q4_sibling(name, "scale", rows, cols)?,
            },
            DType::Q41 => RowData::Q41 {
                packed: raw,
                scale: self.q4_sibling(name, "scale", rows, cols)?,
                min: self.q4_sibling(name, "min", rows, cols)?,
            },
            other => bail!("row_view dtype {other:?} unsupported for {name}"),
        };
        // The ISA kernel table is resolved ONCE per view (i.e. once per
        // matrix pass), not per row — `RowView::dot`/`accum` then call
        // straight through the fn pointers.
        Ok(RowView { dtype: e.dtype, rows, cols, data, scale, kern: simd::kernels() })
    }

    /// Zero-copy per-group parameter sibling of a Q4/Q4_1 tensor,
    /// validated to f16 `[rows, groups(cols)]` so per-row slicing in the
    /// row kernels can never over-read.
    fn q4_sibling(&self, base: &str, suffix: &str, rows: usize, cols: usize) -> Result<&[u16]> {
        let name = format!("{base}.{suffix}");
        let e = self.rkv.entry(&name)?;
        let ng = q4_groups(cols);
        if e.dtype != DType::F16 || e.shape != [rows, ng] {
            bail!(
                "tensor '{name}': quantized sibling must be f16 [{rows}, {ng}], got {:?} {:?}",
                e.dtype,
                e.shape
            );
        }
        cast_slice_len::<u16>(self.rkv.raw(&name)?, rows * ng)
    }
}

/// The storage-precision payload behind a [`RowView`], typed at
/// construction so row access needs no casting.
enum RowData<'a> {
    F16(&'a [u16]),
    F32(&'a [f32]),
    I8(&'a [i8]),
    Q4 { packed: &'a [u8], scale: &'a [u16] },
    Q41 { packed: &'a [u8], scale: &'a [u16], min: &'a [u16] },
}

/// Borrowed row-major matrix view in storage precision.
pub struct RowView<'a> {
    pub dtype: DType,
    pub rows: usize,
    pub cols: usize,
    data: RowData<'a>,
    /// Per-row scale (i8, row-per-output tensors like wk_t/head) OR
    /// per-column scale (i8, (in,out) tensors like wv) — consumer knows.
    pub scale: Option<Vec<f32>>,
    /// Active SIMD kernel table, resolved at view construction.
    kern: &'static Kernels,
}

impl<'a> RowView<'a> {
    /// Stored bytes one row streams: packed payload plus the per-group
    /// parameter bytes for the sub-byte dtypes (this is what the
    /// technique byte-accounting charges per row touched).
    pub fn row_bytes(&self) -> u64 {
        match self.dtype {
            DType::Q4 => (q4_row_packed_bytes(self.cols) + 2 * q4_groups(self.cols)) as u64,
            DType::Q41 => (q4_row_packed_bytes(self.cols) + 4 * q4_groups(self.cols)) as u64,
            d => (self.cols * d.size()) as u64,
        }
    }

    /// `dot(row_j, x)` with per-ROW scale applied for i8 — the unified
    /// per-dtype dot: the storage precision was matched once at view
    /// construction, so this is a slice + one indirect call.
    pub fn dot(&self, j: usize, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.cols);
        let lo = j * self.cols;
        match &self.data {
            RowData::F16(all) => (self.kern.dot_f16)(&all[lo..lo + self.cols], x),
            RowData::F32(all) => (self.kern.dot_f32)(&all[lo..lo + self.cols], x),
            RowData::I8(all) => {
                let s = self.scale.as_ref().map(|s| s[j]).unwrap_or(1.0);
                s * (self.kern.dot_i8)(&all[lo..lo + self.cols], x)
            }
            RowData::Q4 { packed, scale } => {
                let (prb, ng) = (q4_row_packed_bytes(self.cols), q4_groups(self.cols));
                (self.kern.dot_q4)(&packed[j * prb..(j + 1) * prb], &scale[j * ng..(j + 1) * ng], x)
            }
            RowData::Q41 { packed, scale, min } => {
                let (prb, ng) = (q4_row_packed_bytes(self.cols), q4_groups(self.cols));
                (self.kern.dot_q4_1)(
                    &packed[j * prb..(j + 1) * prb],
                    &scale[j * ng..(j + 1) * ng],
                    &min[j * ng..(j + 1) * ng],
                    x,
                )
            }
        }
    }

    /// `out[:] += h * row_j` (per-COLUMN scale for i8 applied by caller
    /// via [`RowView::apply_col_scale`] after accumulation).
    pub fn accum(&self, j: usize, h: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        let lo = j * self.cols;
        match &self.data {
            RowData::F16(all) => (self.kern.axpy_f16)(h, &all[lo..lo + self.cols], out),
            RowData::F32(all) => (self.kern.axpy_f32)(h, &all[lo..lo + self.cols], out),
            RowData::I8(all) => (self.kern.axpy_i8)(h, &all[lo..lo + self.cols], out),
            // group scales fold in per element here (unlike i8's deferred
            // per-column fold), so `apply_col_scale` stays a no-op and the
            // output may carry a residual at all times
            RowData::Q4 { packed, scale } => {
                let (prb, ng) = (q4_row_packed_bytes(self.cols), q4_groups(self.cols));
                let prow = &packed[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                (self.kern.axpy_q4)(h, prow, srow, 0, out);
            }
            RowData::Q41 { packed, scale, min } => {
                let (prb, ng) = (q4_row_packed_bytes(self.cols), q4_groups(self.cols));
                let prow = &packed[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                let mrow = &min[j * ng..(j + 1) * ng];
                (self.kern.axpy_q4_1)(h, prow, srow, mrow, 0, out);
            }
        }
    }

    /// Apply the per-column scale (i8 `(in,out)` tensors) after accumulation.
    pub fn apply_col_scale(&self, out: &mut [f32]) {
        if let Some(scale) = &self.scale {
            for (o, &s) in out.iter_mut().zip(scale.iter()) {
                *o *= s;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Typed per-layer weight bundles
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub struct LnW {
    pub scale: Arc<Vec<f32>>,
    pub bias: Arc<Vec<f32>>,
}

impl LnW {
    pub fn load(store: &WeightStore, prefix: &str) -> Result<Self> {
        Ok(Self {
            scale: store.vec(&format!("{prefix}.scale"))?,
            bias: store.vec(&format!("{prefix}.bias"))?,
        })
    }
}

/// A projection in whichever representation the checkpoint stores (§3.1).
#[derive(Clone)]
pub enum ProjW {
    Dense(Arc<Mat>),
    LowRank { l: Arc<Mat>, r: Arc<Mat> },
    Enhanced { l: Arc<Mat>, r: Arc<Mat>, d: Arc<Vec<f32>> },
}

impl ProjW {
    pub fn load(store: &WeightStore, prefix: &str) -> Result<Self> {
        if store.rkv.has(&format!("{prefix}.w")) {
            Ok(ProjW::Dense(store.mat(&format!("{prefix}.w"))?))
        } else if store.rkv.has(&format!("{prefix}.d")) {
            Ok(ProjW::Enhanced {
                l: store.mat(&format!("{prefix}.l"))?,
                r: store.mat(&format!("{prefix}.r"))?,
                d: store.vec(&format!("{prefix}.d"))?,
            })
        } else if store.rkv.has(&format!("{prefix}.l")) {
            Ok(ProjW::LowRank {
                l: store.mat(&format!("{prefix}.l"))?,
                r: store.mat(&format!("{prefix}.r"))?,
            })
        } else {
            bail!("no projection tensors under '{prefix}'")
        }
    }

    /// `out = proj(x)` (out zeroed here). `scratch` holds the rank-sized
    /// intermediate for the low-rank forms; `acc` is the i8 matvec
    /// accumulator scratch (see [`matvec_in_out`]).
    pub fn apply(&self, x: &[f32], out: &mut [f32], scratch: &mut Vec<f32>, acc: &mut Vec<f32>) {
        out.fill(0.0);
        match self {
            ProjW::Dense(w) => matvec_in_out(x, w, out, acc),
            ProjW::LowRank { l, r } => {
                scratch.clear();
                scratch.resize(l.cols(), 0.0);
                matvec_in_out(x, l, scratch, acc);
                matvec_in_out(scratch, r, out, acc);
            }
            ProjW::Enhanced { l, r, d } => {
                // relu(xL)^2 R + x*d   (paper Eq. 2)
                scratch.clear();
                scratch.resize(l.cols(), 0.0);
                matvec_in_out(x, l, scratch, acc);
                crate::tensor::sqrelu_inplace(scratch);
                matvec_in_out(scratch, r, out, acc);
                for i in 0..out.len() {
                    out[i] += x[i] * d[i];
                }
            }
        }
    }

    /// Batched `outs[s] = proj(xs[s])` over `(B, dim)` flat activations —
    /// every weight row streams once for the whole round, sharded over
    /// `par`'s lanes (inline without a pool; bit-identical either way).
    /// Bit-identical per slot to [`ProjW::apply`].  `scratch` holds the
    /// `(B, rank)` intermediate for the low-rank forms; `accs` is the
    /// per-lane matmat kernel scratch (f16 row decode / i8 accumulators).
    pub fn apply_batch(
        &self,
        xs: &[f32],
        b: usize,
        outs: &mut [f32],
        scratch: &mut Vec<f32>,
        accs: &mut Vec<Vec<f32>>,
        par: Par<'_>,
    ) {
        outs.fill(0.0);
        match self {
            ProjW::Dense(w) => matmat_in_out(xs, w, outs, accs, par),
            ProjW::LowRank { l, r } => {
                scratch.clear();
                scratch.resize(b * l.cols(), 0.0);
                matmat_in_out(xs, l, scratch, accs, par);
                matmat_in_out(scratch, r, outs, accs, par);
            }
            ProjW::Enhanced { l, r, d } => {
                scratch.clear();
                scratch.resize(b * l.cols(), 0.0);
                matmat_in_out(xs, l, scratch, accs, par);
                crate::tensor::sqrelu_inplace(scratch);
                matmat_in_out(scratch, r, outs, accs, par);
                let dim = d.len();
                for s in 0..b {
                    let (x, out) = (&xs[s * dim..(s + 1) * dim], &mut outs[s * dim..(s + 1) * dim]);
                    for i in 0..dim {
                        out[i] += x[i] * d[i];
                    }
                }
            }
        }
    }

    pub fn nbytes(&self) -> u64 {
        match self {
            ProjW::Dense(w) => w.nbytes(),
            ProjW::LowRank { l, r } => l.nbytes() + r.nbytes(),
            ProjW::Enhanced { l, r, d } => l.nbytes() + r.nbytes() + 4 * d.len() as u64,
        }
    }
}

#[derive(Clone)]
pub struct AttW {
    pub mu_r: Arc<Vec<f32>>,
    pub mu_k: Arc<Vec<f32>>,
    pub mu_v: Arc<Vec<f32>>,
    pub mu_g: Arc<Vec<f32>>,
    pub decay: Arc<Vec<f32>>, // (H*S,) precomputed exp(-exp(.))
    pub first: Arc<Vec<f32>>, // (H*S,)
    pub wr: ProjW,
    pub wk: ProjW,
    pub wv: ProjW,
    pub wg: ProjW,
    pub wo: Arc<Mat>,
    pub lnx: LnW,
}

#[derive(Clone)]
pub struct FfnW {
    pub mu_k: Arc<Vec<f32>>,
    pub mu_r: Arc<Vec<f32>>,
    pub wr: ProjW,
    /// Dense FFN matrices; `None` when the sparse path manages them (§3.2).
    pub wk_t: Option<Arc<Mat>>,
    pub wv: Option<Arc<Mat>>,
}

#[derive(Clone)]
pub struct BlockW {
    pub ln1: LnW,
    pub ln2: LnW,
    pub att: AttW,
    pub ffn: FfnW,
}

impl BlockW {
    /// Load block `i`; `dense_ffn = false` leaves wk_t/wv unloaded
    /// (sparse-managed).
    pub fn load(store: &WeightStore, i: usize, dense_ffn: bool) -> Result<Self> {
        let p = format!("b{i}");
        let att = AttW {
            mu_r: store.vec(&format!("{p}.att.mu_r"))?,
            mu_k: store.vec(&format!("{p}.att.mu_k"))?,
            mu_v: store.vec(&format!("{p}.att.mu_v"))?,
            mu_g: store.vec(&format!("{p}.att.mu_g"))?,
            decay: store.vec(&format!("{p}.att.decay"))?,
            first: store.vec(&format!("{p}.att.first"))?,
            wr: ProjW::load(store, &format!("{p}.att.wr"))?,
            wk: ProjW::load(store, &format!("{p}.att.wk"))?,
            wv: ProjW::load(store, &format!("{p}.att.wv"))?,
            wg: ProjW::load(store, &format!("{p}.att.wg"))?,
            wo: store.mat(&format!("{p}.att.wo.w"))?,
            lnx: LnW::load(store, &format!("{p}.att.lnx"))?,
        };
        let ffn = FfnW {
            mu_k: store.vec(&format!("{p}.ffn.mu_k"))?,
            mu_r: store.vec(&format!("{p}.ffn.mu_r"))?,
            wr: ProjW::load(store, &format!("{p}.ffn.wr"))?,
            wk_t: if dense_ffn {
                Some(store.mat(&format!("{p}.ffn.wk_t"))?)
            } else {
                None
            },
            wv: if dense_ffn {
                Some(store.mat(&format!("{p}.ffn.wv"))?)
            } else {
                None
            },
        };
        Ok(Self {
            ln1: LnW::load(store, &format!("{p}.ln1"))?,
            ln2: LnW::load(store, &format!("{p}.ln2"))?,
            att,
            ffn,
        })
    }
}

// ---------------------------------------------------------------------------
// Double-buffered layerwise block prefetch (§5.1 + ROADMAP "Layerwise
// strategy + batching")
// ---------------------------------------------------------------------------

/// Double-buffers `LoadStrategy::Layerwise` block streaming: while the
/// round thread computes block N, a dedicated single-worker I/O pool
/// streams block N+1 ([`ThreadPool::submit`] + `advise_prefix` kernel
/// readahead), so the layer boundary pays only the *remaining* wait
/// instead of a full cold load.  After the last layer the prefetch wraps
/// to block 0, overlapping the next round's first load with this round's
/// head + sampling.
///
/// The two "buffers" are the block the engine currently holds and the
/// in-flight [`Task`]'s [`BlockW`] — both plain Arc'd tensor bundles, so
/// the swap at the layer boundary is a channel receive, not a copy.
/// Prefetching never changes the math (the same bytes are decoded either
/// way) and `round_weight_bytes` accounting is untouched; the one
/// observable cost is residency: up to TWO blocks are resident during the
/// overlap, and the [`MemTracker`] reports that double-buffered peak
/// honestly.
///
/// The I/O worker is deliberately NOT the intra-round compute pool: a
/// block load parked on a compute worker would stall `parallel_for`
/// sections (and with `threads = 1` there is no compute pool at all).
pub struct BlockPrefetcher {
    io: ThreadPool,
    store: Arc<WeightStore>,
    dense_ffn: bool,
    layers: usize,
    /// The in-flight background load, tagged with its layer.
    inflight: Option<(usize, Task<Result<BlockW>>)>,
    /// Seconds the round thread spent blocked on in-flight loads since
    /// the last [`BlockPrefetcher::drain_round_stats`].
    wait_secs: f64,
    /// Blocks served from a background load since the last drain.
    prefetched: u64,
    /// Blocks the round thread had to load synchronously (cold start or
    /// a stale in-flight layer) since the last drain.
    sync_loads: u64,
}

impl BlockPrefetcher {
    pub fn new(store: Arc<WeightStore>, dense_ffn: bool, layers: usize) -> Self {
        Self {
            io: ThreadPool::named(1, "rwkv-prefetch"),
            store,
            dense_ffn,
            layers,
            inflight: None,
            wait_secs: 0.0,
            prefetched: 0,
            sync_loads: 0,
        }
    }

    /// Hand the round thread block `layer`, then start streaming the next
    /// block in the background.  The caller remains responsible for
    /// `unload_prefix("b{layer}.")` after computing the block, exactly as
    /// on the non-prefetching path.
    pub fn take(&mut self, layer: usize) -> Result<BlockW> {
        let block = match self.inflight.take() {
            Some((l, task)) if l == layer => {
                let t = crate::util::Stopwatch::start();
                let r = task.wait();
                self.wait_secs += t.elapsed_secs();
                self.prefetched += 1;
                r?
            }
            other => {
                // Stale in-flight layer (callers always walk 0..L, so this
                // is a cold start or an aborted previous pass): let it
                // land, release its tracked bytes, and load synchronously.
                if let Some((l, task)) = other {
                    let _ = task.wait();
                    self.store.unload_prefix(&format!("b{l}."));
                }
                self.sync_loads += 1;
                BlockW::load(&self.store, layer, self.dense_ffn)?
            }
        };
        // Overlap the next block's streaming with this block's compute;
        // wrapping to 0 keeps the pipeline primed across rounds.  A
        // 1-layer model would prefetch the block the engine is about to
        // unload (racing the unload), so it stays synchronous.
        let next = (layer + 1) % self.layers;
        if next != layer {
            let store = Arc::clone(&self.store);
            let dense_ffn = self.dense_ffn;
            let task = self.io.submit(move || {
                // readahead exactly what the load below decodes: never
                // the resident predictor tensors, and not the sparse-
                // managed FFN matrices (§3.2 streams their rows per
                // round) unless this engine runs the FFN dense
                store.rkv.advise_prefix_where(&format!("b{next}."), |name| {
                    !name.contains(".pred.")
                        && (dense_ffn
                            || !(name.contains(".ffn.wk_t") || name.contains(".ffn.wv")))
                });
                BlockW::load(&store, next, dense_ffn)
            });
            self.inflight = Some((next, task));
        }
        Ok(block)
    }

    /// Drain `(wait_secs, blocks_prefetched, blocks_loaded_sync)`
    /// accumulated since the previous drain (per-round telemetry).
    pub fn drain_round_stats(&mut self) -> (f64, u64, u64) {
        let out = (self.wait_secs, self.prefetched, self.sync_loads);
        self.wait_secs = 0.0;
        self.prefetched = 0;
        self.sync_loads = 0;
        out
    }
}

impl Drop for BlockPrefetcher {
    fn drop(&mut self) {
        // Let the in-flight load land, then release its tracked bytes so
        // the residency report returns to the engine's baseline.
        if let Some((l, task)) = self.inflight.take() {
            let _ = catch_unwind(AssertUnwindSafe(|| task.wait()));
            self.store.unload_prefix(&format!("b{l}."));
        }
    }
}
