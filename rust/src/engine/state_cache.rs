//! Prefix-state cache: snapshot/fork RWKV states so shared prompt
//! prefixes skip prefill entirely.
//!
//! RWKV's recurrent state is O(1) in sequence length — no KV cache —
//! so caching a processed prompt prefix costs ONE [`RwkvState`] snapshot
//! (a few MB) regardless of prefix length, something transformer serving
//! stacks cannot do cheaply.  For the dominant edge workload (one fixed
//! system prompt + short user turns) this turns almost all prompt tokens
//! into a state copy: zero weight bytes, zero forward passes.
//!
//! Structure: a token trie keyed by feed streams (`[BOS, prompt...]`).
//! Any node may hold a snapshot — the state after consuming exactly that
//! prefix — plus an LRU stamp.  [`StateCache::lookup`] walks a feed and
//! returns the DEEPEST snapshot on its path (longest-prefix match);
//! [`StateCache::insert`] stores a snapshot, evicting least-recently-used
//! snapshots until the byte budget (`CacheConfig::max_bytes`, state
//! payload only — trie nodes are noise next to multi-MB states) holds.
//! Eviction prunes emptied trie branches so dead prompts do not leak
//! nodes.
//!
//! Concurrency: the cache is deliberately NOT thread-safe.  It lives on
//! the coordinator's single round thread (the only place sessions are
//! mutated), so the hot path pays no locks.
//!
//! Insertions are driven from `RwkvEngine::step_round_cached` at prefill
//! chunk boundaries: after a fused round advances a prefill session to
//! `pos`, the session's state is exactly "feed[..pos] consumed" and is
//! snapshotted under that prefix.  Lookups happen once per request in
//! [`super::session::Session::new_with_cache`], which forks the session
//! off the matched snapshot and starts prefill at `pos = matched_len`.
//!
//! Persistence: [`StateCache::save`] / [`StateCache::load`] round-trip
//! every snapshot through `io::statefile` (versioned header, f32
//! payload), bit-exact, so a warm cache survives process restarts
//! (`--state-file`).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use super::state::RwkvState;

/// Sizing knobs for a [`StateCache`].
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Byte budget for resident snapshots (state payload, via
    /// [`RwkvState::nbytes`]).  Inserting past it evicts LRU snapshots;
    /// a single state larger than the whole budget is refused.
    pub max_bytes: u64,
    /// Shortest prefix worth snapshotting (in feed tokens, BOS included).
    /// Very short prefixes save almost nothing and pollute the budget.
    pub min_prefix: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self { max_bytes: 64 << 20, min_prefix: 1 }
    }
}

impl CacheConfig {
    /// A config with `mb` MiB of budget and the default `min_prefix`.
    pub fn with_mb(mb: usize) -> Self {
        Self { max_bytes: (mb as u64) << 20, ..Self::default() }
    }
}

/// Monotonic counters (never reset; `cache_bytes` is read live from
/// [`StateCache::bytes`] instead because residency goes down too).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that matched a snapshot.
    pub hits: u64,
    /// Lookups that matched nothing.
    pub misses: u64,
    /// Feed tokens served from snapshots instead of prefill passes.
    pub hit_tokens: u64,
    /// Snapshots stored (refreshing an existing prefix does not count).
    pub insertions: u64,
    /// Snapshots evicted to hold the byte budget.
    pub evictions: u64,
}

struct Snap {
    state: Arc<RwkvState>,
    bytes: u64,
    /// LRU clock value of the last lookup hit / insert.
    stamp: u64,
    /// Prefix length (trie depth) — returned as `matched_len`.
    len: usize,
}

struct Node {
    token: u32,
    parent: usize,
    children: BTreeMap<u32, usize>,
    snap: Option<Snap>,
}

const ROOT: usize = 0;

pub struct StateCache {
    cfg: CacheConfig,
    /// Trie arena; node 0 is the root.  Freed nodes go on `free` and are
    /// reused (their `snap` is `None` and `children` empty meanwhile).
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// Recency index: stamp -> snapshot-bearing node.  Stamps are unique
    /// (the clock only moves forward), so the map's first entry is always
    /// the LRU victim — eviction is O(log snapshots), never an arena
    /// scan.  Invariant: one entry per resident snapshot, keyed by its
    /// current stamp.
    lru: BTreeMap<u64, usize>,
    clock: u64,
    bytes: u64,
    snapshots: usize,
    stats: CacheStats,
}

impl StateCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let root = Node { token: 0, parent: ROOT, children: BTreeMap::new(), snap: None };
        Self {
            cfg,
            nodes: vec![root],
            free: Vec::new(),
            lru: BTreeMap::new(),
            clock: 0,
            bytes: 0,
            snapshots: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    /// Resident snapshot bytes (the telemetry `cache_bytes` gauge).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Resident snapshot count.
    pub fn snapshots(&self) -> usize {
        self.snapshots
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Longest-prefix match: the deepest snapshot along `tokens`' path,
    /// as `(snapshot, matched_len)` — the state after consuming exactly
    /// `tokens[..matched_len]`.  A hit refreshes the snapshot's recency.
    ///
    /// Callers pass the feed MINUS its final position (`Session::
    /// new_with_cache` does): the last token must always re-run so the
    /// round has logits to sample from.
    pub fn lookup(&mut self, tokens: &[u32]) -> Option<(Arc<RwkvState>, usize)> {
        let mut cur = ROOT;
        let mut best: Option<usize> = None;
        for &t in tokens {
            match self.nodes[cur].children.get(&t).copied() {
                Some(next) => {
                    cur = next;
                    if self.nodes[cur].snap.is_some() {
                        best = Some(cur);
                    }
                }
                None => break,
            }
        }
        match best {
            Some(ni) => {
                self.clock += 1;
                let snap = self.nodes[ni].snap.as_mut().expect("best node has snap");
                let old_stamp = snap.stamp;
                snap.stamp = self.clock;
                let len = snap.len;
                let state = Arc::clone(&snap.state);
                self.lru.remove(&old_stamp);
                self.lru.insert(self.clock, ni);
                self.stats.hits += 1;
                self.stats.hit_tokens += len as u64;
                Some((state, len))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// `true` when a snapshot exists at exactly `prefix` (no recency
    /// refresh, no stats).
    pub fn contains(&self, prefix: &[u32]) -> bool {
        let mut cur = ROOT;
        for &t in prefix {
            match self.nodes[cur].children.get(&t).copied() {
                Some(next) => cur = next,
                None => return false,
            }
        }
        cur != ROOT && self.nodes[cur].snap.is_some()
    }

    /// Snapshot `state` under `prefix`, evicting LRU snapshots first if
    /// the budget needs room.  Returns `true` if a new snapshot was
    /// stored; refreshing an already-cached prefix only touches its
    /// recency (and skips the state clone entirely).  Prefixes shorter
    /// than `min_prefix`, empty prefixes and states larger than the whole
    /// budget are refused.
    pub fn insert(&mut self, prefix: &[u32], state: &RwkvState) -> bool {
        let sbytes = state.nbytes();
        if prefix.is_empty() || prefix.len() < self.cfg.min_prefix || sbytes > self.cfg.max_bytes {
            return false;
        }
        let mut cur = ROOT;
        for &t in prefix {
            cur = match self.nodes[cur].children.get(&t).copied() {
                Some(next) => next,
                None => {
                    let node =
                        Node { token: t, parent: cur, children: BTreeMap::new(), snap: None };
                    let ni = self.alloc(node);
                    self.nodes[cur].children.insert(t, ni);
                    ni
                }
            };
        }
        self.clock += 1;
        if let Some(s) = self.nodes[cur].snap.as_mut() {
            if s.state.same_shape(state) {
                let old_stamp = s.stamp;
                s.stamp = self.clock;
                self.lru.remove(&old_stamp);
                self.lru.insert(self.clock, cur);
                return false;
            }
            // a stale snapshot from another model's run (e.g. a reused
            // state file) would otherwise pin this prefix cold forever —
            // replace it with the live engine's state
            let old = self.nodes[cur].snap.take().expect("checked above");
            self.lru.remove(&old.stamp);
            self.bytes -= old.bytes;
            self.snapshots -= 1;
            self.stats.evictions += 1;
        }
        // store FIRST, then evict to budget: the new snapshot carries the
        // newest stamp so the LRU order never victimizes it (unless it
        // were the sole snapshot — impossible while over budget, since
        // sbytes <= max_bytes).  Evicting first would let `prune` free the
        // still-snapless `cur` when the victim is its only descendant.
        self.nodes[cur].snap = Some(Snap {
            state: Arc::new(state.clone()),
            bytes: sbytes,
            stamp: self.clock,
            len: prefix.len(),
        });
        self.lru.insert(self.clock, cur);
        self.bytes += sbytes;
        self.snapshots += 1;
        self.stats.insertions += 1;
        while self.bytes > self.cfg.max_bytes {
            if !self.evict_lru() {
                break; // unreachable: at least the new snapshot exists
            }
        }
        true
    }

    /// Drop every snapshot (stats are kept — they are monotonic).
    pub fn clear(&mut self) {
        let root = Node { token: 0, parent: ROOT, children: BTreeMap::new(), snap: None };
        self.nodes = vec![root];
        self.free.clear();
        self.lru.clear();
        self.bytes = 0;
        self.snapshots = 0;
    }

    /// Evict the least-recently-used snapshot (the recency index's first
    /// entry) and prune its now-empty branch.  `false` when the cache
    /// holds no snapshots.
    fn evict_lru(&mut self) -> bool {
        let Some((_, vi)) = self.lru.pop_first() else {
            return false;
        };
        let snap = self.nodes[vi].snap.take().expect("lru entry has snap");
        self.bytes -= snap.bytes;
        self.snapshots -= 1;
        self.stats.evictions += 1;
        self.prune(vi);
        true
    }

    /// Free trie nodes from `ni` upward while they carry neither a
    /// snapshot nor children.
    fn prune(&mut self, mut ni: usize) {
        while ni != ROOT && self.nodes[ni].snap.is_none() && self.nodes[ni].children.is_empty() {
            let parent = self.nodes[ni].parent;
            let token = self.nodes[ni].token;
            self.nodes[parent].children.remove(&token);
            self.free.push(ni);
            ni = parent;
        }
    }

    fn alloc(&mut self, node: Node) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Reconstruct a snapshot node's prefix by walking parent links.
    fn prefix_of(&self, mut ni: usize) -> Vec<u32> {
        let mut out = Vec::new();
        while ni != ROOT {
            out.push(self.nodes[ni].token);
            ni = self.nodes[ni].parent;
        }
        out.reverse();
        out
    }

    /// Every resident snapshot as `(prefix, state)`, least-recently-used
    /// first (the recency index's order) — the order [`StateCache::save`]
    /// persists, so a reload re-inserts oldest-first and recency survives
    /// the round trip.
    pub fn entries(&self) -> Vec<(Vec<u32>, Arc<RwkvState>)> {
        self.lru
            .values()
            .map(|&ni| {
                let snap = self.nodes[ni].snap.as_ref().expect("lru node has snap");
                (self.prefix_of(ni), Arc::clone(&snap.state))
            })
            .collect()
    }

    /// Persist every snapshot to `path` (`io::statefile`) under a
    /// writer-chosen model fingerprint `tag`; returns how many were
    /// written.
    pub fn save(&self, path: &Path, tag: &str) -> Result<usize> {
        let entries = self.entries();
        let refs: Vec<(&[u32], &RwkvState)> =
            entries.iter().map(|(p, s)| (p.as_slice(), s.as_ref())).collect();
        crate::io::write_statefile(path, tag, &refs)?;
        Ok(refs.len())
    }

    /// Load snapshots from `path` into this cache, ignoring the file's
    /// tag (budget and `min_prefix` apply as usual).  A missing file is a
    /// fresh start, not an error.  Returns how many snapshots were
    /// inserted.  Serving code should use [`StateCache::load_matching`].
    pub fn load(&mut self, path: &Path) -> Result<usize> {
        if !path.exists() {
            return Ok(0);
        }
        let (_tag, entries) = crate::io::read_statefile(path)?;
        let mut n = 0;
        for (prefix, state) in entries {
            if self.insert(&prefix, &state) {
                n += 1;
            }
        }
        Ok(n)
    }

    /// [`StateCache::load`] restricted to a file whose model fingerprint
    /// equals `tag` AND to snapshots whose shape matches `template`
    /// (e.g. `engine.new_state()`).  Shape alone cannot tell two
    /// checkpoints apart — a fine-tuned model has identical dims but
    /// different weights, and forking its states would silently break the
    /// warm==cold bit-identity contract — so a tag mismatch rejects the
    /// whole file (an error the coordinator logs, then starts cold).
    pub fn load_matching(&mut self, path: &Path, tag: &str, template: &RwkvState) -> Result<usize> {
        if !path.exists() {
            return Ok(0);
        }
        let (file_tag, entries) = crate::io::read_statefile(path)?;
        if file_tag != tag {
            anyhow::bail!(
                "state file was written by a different model (file tag '{file_tag}', \
                 current '{tag}') — starting cold"
            );
        }
        let mut n = 0;
        for (prefix, state) in entries {
            if state.same_shape(template) && self.insert(&prefix, &state) {
                n += 1;
            }
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(tag: f32) -> RwkvState {
        let mut st = RwkvState::zero(1, 4, 1, 4);
        st.att_x[0][0] = tag;
        st
    }

    fn cache(max_states: u64) -> StateCache {
        let bytes = state(0.0).nbytes();
        StateCache::new(CacheConfig { max_bytes: max_states * bytes, min_prefix: 1 })
    }

    #[test]
    fn longest_prefix_match_wins() {
        let mut c = cache(8);
        assert!(c.insert(&[2, 5], &state(1.0)));
        assert!(c.insert(&[2, 5, 7, 9], &state(2.0)));
        // deeper snapshot on the path wins
        let (st, len) = c.lookup(&[2, 5, 7, 9, 11]).unwrap();
        assert_eq!(len, 4);
        assert_eq!(st.att_x[0][0], 2.0);
        // diverging after [2,5] falls back to the shallower snapshot
        let (st, len) = c.lookup(&[2, 5, 8]).unwrap();
        assert_eq!(len, 2);
        assert_eq!(st.att_x[0][0], 1.0);
        assert!(c.lookup(&[3, 3]).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.hit_tokens), (2, 1, 6));
    }

    #[test]
    fn reinsert_refreshes_without_storing() {
        let mut c = cache(8);
        assert!(c.insert(&[2, 5], &state(1.0)));
        assert!(!c.insert(&[2, 5], &state(9.0)), "existing prefix only refreshed");
        assert_eq!(c.snapshots(), 1);
        let (st, _) = c.lookup(&[2, 5]).unwrap();
        assert_eq!(st.att_x[0][0], 1.0, "original snapshot kept");
        assert_eq!(c.stats().insertions, 1);
    }

    #[test]
    fn lru_eviction_holds_byte_budget_and_prunes() {
        let mut c = cache(2);
        assert!(c.insert(&[2, 1], &state(1.0)));
        assert!(c.insert(&[2, 2], &state(2.0)));
        // touch [2,1] so [2,2] is the LRU victim
        c.lookup(&[2, 1]).unwrap();
        let nodes_before = c.nodes.len();
        assert!(c.insert(&[2, 3, 4], &state(3.0)));
        assert_eq!(c.snapshots(), 2);
        assert!(c.bytes() <= c.config().max_bytes);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.lookup(&[2, 2]).is_none(), "LRU snapshot evicted");
        assert!(c.lookup(&[2, 1]).is_some(), "recently used survives");
        // the [2,2] branch was pruned and its node reused by [2,3,4]
        assert!(nodes_before >= c.nodes.len() - 1);
        // evicting everything leaves an insertable cache
        assert!(c.insert(&[9, 9], &state(4.0)));
        assert!(c.insert(&[8, 8], &state(5.0)));
        assert!(c.lookup(&[9, 9]).is_some());
    }

    /// Regression: inserting a SHORTER prefix whose only-descendant
    /// snapshot is the eviction victim must not free the node being
    /// inserted into (evict-then-store did; store-then-evict cannot).
    #[test]
    fn eviction_of_descendant_keeps_new_ancestor_snapshot() {
        let mut c = cache(1); // budget: exactly one snapshot
        assert!(c.insert(&[2, 5, 7], &state(1.0)));
        // same path, shorter prefix: [2,5] is snapless interior; the
        // eviction victim [2,5,7] hangs below it
        assert!(c.insert(&[2, 5], &state(2.0)));
        assert_eq!(c.snapshots(), 1);
        assert_eq!(c.stats().evictions, 1);
        let (st, len) = c.lookup(&[2, 5, 7]).expect("ancestor snapshot survives");
        assert_eq!(len, 2);
        assert_eq!(st.att_x[0][0], 2.0);
        // the trie stayed consistent: a fresh insert under the same path
        // works and is found
        assert!(c.insert(&[2, 5, 9], &state(3.0)));
        let (st, len) = c.lookup(&[2, 5, 9]).expect("fresh descendant insert works");
        assert_eq!(len, 3);
        assert_eq!(st.att_x[0][0], 3.0);
    }

    #[test]
    fn refuses_undersized_and_oversized() {
        let bytes = state(0.0).nbytes();
        let mut c = StateCache::new(CacheConfig { max_bytes: bytes * 4, min_prefix: 3 });
        assert!(!c.insert(&[2, 5], &state(1.0)), "below min_prefix");
        assert!(!c.insert(&[], &state(1.0)), "empty prefix");
        assert!(c.insert(&[2, 5, 6], &state(1.0)));
        let big = RwkvState::zero(64, 64, 8, 8);
        assert!(big.nbytes() > c.config().max_bytes);
        assert!(!c.insert(&[2, 5, 6, 7], &big), "state larger than whole budget");
        assert_eq!(c.snapshots(), 1);
    }

    /// A stale snapshot with a different model shape at the same prefix
    /// is REPLACED by a live insert (never pinned forever), and
    /// `load_matching` filters foreign shapes out up front.
    #[test]
    fn stale_shape_snapshot_is_replaced_and_filtered() {
        let mut c = StateCache::new(CacheConfig { max_bytes: 1 << 20, min_prefix: 1 });
        let foreign = RwkvState::zero(2, 8, 2, 4); // a different model's shape
        assert!(c.insert(&[2, 5], &foreign));
        // the live engine inserts its own shape at the same prefix
        assert!(c.insert(&[2, 5], &state(7.0)), "stale snapshot must be replaced");
        assert_eq!(c.snapshots(), 1);
        assert_eq!(c.stats().evictions, 1, "replacement accounts as an eviction");
        assert_eq!(c.bytes(), state(7.0).nbytes());
        let (st, _) = c.lookup(&[2, 5]).unwrap();
        assert!(st.bitwise_eq(&state(7.0)));
        // load_matching refuses a different model's file: by fingerprint
        // tag (same-shape fine-tunes!) and, within a file, by shape
        let dir = std::env::temp_dir().join(format!("rwkv-sc-shape-{}", std::process::id()));
        let path = dir.join("cache.rwst");
        let mut foreign_cache = StateCache::new(CacheConfig { max_bytes: 1 << 20, min_prefix: 1 });
        assert!(foreign_cache.insert(&[2, 9], &foreign));
        foreign_cache.save(&path, "model-a").unwrap();
        let mut c2 = StateCache::new(CacheConfig { max_bytes: 1 << 20, min_prefix: 1 });
        assert!(
            c2.load_matching(&path, "model-b", &foreign).is_err(),
            "a tag mismatch (e.g. a same-shape fine-tune) rejects the file"
        );
        assert_eq!(c2.snapshots(), 0);
        assert_eq!(c2.load_matching(&path, "model-a", &state(0.0)).unwrap(), 0);
        assert_eq!(c2.snapshots(), 0, "matching tag but foreign shape loads nothing");
        assert_eq!(c2.load_matching(&path, "model-a", &foreign).unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("rwkv-sc-rt-{}", std::process::id()));
        let path = dir.join("cache.rwst");
        let mut c = cache(8);
        assert!(c.insert(&[2, 5], &state(1.5)));
        assert!(c.insert(&[2, 5, 7], &state(2.5)));
        assert_eq!(c.save(&path, "m").unwrap(), 2);
        let mut c2 = cache(8);
        assert_eq!(c2.load(&path).unwrap(), 2);
        let (st, len) = c2.lookup(&[2, 5, 7]).unwrap();
        assert_eq!(len, 3);
        assert!(st.bitwise_eq(&state(2.5)));
        // missing file: fresh start, not an error
        let mut c3 = cache(8);
        assert_eq!(c3.load(&dir.join("nope.rwst")).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
