//! Embedding LRU cache (§3.3): only embeddings of recently-seen tokens are
//! resident.  Token usage is long-tailed (Zipf), so a cache of ~1.5% of
//! the table serves almost all lookups; misses stream one row from the
//! checkpoint mmap.

use std::collections::HashMap;

use anyhow::Result;

use crate::engine::weights::WeightStore;
use crate::metrics::{Group, MemTracker};

pub struct EmbCache {
    capacity: usize,
    dim: usize,
    row_bytes: u64,
    entries: HashMap<u32, (Vec<f32>, u64)>, // token -> (row, last-use tick)
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl EmbCache {
    pub fn new(capacity: usize, dim: usize, row_bytes: u64) -> Self {
        Self {
            capacity: capacity.max(1),
            dim,
            row_bytes,
            entries: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Fetch the embedding of `token` into `out`, loading through the
    /// store on miss and evicting LRU beyond capacity.
    pub fn fetch(
        &mut self,
        store: &WeightStore,
        tracker: &MemTracker,
        token: u32,
        out: &mut [f32],
    ) -> Result<()> {
        self.tick += 1;
        if let Some((row, t)) = self.entries.get_mut(&token) {
            *t = self.tick;
            out.copy_from_slice(row);
            self.hits += 1;
            return Ok(());
        }
        self.misses += 1;
        let mut row = vec![0.0f32; self.dim];
        store.emb_row(token, &mut row)?;
        out.copy_from_slice(&row);
        tracker.load(Group::Emb, self.row_bytes);
        if self.entries.len() >= self.capacity {
            // evict least-recently-used
            if let Some((&lru, _)) = self.entries.iter().min_by_key(|(_, (_, t))| *t) {
                self.entries.remove(&lru);
                tracker.unload(Group::Emb, self.row_bytes);
                self.evictions += 1;
            }
        }
        self.entries.insert(token, (row, self.tick));
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Resident bytes (capacity-bounded).
    pub fn resident_bytes(&self) -> u64 {
        self.entries.len() as u64 * self.row_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A store-free LRU logic test via the internal maps (fetch() needs a
    // real store; integration tests cover that path).
    #[test]
    fn lru_eviction_order() {
        let mut c = EmbCache::new(2, 4, 8);
        // simulate inserts directly
        c.tick += 1;
        c.entries.insert(1, (vec![0.0; 4], c.tick));
        c.tick += 1;
        c.entries.insert(2, (vec![0.0; 4], c.tick));
        // touch 1 so 2 becomes LRU
        c.tick += 1;
        c.entries.get_mut(&1).unwrap().1 = c.tick;
        let lru = *c.entries.iter().min_by_key(|(_, (_, t))| *t).unwrap().0;
        assert_eq!(lru, 2);
    }

    #[test]
    fn hit_rate_zero_when_untouched() {
        let c = EmbCache::new(4, 4, 8);
        assert_eq!(c.hit_rate(), 0.0);
        assert!(c.is_empty());
    }
}
