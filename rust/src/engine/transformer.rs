//! Baseline transformer engine (OPT/GPT-Neo/TinyLlama stand-in, S3).
//!
//! Pre-LN GPT with learned positions, causal multi-head attention and a
//! GELU MLP — the comparison models of Figures 5 and 10.  The KV cache
//! grows O(T) per layer; Figure 5's memory comparison excludes it (as the
//! paper does, favoring transformers), but we track it under
//! `Group::State` so `exp fig5 --with-kv` can show the honest number.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::EngineConfig;
use crate::metrics::Group;
use crate::tensor::{gelu, layer_norm, matvec_in_out, matvec_rows, Mat};
use crate::util::softmax_inplace;
use super::sampler::Sampler;
use super::weights::{LnW, WeightStore};

pub struct TfBlockW {
    pub ln1: LnW,
    pub ln2: LnW,
    pub wq: Arc<Mat>,
    pub wk: Arc<Mat>,
    pub wv: Arc<Mat>,
    pub wo: Arc<Mat>,
    pub up: Arc<Mat>,
    pub down: Arc<Mat>,
}

/// Per-layer KV cache: k/v rows appended per timestep.
pub struct KvCache {
    pub k: Vec<f32>, // t * dim
    pub v: Vec<f32>,
    pub t: usize,
}

pub struct TransformerEngine {
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_size: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub store: Arc<WeightStore>,
    emb: Arc<Mat>,
    pos: Arc<Mat>,
    ln_out: LnW,
    head: Arc<Mat>,
    blocks: Vec<TfBlockW>,
    pub kv: Vec<KvCache>,
}

impl TransformerEngine {
    pub fn load(cfg: &EngineConfig) -> Result<Self> {
        let manifest_path: PathBuf = cfg
            .artifacts
            .join("models")
            .join(format!("{}.json", cfg.model));
        let store = Arc::new(WeightStore::open(&manifest_path)?);
        let m = store.manifest.clone();
        if m.is_rwkv() {
            bail!("{} is an RWKV checkpoint; use RwkvEngine", cfg.model);
        }
        let mut blocks = Vec::new();
        for i in 0..m.layers {
            let p = format!("b{i}");
            blocks.push(TfBlockW {
                ln1: LnW::load(&store, &format!("{p}.ln1"))?,
                ln2: LnW::load(&store, &format!("{p}.ln2"))?,
                wq: store.mat(&format!("{p}.att.wq"))?,
                wk: store.mat(&format!("{p}.att.wk"))?,
                wv: store.mat(&format!("{p}.att.wv"))?,
                wo: store.mat(&format!("{p}.att.wo"))?,
                up: store.mat(&format!("{p}.mlp.up"))?,
                down: store.mat(&format!("{p}.mlp.down"))?,
            });
        }
        let max_seq = store.manifest.raw.f64_at(&["max_seq"]).unwrap_or(512.0) as usize;
        Ok(Self {
            dim: m.dim,
            layers: m.layers,
            heads: m.heads,
            head_size: m.head_size,
            vocab: m.vocab,
            max_seq,
            emb: store.mat("emb")?,
            pos: store.mat("pos")?,
            ln_out: LnW::load(&store, "ln_out")?,
            head: store.mat("head")?,
            kv: (0..m.layers).map(|_| KvCache { k: vec![], v: vec![], t: 0 }).collect(),
            blocks,
            store,
        })
    }

    pub fn reset(&mut self) {
        for kv in &mut self.kv {
            let bytes = 4 * (kv.k.len() + kv.v.len()) as u64;
            self.store.tracker.unload(Group::State, bytes);
            kv.k.clear();
            kv.v.clear();
            kv.t = 0;
        }
    }

    /// One decode step; returns logits.
    pub fn forward_token(&mut self, token: u32) -> Result<Vec<f32>> {
        let d = self.dim;
        let (h, s) = (self.heads, self.head_size);
        let t_now = self.kv[0].t;
        if t_now >= self.max_seq {
            bail!("sequence exceeds max_seq={}", self.max_seq);
        }
        let mut x = vec![0.0f32; d];
        self.emb.decode_row(token as usize, &mut x);
        let mut pos_row = vec![0.0f32; d];
        self.pos.decode_row(t_now, &mut pos_row);
        for i in 0..d {
            x[i] += pos_row[i];
        }
        let mut xn = vec![0.0f32; d];
        let (mut q, mut k, mut v) = (vec![0.0f32; d], vec![0.0f32; d], vec![0.0f32; d]);
        let mut att_out = vec![0.0f32; d];
        let mut acc = Vec::new(); // i8 matvec dequant scratch
        for li in 0..self.layers {
            let b = &self.blocks[li];
            layer_norm(&x, &b.ln1.scale, &b.ln1.bias, 1e-5, &mut xn);
            q.fill(0.0);
            k.fill(0.0);
            v.fill(0.0);
            matvec_in_out(&xn, &b.wq, &mut q, &mut acc);
            matvec_in_out(&xn, &b.wk, &mut k, &mut acc);
            matvec_in_out(&xn, &b.wv, &mut v, &mut acc);
            let kv = &mut self.kv[li];
            kv.k.extend_from_slice(&k);
            kv.v.extend_from_slice(&v);
            kv.t += 1;
            self.store.tracker.load(Group::State, 8 * d as u64);
            let t_len = kv.t;
            att_out.fill(0.0);
            let inv_sqrt = 1.0 / (s as f32).sqrt();
            let mut scores = vec![0.0f32; t_len];
            for hh in 0..h {
                let qh = &q[hh * s..(hh + 1) * s];
                for (tt, sc) in scores.iter_mut().enumerate() {
                    let kh = &kv.k[tt * d + hh * s..tt * d + (hh + 1) * s];
                    *sc = crate::tensor::dot_f32(qh, kh) * inv_sqrt;
                }
                softmax_inplace(&mut scores);
                let oh = &mut att_out[hh * s..(hh + 1) * s];
                for (tt, &p) in scores.iter().enumerate() {
                    let vh = &kv.v[tt * d + hh * s..tt * d + (hh + 1) * s];
                    for j in 0..s {
                        oh[j] += p * vh[j];
                    }
                }
            }
            matvec_in_out(&att_out, &b.wo, &mut x, &mut acc); // += residual
            // MLP
            layer_norm(&x, &b.ln2.scale, &b.ln2.bias, 1e-5, &mut xn);
            let mut hidden = vec![0.0f32; b.up.cols()];
            matvec_in_out(&xn, &b.up, &mut hidden, &mut acc);
            for hv in hidden.iter_mut() {
                *hv = gelu(*hv);
            }
            matvec_in_out(&hidden, &b.down, &mut x, &mut acc); // += residual
        }
        layer_norm(&x, &self.ln_out.scale, &self.ln_out.bias, 1e-5, &mut xn);
        let mut logits = vec![0.0f32; self.vocab];
        matvec_rows(&self.head, &xn, &mut logits);
        Ok(logits)
    }

    pub fn generate(&mut self, prompt: &[u32], n: usize, sampler: &mut Sampler) -> Result<Vec<u32>> {
        let mut last = crate::text::BOS;
        for &t in prompt {
            self.forward_token(last)?;
            last = t;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut logits = self.forward_token(last)?;
            let tok = sampler.sample(&mut logits);
            out.push(tok);
            last = tok;
        }
        Ok(out)
    }

    pub fn memory_report(&self) -> (u64, u64) {
        (self.store.tracker.current(), self.store.tracker.peak())
    }

    /// Weight bytes excluding the KV cache (Figure 5's convention).
    pub fn weight_bytes(&self) -> u64 {
        self.store.rkv.total_bytes()
    }
}
