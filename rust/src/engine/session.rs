//! Session-centric inference: one `step_round` entry point for mixed
//! prefill + decode work.
//!
//! A [`Session`] owns everything one generation request needs — the
//! recurrent [`RwkvState`], the [`Sampler`], generation params
//! (`max_tokens`, `stop_tokens`) and its [`Phase`].  The engine advances
//! any set of sessions with [`RwkvEngine::step_round`]: prefill sessions
//! move a chunk of up to `cfg.prefill_chunk` prompt tokens, decode
//! sessions move one token, and everything shares ONE weight-streaming
//! pass (the fused segment rounds in `engine::forward_segments`).
//! Sampling and
//! stop-checking happen inside the round, so callers only consume the
//! emitted tokens from the returned [`RoundReport`].
//!
//! Invariants:
//! * A session's token stream is `[BOS, prompt...]`; the head runs only on
//!   the stream's final position and on decode rows, so non-final prompt
//!   positions never pay head bytes.
//! * Chunked prefill is bit-identical to feeding the same tokens through
//!   [`RwkvEngine::forward_hidden`] one at a time (every chunk size, every
//!   dtype/technique config) — enforced by `tests/prefill_equivalence.rs`.
//! * A round's dense-layer weight bytes are constant in the number of
//!   prefill rows and decode slots (`RoundReport::round_weight_bytes`).

use anyhow::Result;

use super::sampler::Sampler;
use super::state::RwkvState;
use super::state_cache::StateCache;
use super::{RwkvEngine, SegSpan};

/// Why a session stopped emitting tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted `max_tokens` tokens.
    MaxTokens,
    /// Sampled a stop token (EOS or a request-supplied stop id); the stop
    /// token itself is emitted, matching the coordinator's historical
    /// EOS behaviour.
    Stop(u32),
    /// The emitted stream's suffix matched a multi-token stop sequence
    /// ([`Session::stop_seqs`]); carries the matched sequence's index.
    /// The matching tokens were already emitted in-stream.
    StopSeq(u32),
    /// Cancelled by the caller ([`Session::cancel`]) or retired by the
    /// coordinator after the client went away.
    Cancelled,
    /// The request's deadline passed before the session finished (the
    /// coordinator checks at round boundaries, so partial tokens were
    /// already streamed).  Wire name: `"deadline"`.
    DeadlineExceeded,
}

impl FinishReason {
    /// Stable wire name (server protocol / CLI reporting).
    pub fn name(self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "length",
            FinishReason::Stop(_) | FinishReason::StopSeq(_) => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline",
        }
    }
}

/// Where a session is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Consuming the prompt; `pos` tokens of the feed stream are done.
    Prefill { pos: usize },
    /// Prompt consumed; each round emits one sampled token.
    Decode,
    /// No further work; the session keeps its final state for inspection.
    Done { reason: FinishReason },
}

/// One in-flight generation request: recurrent state + sampler + params +
/// phase.  Construct with [`Session::new`], then adjust the public fields;
/// drive with [`RwkvEngine::step_round`].
pub struct Session {
    pub id: u64,
    pub sampler: Sampler,
    pub max_tokens: usize,
    /// Token ids that end the session when sampled (the coordinator adds
    /// EOS; [`RwkvEngine::generate`] leaves this empty for fixed-length
    /// generation).
    pub stop_tokens: Vec<u32>,
    /// Multi-token stop sequences: the session ends when the EMITTED
    /// stream's suffix equals any of these (the matching tokens are
    /// emitted, consistent with single stop-token semantics).  Empty
    /// sequences never match.
    pub stop_seqs: Vec<Vec<u32>>,
    /// Participate in the prefix-state cache: lookup happens in
    /// [`Session::new_with_cache`]; snapshot insertion happens at prefill
    /// chunk boundaries when a cache is passed to
    /// [`RwkvEngine::step_round_cached`].  `false` opts the request out
    /// of both (the server's per-request `"cache": false`).
    pub use_cache: bool,
    state: RwkvState,
    /// `[BOS, prompt...]` — the teacher-forced stream prefill consumes.
    feed: Vec<u32>,
    phase: Phase,
    last_token: u32,
    produced: usize,
    /// Trailing window of emitted tokens, as long as the longest stop
    /// sequence — the suffix the stop-sequence match runs over.
    tail: Vec<u32>,
    /// Already surfaced in a `RoundReport::finished` (exactly-once).
    reported: bool,
}

impl Session {
    /// A session for `prompt`, defaulting to greedy sampling seeded by
    /// `id` and `max_tokens = 32`; set the public fields to customize.
    pub fn new(engine: &RwkvEngine, id: u64, prompt: &[u32]) -> Self {
        let mut feed = Vec::with_capacity(prompt.len() + 1);
        feed.push(crate::text::BOS);
        feed.extend_from_slice(prompt);
        Self {
            id,
            sampler: Sampler::new(0.0, 1.0, id),
            max_tokens: 32,
            stop_tokens: Vec::new(),
            stop_seqs: Vec::new(),
            use_cache: true,
            state: engine.new_state(),
            feed,
            phase: Phase::Prefill { pos: 0 },
            last_token: crate::text::BOS,
            produced: 0,
            tail: Vec::new(),
            reported: false,
        }
    }

    /// Like [`Session::new`], but forked off the prefix-state cache: the
    /// longest cached prefix of the feed stream becomes the starting
    /// state (one `RwkvState` copy — zero weight bytes) and prefill
    /// begins at `pos = matched_len`.  The final feed position is never
    /// matched — it must run through the model so the round has logits to
    /// sample the first token from.  Returns the session plus the number
    /// of feed tokens served from the cache (`0` on a miss).
    ///
    /// Warm-cache decode is bit-identical to cold prefill: the snapshot
    /// IS the state the cold path would have computed at that position
    /// (`tests/state_cache_equivalence.rs`).
    pub fn new_with_cache(
        engine: &RwkvEngine,
        id: u64,
        prompt: &[u32],
        cache: &mut StateCache,
    ) -> (Self, usize) {
        let mut sess = Self::new(engine, id, prompt);
        let cap = sess.feed.len() - 1;
        if let Some((snap, matched)) = cache.lookup(&sess.feed[..cap]) {
            // a persisted cache from a different model must never fork a
            // shape-mismatched state — fall back to cold prefill instead
            if snap.same_shape(&sess.state) {
                sess.state = (*snap).clone();
                sess.phase = Phase::Prefill { pos: matched };
                return (sess, matched);
            }
        }
        (sess, 0)
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn is_done(&self) -> bool {
        matches!(self.phase, Phase::Done { .. })
    }

    /// `Some(reason)` once the session is done.
    pub fn finish_reason(&self) -> Option<FinishReason> {
        match self.phase {
            Phase::Done { reason } => Some(reason),
            _ => None,
        }
    }

    /// Tokens emitted so far.
    pub fn tokens_produced(&self) -> usize {
        self.produced
    }

    /// Stop the session; the next round reports it finished.  No-op once
    /// done (a real finish reason is never overwritten).
    pub fn cancel(&mut self) {
        self.finish(FinishReason::Cancelled);
    }

    /// Stop the session with an explicit terminal `reason` (the
    /// coordinator's deadline enforcement).  No-op once done — an earlier
    /// finish reason is never overwritten.
    pub fn finish(&mut self, reason: FinishReason) {
        if !self.is_done() {
            self.phase = Phase::Done { reason };
        }
    }

    pub fn state(&self) -> &RwkvState {
        &self.state
    }

    /// Record an emitted token in the stop-sequence window (bounded by
    /// the longest sequence; a no-op when there are none).
    fn note_emitted(&mut self, tok: u32) {
        let keep = self.stop_seqs.iter().map(|s| s.len()).max().unwrap_or(0);
        if keep == 0 {
            return;
        }
        self.tail.push(tok);
        if self.tail.len() > keep {
            let excess = self.tail.len() - keep;
            self.tail.drain(..excess);
        }
    }

    /// Index of the first stop sequence that suffix-matches the emitted
    /// stream, if any.
    fn matched_stop_seq(&self) -> Option<usize> {
        self.stop_seqs.iter().position(|seq| {
            !seq.is_empty()
                && self.tail.len() >= seq.len()
                && self.tail[self.tail.len() - seq.len()..] == seq[..]
        })
    }

    /// Exchange the session's recurrent state with `other` (lets callers
    /// resume from / recover an externally owned state without copying).
    pub fn swap_state(&mut self, other: &mut RwkvState) {
        std::mem::swap(&mut self.state, other);
    }
}

/// A token emitted by [`RwkvEngine::step_round`]; `session` indexes the
/// slice passed to the round.
#[derive(Clone, Copy, Debug)]
pub struct Emission {
    pub session: usize,
    pub token: u32,
}

/// What one scheduling round did.
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    /// Sampled tokens, in session order (at most one per session).
    pub emitted: Vec<Emission>,
    /// Sessions that entered `Done` this round (indexes into the slice).
    pub finished: Vec<usize>,
    /// Prompt tokens advanced across all prefill sessions.
    pub prefill_tokens: usize,
    /// Decode rows advanced (one per decode session).
    pub decode_tokens: usize,
    /// Weight bytes streamed by the fused pass — constant in the number
    /// of prefill/decode sessions for dense layers (0 on the XLA
    /// fallback, which has no byte accounting).
    pub round_weight_bytes: u64,
}

impl RwkvEngine {
    /// Advance every active session by one scheduling round through ONE
    /// pass over the weights: prefill sessions move up to
    /// `cfg.prefill_chunk` prompt tokens, decode sessions move one token,
    /// and sessions that reach a sampling position get their token
    /// sampled, stop-checked and reported — `Done` sessions are skipped.
    /// This is the single entry point the serving stack is built on.
    pub fn step_round(&mut self, sessions: &mut [Session]) -> Result<RoundReport> {
        self.step_round_cached(sessions, None)
    }

    /// [`Self::step_round`] with a prefix-state cache attached: after the
    /// fused pass, every prefill session that advanced to `pos` (and has
    /// [`Session::use_cache`]) snapshots its state under `feed[..pos]` —
    /// the chunk boundary is exactly where the state equals "prefix
    /// consumed".  Identical math either way; the cache only ever adds
    /// state copies, never changes what the round computes.
    pub fn step_round_cached(
        &mut self,
        sessions: &mut [Session],
        mut cache: Option<&mut StateCache>,
    ) -> Result<RoundReport> {
        let chunk = self.cfg.prefill_chunk.max(1);
        let round = crate::util::Stopwatch::start();
        // plan: one segment of token rows per active session
        let mut spans: Vec<SegSpan> = Vec::new();
        let mut flat_tokens: Vec<u32> = Vec::new();
        let mut need: Vec<bool> = Vec::new();
        let mut planned: Vec<usize> = Vec::new();
        let mut report = RoundReport::default();
        for (i, sess) in sessions.iter_mut().enumerate() {
            match sess.phase {
                Phase::Done { .. } => {
                    // e.g. cancelled between rounds: surface it exactly
                    // once (the flag is set only when the report is
                    // actually delivered, so a failed round retries it)
                    if !sess.reported {
                        report.finished.push(i);
                    }
                    continue;
                }
                Phase::Prefill { pos } => {
                    let take = chunk.min(sess.feed.len() - pos);
                    spans.push(SegSpan { sess: planned.len(), start: flat_tokens.len(), len: take });
                    flat_tokens.extend_from_slice(&sess.feed[pos..pos + take]);
                    need.push(pos + take == sess.feed.len());
                    planned.push(i);
                    report.prefill_tokens += take;
                }
                Phase::Decode => {
                    spans.push(SegSpan { sess: planned.len(), start: flat_tokens.len(), len: 1 });
                    flat_tokens.push(sess.last_token);
                    need.push(true);
                    planned.push(i);
                    report.decode_tokens += 1;
                }
            }
        }
        if planned.is_empty() {
            for &i in &report.finished {
                sessions[i].reported = true;
            }
            return Ok(report);
        }

        // the fused pass borrows all states together; lend them out
        let mut states: Vec<RwkvState> = planned
            .iter()
            .map(|&i| std::mem::replace(&mut sessions[i].state, RwkvState::zero(0, 0, 1, 1)))
            .collect();
        let result = if self.xla.is_some() {
            self.step_segments_sequential(&flat_tokens, &spans, &mut states, &need)
        } else {
            self.forward_segments(&flat_tokens, &spans, &mut states, &need)
        };
        for (&i, st) in planned.iter().zip(states) {
            sessions[i].state = st;
        }
        let (mut logits, round_bytes) = result?;
        report.round_weight_bytes = round_bytes;
        // the round succeeded, so this report WILL reach the caller:
        // pre-Done sessions queued during planning are now safely marked
        for &i in &report.finished {
            sessions[i].reported = true;
        }

        // sample + stop-check inside the round
        let mut li = 0usize;
        for (k, sp) in spans.iter().enumerate() {
            let sess = &mut sessions[planned[k]];
            if let Phase::Prefill { pos } = sess.phase {
                let new_pos = pos + sp.len;
                sess.phase = if new_pos == sess.feed.len() {
                    Phase::Decode
                } else {
                    Phase::Prefill { pos: new_pos }
                };
                // prefix-state cache insert point: the session's state now
                // reflects exactly feed[..new_pos], so it snapshots under
                // that prefix (a clone only when the prefix is new)
                if sess.use_cache {
                    if let Some(c) = cache.as_deref_mut() {
                        c.insert(&sess.feed[..new_pos], &sess.state);
                    }
                }
            }
            if need[k] {
                let lg = &mut logits[li];
                li += 1;
                if sess.produced >= sess.max_tokens {
                    // max_tokens == 0: never sample
                    sess.phase = Phase::Done { reason: FinishReason::MaxTokens };
                } else {
                    let tok = sess.sampler.sample(lg);
                    sess.produced += 1;
                    sess.last_token = tok;
                    report.emitted.push(Emission { session: planned[k], token: tok });
                    sess.note_emitted(tok);
                    if sess.stop_tokens.contains(&tok) {
                        sess.phase = Phase::Done { reason: FinishReason::Stop(tok) };
                    } else if let Some(si) = sess.matched_stop_seq() {
                        sess.phase = Phase::Done { reason: FinishReason::StopSeq(si as u32) };
                    } else if sess.produced >= sess.max_tokens {
                        sess.phase = Phase::Done { reason: FinishReason::MaxTokens };
                    }
                }
            }
            if sess.is_done() && !sess.reported {
                sess.reported = true;
                report.finished.push(planned[k]);
            }
        }

        self.metrics.inc("session_rounds", 1);
        // (round_weight_bytes is counted by the serving coordinator, which
        // shares this registry — counting it here too would double it)
        self.metrics.inc("round_prefill_tokens", report.prefill_tokens as u64);
        self.metrics.inc("round_decode_tokens", report.decode_tokens as u64);
        self.metrics.observe("round_secs", round.elapsed_secs());
        // per-phase split of the fused pass (where did this round's time
        // go: recurrence vs weight-streaming matmuls vs predictor vs head)
        self.metrics.observe("round_wkv_secs", self.last_stats.wkv_secs);
        self.metrics.observe("round_matmul_secs", self.last_stats.matmul_secs);
        self.metrics.observe("round_pred_secs", self.last_stats.pred_secs);
        self.metrics.observe("round_head_secs", self.last_stats.head_secs);
        // layerwise block streaming: total stall acquiring blocks, the
        // part spent waiting on in-flight prefetches (the UN-hidden
        // remainder), and how many blocks a background load served.
        // All zero under `Full` loading.
        self.metrics.observe("round_block_load_secs", self.last_stats.block_load_secs);
        self.metrics.observe("round_prefetch_wait_secs", self.last_stats.prefetch_wait_secs);
        self.metrics.inc("blocks_prefetched", self.last_stats.blocks_prefetched as u64);
        Ok(report)
    }

    /// Drive `sess` until it finishes, returning every emitted token —
    /// the shared loop under [`Self::generate`], the CLI and the exp
    /// drivers (the coordinator drives rounds itself to multiplex
    /// sessions).
    pub fn run_session(&mut self, sess: &mut Session) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(sess.max_tokens);
        while !sess.is_done() {
            let report = self.step_round(std::slice::from_mut(sess))?;
            out.extend(report.emitted.iter().map(|e| e.token));
            self.metrics.inc("tokens_generated", report.emitted.len() as u64);
        }
        Ok(out)
    }

    /// Teacher-forced sequence prefill for one state: advance over
    /// `tokens` in fused chunks of `cfg.prefill_chunk` and return the
    /// final position's logits.  Bit-identical to [`Self::forward_hidden`]
    /// per token plus [`Self::head_logits`] on the last.
    pub fn forward_sequence(&mut self, tokens: &[u32], state: &mut RwkvState) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "forward_sequence needs at least one token");
        if self.xla.is_some() {
            for &t in &tokens[..tokens.len() - 1] {
                self.forward_hidden(t, state)?;
            }
            return self.forward_token(tokens[tokens.len() - 1], state);
        }
        let chunk = self.cfg.prefill_chunk.max(1);
        let mut states = [std::mem::replace(state, RwkvState::zero(0, 0, 1, 1))];
        let mut result: Result<Vec<f32>> = Err(anyhow::anyhow!("empty sequence"));
        let mut pos = 0usize;
        while pos < tokens.len() {
            let take = chunk.min(tokens.len() - pos);
            let last = pos + take == tokens.len();
            let spans = [SegSpan { sess: 0, start: 0, len: take }];
            result = self
                .forward_segments(&tokens[pos..pos + take], &spans, &mut states, &[last])
                .map(|(mut lg, _)| if last { lg.remove(0) } else { Vec::new() });
            if result.is_err() {
                break;
            }
            pos += take;
        }
        let [st] = states;
        *state = st;
        result
    }

    /// XLA fallback for [`Self::step_round`]: the session API stays the
    /// single entry point, but segments step token-by-token through the
    /// per-slot path (no fused kernels on that backend).
    fn step_segments_sequential(
        &mut self,
        tokens: &[u32],
        spans: &[SegSpan],
        states: &mut [RwkvState],
        need_logits: &[bool],
    ) -> Result<(Vec<Vec<f32>>, u64)> {
        let mut logits_out: Vec<Vec<f32>> = Vec::new();
        for (k, sp) in spans.iter().enumerate() {
            let st = &mut states[sp.sess];
            for t in 0..sp.len {
                let tok = tokens[sp.start + t];
                if t + 1 == sp.len && need_logits[k] {
                    let hidden = self.forward_hidden(tok, st)?;
                    logits_out.push(self.head_logits(&hidden)?);
                } else {
                    self.forward_hidden(tok, st)?;
                }
            }
        }
        Ok((logits_out, 0))
    }
}
