//! Token sampler: greedy / temperature / nucleus (top-p).

use crate::util::{softmax_inplace, XorShift};

#[derive(Clone, Debug)]
pub struct Sampler {
    pub temperature: f32,
    pub top_p: f32,
    rng: XorShift,
}

impl Sampler {
    pub fn greedy() -> Self {
        Self { temperature: 0.0, top_p: 1.0, rng: XorShift::new(0) }
    }

    pub fn new(temperature: f32, top_p: f32, seed: u64) -> Self {
        Self { temperature, top_p, rng: XorShift::new(seed) }
    }

    /// Sample a token id; `logits` is clobbered.
    pub fn sample(&mut self, logits: &mut [f32]) -> u32 {
        if self.temperature <= 0.0 {
            return crate::util::argmax(logits) as u32;
        }
        let inv_t = 1.0 / self.temperature;
        for l in logits.iter_mut() {
            *l *= inv_t;
        }
        softmax_inplace(logits);
        if self.top_p < 1.0 {
            // nucleus: zero everything outside the smallest set with
            // cumulative mass >= top_p
            let mut order: Vec<usize> = (0..logits.len()).collect();
            order.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            let mut csum = 0.0f32;
            let mut cut = order.len();
            for (rank, &i) in order.iter().enumerate() {
                csum += logits[i];
                if csum >= self.top_p {
                    cut = rank + 1;
                    break;
                }
            }
            for &i in &order[cut..] {
                logits[i] = 0.0;
            }
            let z: f32 = logits.iter().sum();
            if z > 0.0 {
                for l in logits.iter_mut() {
                    *l /= z;
                }
            }
        }
        let r = self.rng.next_f32();
        let mut acc = 0.0f32;
        for (i, &p) in logits.iter().enumerate() {
            acc += p;
            if r < acc {
                return i as u32;
            }
        }
        (logits.len() - 1) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::greedy();
        let mut l = vec![0.1, 2.0, -1.0];
        assert_eq!(s.sample(&mut l), 1);
    }

    #[test]
    fn top_p_excludes_tail() {
        let mut s = Sampler::new(1.0, 0.5, 42);
        // one dominant token: top-p=0.5 keeps only it
        for _ in 0..50 {
            let mut l = vec![10.0f32, 0.0, 0.0, 0.0];
            assert_eq!(s.sample(&mut l), 0);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut s = Sampler::new(1.0, 1.0, 7);
        let mut seen = [false; 3];
        for _ in 0..500 {
            let mut l = vec![1.0f32, 1.0, 1.0];
            seen[s.sample(&mut l) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "uniform sampling should hit all");
    }
}
