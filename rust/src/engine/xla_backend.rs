//! XLA/PJRT backend: dense per-layer compute runs the AOT-lowered HLO
//! components (jax L2 + Pallas L1) instead of the native kernels.
//!
//! One compiled executable per component shape, *reused across layers*:
//! layer weights live in per-layer device buffers uploaded once at load.
//! Per token only x / state tensors move host<->device.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::engine::weights::{LnW, WeightStore};
use crate::engine::{state::RwkvState, ModelInfo};
use crate::runtime::{literal_f32, Component, Runtime};
use crate::tensor::layer_norm;

pub struct XlaRwkv {
    rt: Runtime,
    timemix: Component,
    chanmix: Component,
    head: Component,
    /// Per layer: ordered weight buffers for timemix / chanmix.
    tm_weights: Vec<Vec<xla::PjRtBuffer>>,
    cm_weights: Vec<Vec<xla::PjRtBuffer>>,
    head_buf: xla::PjRtBuffer,
    info: ModelInfo,
}

impl XlaRwkv {
    pub fn load(store: &Arc<WeightStore>, artifacts: &Path, info: ModelInfo) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let m = &store.manifest;
        let tm_names = m.hlo_params("timemix").context("manifest missing hlo.timemix")?;
        let cm_names = m.hlo_params("chanmix").context("manifest missing hlo.chanmix")?;
        let timemix = rt.load_component(
            &m.hlo_path(artifacts, "timemix").context("hlo path")?,
            tm_names.clone(),
        )?;
        let chanmix = rt.load_component(
            &m.hlo_path(artifacts, "chanmix").context("hlo path")?,
            cm_names.clone(),
        )?;
        let head = rt.load_component(
            &m.hlo_path(artifacts, "head").context("hlo path")?,
            vec!["head".into()],
        )?;

        // Upload per-layer weights once, in manifest order.  XLA CPU runs
        // f32; stored f16/i8 decode on upload.  Residency is tracked as
        // the decoded f32 bytes (the honest number for this backend).
        let mut tm_weights = Vec::with_capacity(info.layers);
        let mut cm_weights = Vec::with_capacity(info.layers);
        for layer in 0..info.layers {
            tm_weights.push(upload_layer(&rt, store, layer, &tm_names)?);
            cm_weights.push(upload_layer(&rt, store, layer, &cm_names)?);
        }
        let head_mat = store.mat("head")?; // (V, D) transposed layout
        let head_buf = rt.upload(&head_mat.to_f32_vec(), &[info.vocab, info.dim])?;

        Ok(Self { rt, timemix, chanmix, head, tm_weights, cm_weights, head_buf, info })
    }

    /// One dense decode step through the HLO components.
    pub fn step(
        &mut self,
        x_emb: &[f32],
        ln0: &LnW,
        ln_out: &LnW,
        state: &mut RwkvState,
    ) -> Result<Vec<f32>> {
        let d = self.info.dim;
        let (h, s) = (self.info.heads, self.info.head_size);
        let mut x = vec![0.0f32; d];
        layer_norm(x_emb, &ln0.scale, &ln0.bias, 1e-5, &mut x);
        let mut x_buf = self.rt.upload(&x, &[d])?;
        for layer in 0..self.info.layers {
            // timemix(x, att_x, wkv, *w) -> (x', xa, wkv')
            let att_x = self.rt.upload(&state.att_x[layer], &[d])?;
            let wkv = self.rt.upload(&state.wkv[layer], &[h, s, s])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&x_buf, &att_x, &wkv];
            args.extend(self.tm_weights[layer].iter());
            let outs = self.timemix.run(&args)?;
            state.att_x[layer] = literal_f32(&outs[1])?;
            state.wkv[layer] = literal_f32(&outs[2])?;
            let x_after_tm = literal_f32(&outs[0])?;
            x_buf = self.rt.upload(&x_after_tm, &[d])?;
            // chanmix(x, ffn_x, *w) -> (x', xf)
            let ffn_x = self.rt.upload(&state.ffn_x[layer], &[d])?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&x_buf, &ffn_x];
            args.extend(self.cm_weights[layer].iter());
            let outs = self.chanmix.run(&args)?;
            state.ffn_x[layer] = literal_f32(&outs[1])?;
            let x_after_cm = literal_f32(&outs[0])?;
            x_buf = self.rt.upload(&x_after_cm, &[d])?;
            x = x_after_cm;
        }
        let mut hidden = vec![0.0f32; d];
        layer_norm(&x, &ln_out.scale, &ln_out.bias, 1e-5, &mut hidden);
        Ok(hidden)
    }

    /// Dense head through HLO: logits = head_t @ hidden.
    pub fn head(&mut self, hidden: &[f32]) -> Result<Vec<f32>> {
        let hb = self.rt.upload(hidden, &[self.info.dim])?;
        let outs = self.head.run(&[&hb, &self.head_buf])?;
        literal_f32(&outs[0])
    }
}

/// Upload the ordered weight list of one layer for one component.
fn upload_layer(
    rt: &Runtime,
    store: &WeightStore,
    layer: usize,
    names: &[String],
) -> Result<Vec<xla::PjRtBuffer>> {
    let mut bufs = Vec::with_capacity(names.len());
    for n in names {
        let full = format!("b{layer}.{n}");
        let e = store.rkv.entry(&full)?;
        let dims = e.shape.clone();
        let data: Vec<f32> = if dims.len() == 2 {
            store.rkv.mat(&full)?.to_f32_vec()
        } else {
            store.rkv.vec_f32(&full)?
        };
        store
            .tracker
            .load(crate::engine::weights::group_of(&full), 4 * data.len() as u64);
        bufs.push(rt.upload(&data, &dims)?);
    }
    Ok(bufs)
}
