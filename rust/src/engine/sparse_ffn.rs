//! §3.2 — sparse channel-mix FFN via the predictor ensemble.
//!
//! Per layer we hold the MLP predictor (L1: D->N, L2: N->F) and the 1-bit
//! shadow of W_k (sign bits + per-column scale).  Per token:
//!
//!   P_mlp   = sigmoid(relu(x L1) L2)        >= t_mlp           (Eq. 3)
//!   P_quant = x W^{INT1}                    >= percentile(t_quant) (Eq. 4)
//!   P_ens   = P_mlp OR P_quant                                  (Eq. 5)
//!
//! Only the P_ens-selected rows of wk_t / wv are streamed from the mmap
//! (never materialized as full matrices); the bytes touched are accounted
//! as transient ChanMix residency — that is the §3.2 memory saving.

use anyhow::Result;

use crate::engine::weights::{ProjW, WeightStore};
use crate::metrics::{Group, MemTracker};
use crate::pool::{Par, SharedSliceMut};
use crate::tensor::{matvec_in_out, sigmoid, ShadowView};

/// Which predictor drives row selection (Figure 9's study).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredMode {
    /// max(P_MLP, P_quant) — the paper's default (Eq. 5).
    Ensemble,
    /// MLP only (Eq. 3).
    MlpOnly,
    /// 1-bit shadow only (Eq. 4).
    QuantOnly,
    /// 4-bit shadow only (§B.4's "n-bit" study; 4x the 1-bit memory).
    Quant4Only,
    /// Oracle: the true relu mask (accuracy ceiling; no memory saving
    /// in practice since computing it touches every row).
    GroundTruth,
}

impl PredMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "ensemble" => PredMode::Ensemble,
            "mlp" => PredMode::MlpOnly,
            "quant" => PredMode::QuantOnly,
            "quant4" => PredMode::Quant4Only,
            "gt" => PredMode::GroundTruth,
            _ => anyhow::bail!("unknown predictor mode '{s}' (ensemble|mlp|quant|quant4|gt)"),
        })
    }
}

pub struct SparsePredictor {
    pub layer: usize,
    l1: std::sync::Arc<crate::tensor::Mat>, // (D, N)
    l2: std::sync::Arc<crate::tensor::Mat>, // (N, F)
    sign: Vec<u8>,                          // (ceil(D/8), F) packed
    sign_scale: Vec<f32>,                   // (F,)
    q4: Option<Vec<u8>>,                    // (ceil(D/2), F) nibble-packed
    q4_scale: Vec<f32>,                     // (F,)
    pub t_mlp: f32,
    pub t_quant: f32,
    pub mode: PredMode,
    // telemetry for fig3/fig9
    pub tokens: u64,
    pub kept_sum: f64,
    pub bytes_streamed: u64,
}

pub struct SparseStats {
    pub active: usize,
    pub total: usize,
    pub bytes: u64,
}

impl SparsePredictor {
    pub fn load(store: &WeightStore, layer: usize, t_mlp: f32, t_quant: f32) -> Result<Self> {
        let p = format!("b{layer}.pred");
        let l1 = store.mat(&format!("{p}.l1"))?;
        let l2 = store.mat(&format!("{p}.l2"))?;
        let sign = store.rkv.raw(&format!("{p}.sign"))?.to_vec();
        let sign_scale = store.rkv.vec_f32(&format!("{p}.scale"))?;
        store
            .tracker
            .load(Group::Predictor, sign.len() as u64 + 4 * sign_scale.len() as u64);
        // optional 4-bit shadow: loaded lazily only when the mode asks
        // for it (it is 4x the 1-bit size — fig9's memory/accuracy axis)
        let (q4, q4_scale) = if store.rkv.has(&format!("{p}.q4")) {
            (None, store.rkv.vec_f32(&format!("{p}.q4.scale"))?)
        } else {
            (None, Vec::new())
        };
        Ok(Self {
            layer,
            l1,
            l2,
            sign,
            sign_scale,
            q4,
            q4_scale,
            t_mlp,
            t_quant,
            mode: PredMode::Ensemble,
            tokens: 0,
            kept_sum: 0.0,
            bytes_streamed: 0,
        })
    }

    /// Materialize the 4-bit shadow (Quant4Only mode). Tracked bytes.
    pub fn load_q4(&mut self, store: &WeightStore) -> Result<()> {
        if self.q4.is_some() {
            return Ok(());
        }
        let p = format!("b{}.pred", self.layer);
        anyhow::ensure!(
            store.rkv.has(&format!("{p}.q4")),
            "checkpoint has no 4-bit shadow (re-run make artifacts)"
        );
        let q4 = store.rkv.raw(&format!("{p}.q4"))?.to_vec();
        store.tracker.load(Group::Predictor, q4.len() as u64);
        self.q4 = Some(q4);
        Ok(())
    }

    /// Predict the active-neuron index set for input `xk` (the channel-mix
    /// key input), recording telemetry.  `scratch` buffers are
    /// caller-owned to keep this allocation-free on the hot path.
    pub fn predict(
        &mut self,
        xk: &[f32],
        scratch_n: &mut Vec<f32>,
        scratch_f: &mut Vec<f32>,
        scratch_f2: &mut Vec<f32>,
        out_idx: &mut Vec<u32>,
    ) {
        self.predict_into(xk, scratch_n, scratch_f, scratch_f2, out_idx);
        self.note_external(out_idx.len(), self.l2.cols());
    }

    /// Telemetry-free prediction core (`&self`, fully deterministic per
    /// row): the engine's parallel predictor path runs one call per token
    /// row across the pool with per-lane scratch, then accounts telemetry
    /// once on the round thread via [`SparsePredictor::note_external`].
    pub fn predict_into(
        &self,
        xk: &[f32],
        scratch_n: &mut Vec<f32>,
        scratch_f: &mut Vec<f32>,
        scratch_f2: &mut Vec<f32>,
        out_idx: &mut Vec<u32>,
    ) {
        let n = self.l1.cols();
        let f = self.l2.cols();
        // MLP logits.  The i8-dequant scratch of each matvec reuses the
        // buffer that is cleared and refilled right afterwards, so the
        // predictor stays allocation-free without extra parameters.
        scratch_n.clear();
        scratch_n.resize(n, 0.0);
        matvec_in_out(xk, &self.l1, scratch_n, scratch_f);
        for v in scratch_n.iter_mut() {
            *v = v.max(0.0);
        }
        scratch_f.clear();
        scratch_f.resize(f, 0.0);
        matvec_in_out(scratch_n, &self.l2, scratch_f, scratch_f2);
        // shadow scores: 1-bit by default, 4-bit nibbles in Quant4Only
        scratch_f2.clear();
        scratch_f2.resize(f, 0.0);
        if self.mode == PredMode::Quant4Only {
            let q4 = self.q4.as_ref().expect("load_q4 before Quant4Only");
            ShadowView::nib4(q4, &self.q4_scale, xk.len()).matvec(xk, scratch_f2);
        } else {
            ShadowView::bits(&self.sign, &self.sign_scale, xk.len()).matvec(xk, scratch_f2);
        }
        // percentile threshold over shadow scores (keep top (1-t_quant))
        let keep = ((1.0 - self.t_quant) * f as f32).ceil() as usize;
        let thr = kth_largest(scratch_f2, keep.max(1));
        // union / single-source selection per mode
        out_idx.clear();
        let mlp_logit_thr = logit(self.t_mlp);
        for j in 0..f {
            let keep = match self.mode {
                PredMode::Ensemble => scratch_f[j] >= mlp_logit_thr || scratch_f2[j] >= thr,
                PredMode::MlpOnly => scratch_f[j] >= mlp_logit_thr,
                PredMode::QuantOnly | PredMode::Quant4Only => scratch_f2[j] >= thr,
                // GT is materialized by the engine via `ground_truth`;
                // falling through here behaves like the ensemble.
                PredMode::GroundTruth => scratch_f[j] >= mlp_logit_thr || scratch_f2[j] >= thr,
            };
            if keep {
                out_idx.push(j as u32);
            }
        }
    }

    /// Record telemetry for an externally-chosen index set (GT mode).
    pub fn note_external(&mut self, kept: usize, total: usize) {
        self.tokens += 1;
        self.kept_sum += kept as f64 / total.max(1) as f64;
    }

    /// Ground-truth mask (used by fig9's GT row and tests): indices where
    /// relu(x @ wk)^2 > 0, computed from the dense matrices.
    pub fn ground_truth(store: &WeightStore, layer: usize, xk: &[f32]) -> Result<Vec<u32>> {
        let wk_t = store.row_view(&format!("b{layer}.ffn.wk_t"))?;
        let mut idx = Vec::new();
        for j in 0..wk_t.rows {
            if wk_t.dot(j, xk) > 0.0 {
                idx.push(j as u32);
            }
        }
        Ok(idx)
    }

    /// Mean kept-fraction across all predictions so far (1 - sparsity).
    pub fn mean_kept(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            self.kept_sum / self.tokens as f64
        }
    }
}

/// Streamed sparse FFN evaluation: `out = [sqrelu(wk_t[idx] @ xk)] @ wv[idx]`.
/// Returns stats with the bytes touched, accounted as transient ChanMix
/// residency.  Batched rounds use [`sparse_ffn_apply_batch`] instead,
/// which accounts the cross-request UNION once per round.
pub fn sparse_ffn_apply(
    store: &WeightStore,
    tracker: &MemTracker,
    layer: usize,
    idx: &[u32],
    xk: &[f32],
    out: &mut [f32],
    h_scratch: &mut Vec<f32>,
) -> Result<SparseStats> {
    let wk_t = store.row_view(&format!("b{layer}.ffn.wk_t"))?;
    let wv = store.row_view(&format!("b{layer}.ffn.wv"))?;
    h_scratch.clear();
    h_scratch.resize(idx.len(), 0.0);
    for (k, &j) in idx.iter().enumerate() {
        let a = wk_t.dot(j as usize, xk).max(0.0);
        h_scratch[k] = a * a;
    }
    out.fill(0.0);
    for (k, &j) in idx.iter().enumerate() {
        if h_scratch[k] != 0.0 {
            wv.accum(j as usize, h_scratch[k], out);
        }
    }
    wv.apply_col_scale(out);
    let bytes = idx.len() as u64 * (wk_t.row_bytes() + wv.row_bytes());
    // transient residency: rows live only for this token
    tracker.load(Group::ChanMix, bytes);
    tracker.unload(Group::ChanMix, bytes);
    Ok(SparseStats { active: idx.len(), total: wk_t.rows, bytes })
}

/// Union-fused batched sparse FFN (§3.2 across a scheduling round): one
/// pass over the UNION of the slots' predicted rows computes every slot's
/// output.  `wk_t[j]` / `wv[j]` stream from the mmap once per round and
/// serve all B slots while hot — the bytes-touched win the per-slot
/// union *accounting* already claimed, now realized in compute.
///
/// Bit-identical per slot to [`sparse_ffn_apply`]: each slot's activation
/// `h` is computed only for rows in its OWN predicted set (`slot_idx[s]`,
/// strictly ascending, a subset of `union_idx`), and the W_v accumulation
/// visits rows in the same ascending order with the same zero-skip, so
/// the result matches the per-slot path to the last bit.
///
/// `xks` / `outs` are `(B, D)` flat; `h` is resized to `(B, U)` flat;
/// `cursors` is per-lane × per-slot merge-walk scratch.  Residency
/// accounting for the union bytes is the caller's job (it knows the round
/// context).  Returns the FFN width F (for per-slot stats).
///
/// Parallelism: pass 1 (wk_t dots) shards over union-row ranges — each
/// lane streams a disjoint subset of the union rows, re-seeding its
/// per-slot merge cursors by binary search at its range start; pass 2
/// (W_v accumulation) shards over slots — each lane owns whole `(D,)`
/// output rows and walks union rows in the same ascending order as the
/// serial path.  Both passes are bit-identical for every pool size.
#[allow(clippy::too_many_arguments)]
pub fn sparse_ffn_apply_batch(
    store: &WeightStore,
    layer: usize,
    union_idx: &[u32],
    slot_idx: &[Vec<u32>],
    xks: &[f32],
    outs: &mut [f32],
    h: &mut Vec<f32>,
    cursors: &mut Vec<usize>,
    par: Par<'_>,
) -> Result<usize> {
    let wk_t = store.row_view(&format!("b{layer}.ffn.wk_t"))?;
    let wv = store.row_view(&format!("b{layer}.ffn.wv"))?;
    let d = wk_t.cols;
    let b = slot_idx.len();
    let u = union_idx.len();
    debug_assert_eq!(xks.len(), b * d);
    debug_assert_eq!(outs.len(), b * d);
    h.clear();
    h.resize(b * u, 0.0);
    cursors.clear();
    cursors.resize(par.lanes() * b, 0);
    // pass 1: wk_t rows — stream each union row once, dot it against every
    // slot that predicted it (merge-walk over the sorted per-slot sets)
    {
        let h_view = SharedSliceMut::new(h.as_mut_slice());
        let cur_view = SharedSliceMut::new(cursors.as_mut_slice());
        let wk_ref = &wk_t;
        par.run(u, &|lane, u0, u1| {
            h_view.debug_claim(u0, u1);
            cur_view.debug_claim(lane, lane + 1);
            // SAFETY: each lane writes only union positions [u0, u1) of
            // `h` (every slot) — disjoint ranges, claimed above in debug
            // builds.
            let h = unsafe { h_view.get() };
            // SAFETY: cursor stripe `lane` belongs to this lane alone
            // (claimed above).
            let cur = &mut unsafe { cur_view.get() }[lane * b..(lane + 1) * b];
            // re-seed each slot's merge cursor at this lane's range start
            // (slot sets are sorted subsets of the union)
            for (s, c) in cur.iter_mut().enumerate() {
                *c = slot_idx[s].partition_point(|&x| x < union_idx[u0]);
            }
            for (uk, &j) in union_idx.iter().enumerate().take(u1).skip(u0) {
                for s in 0..b {
                    let idx = &slot_idx[s];
                    let c = cur[s];
                    if c < idx.len() && idx[c] == j {
                        cur[s] = c + 1;
                        let a = wk_ref.dot(j as usize, &xks[s * d..(s + 1) * d]).max(0.0);
                        h[s * u + uk] = a * a;
                    }
                }
            }
        });
    }
    // pass 2: wv rows per SLOT — zero h entries (masked-out slots or
    // sqrelu zeros) are skipped exactly as the per-slot kernel skips them,
    // union rows visited in the same ascending order
    outs.fill(0.0);
    {
        let out_view = SharedSliceMut::new(&mut *outs);
        let h_ref = &h[..];
        let wv_ref = &wv;
        par.run(b, &|_lane, s0, s1| {
            out_view.debug_claim(s0, s1);
            // SAFETY: each lane owns slots [s0, s1) of `outs` — disjoint
            // ranges, claimed above in debug builds.
            let outs = unsafe { out_view.get() };
            for s in s0..s1 {
                let out = &mut outs[s * d..(s + 1) * d];
                for (uk, &j) in union_idx.iter().enumerate() {
                    let hv = h_ref[s * u + uk];
                    if hv != 0.0 {
                        wv_ref.accum(j as usize, hv, out);
                    }
                }
                wv_ref.apply_col_scale(out);
            }
        });
    }
    Ok(wk_t.rows)
}

/// Byte cost of one FFN row pair (wk_t + wv) — union accounting helper.
pub fn ffn_row_pair_bytes(store: &WeightStore, layer: usize) -> Result<u64> {
    let wk_t = store.row_view(&format!("b{layer}.ffn.wk_t"))?;
    let wv = store.row_view(&format!("b{layer}.ffn.wv"))?;
    Ok(wk_t.row_bytes() + wv.row_bytes())
}

/// Dense-equivalent FFN used by the gate path: `r = sigmoid(proj(xr))`.
pub fn gate(wr: &ProjW, xr: &[f32], out: &mut [f32], scratch: &mut Vec<f32>, acc: &mut Vec<f32>) {
    wr.apply(xr, out, scratch, acc);
    for v in out.iter_mut() {
        *v = sigmoid(*v);
    }
}

/// k-th largest value of `xs` (k >= 1), O(n) selection on a scratch copy.
pub fn kth_largest(xs: &[f32], k: usize) -> f32 {
    let mut v = xs.to_vec();
    let k = k.min(v.len()).max(1);
    let idx = v.len() - k;
    v.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    v[idx]
}

fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kth_largest_selects() {
        let xs = [1.0f32, 5.0, 3.0, 2.0, 4.0];
        assert_eq!(kth_largest(&xs, 1), 5.0);
        assert_eq!(kth_largest(&xs, 2), 4.0);
        assert_eq!(kth_largest(&xs, 5), 1.0);
    }

    #[test]
    fn logit_inverts_sigmoid() {
        for p in [0.3f32, 0.5, 0.7, 0.9] {
            assert!((sigmoid(logit(p)) - p).abs() < 1e-5);
        }
    }
}
