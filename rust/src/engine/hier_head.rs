//! §3.3 — hierarchical classification head.
//!
//! A trained cluster head H1 (C, D) picks the probable clusters; exact
//! logits are computed only for tokens in selected clusters by streaming
//! their head rows from the mmap; every other token receives a *pseudo
//! logit* derived from the probability invariant (paper Eq. 9): the known
//! softmax mass implies the total unknown exp-mass, which is spread
//! uniformly (mean value) over unknown tokens.  Pseudo logits keep the
//! distribution smooth — assigning -inf wrecks perplexity (paper §3.3).
//!
//! [`HierHead::logits_batch`] serves a whole scheduling round: H1 streams
//! once for all slots (`tensor::matmat_rows` with a pooled [`Par`], output
//! rows sharded across the pool), and the exact-row scoring — the
//! O(rows·D) bulk of the head at high B — fans out over the pool too:
//! every (slot, token) dot product is an independent output position, so
//! the flat job list shards across lanes exactly like
//! `tensor::matmat_rows_indexed` shards selected index positions.  Sharding never cuts a reduction, so results
//! are bit-identical for every thread count.  Exact head rows touched by
//! the round are accounted as the cross-slot UNION (a row streamed for one
//! slot serves every other slot that selected its cluster).

use std::sync::Arc;

use anyhow::Result;

use crate::engine::weights::WeightStore;
use crate::metrics::{Group, MemTracker};
use crate::pool::{Par, SharedSliceMut};
use crate::tensor::{matmat_rows, matvec_rows, Mat};
use crate::util::softmax_inplace;

pub struct HierHead {
    h1: Arc<Mat>,              // (C, D) row per cluster
    pub assign: Vec<i32>,      // (V,) token -> cluster
    pub clusters: Vec<Vec<u32>>, // cluster -> tokens
    pub p_min: f32,
    pub k_min: usize,
    pub k_max: usize,
    // telemetry
    pub tokens: u64,
    pub rows_loaded_sum: u64,
    pub bytes_streamed: u64,
}

pub struct HeadStats {
    pub clusters_selected: usize,
    pub tokens_loaded: usize,
    pub bytes: u64,
}

impl HierHead {
    pub fn load(store: &WeightStore, p_min: f32, k_min: usize, k_max: usize) -> Result<Self> {
        let h1 = store.mat("hh.h1")?;
        let assign = store.rkv.vec_i32("hh.assign")?;
        store.tracker.load(Group::HierHead, 4 * assign.len() as u64);
        let n_clusters = h1.rows();
        let mut clusters = vec![Vec::new(); n_clusters];
        for (tok, &c) in assign.iter().enumerate() {
            clusters[c as usize].push(tok as u32);
        }
        Ok(Self {
            h1,
            assign,
            clusters,
            p_min,
            k_min: k_min.max(1),
            k_max: k_max.max(1),
            tokens: 0,
            rows_loaded_sum: 0,
            bytes_streamed: 0,
        })
    }

    /// Stored bytes of the cluster head H1 (round weight accounting).
    pub fn h1_nbytes(&self) -> u64 {
        self.h1.nbytes()
    }

    /// Softmax the cluster scores in place and apply the selection rule
    /// (Eq. 7): clusters in descending probability until `p_min` mass is
    /// covered, bounded by `k_min`/`k_max`.  Returns the selected cluster
    /// ids (in selection order) and their cumulative probability.
    fn select_clusters(&self, cl: &mut [f32]) -> (Vec<usize>, f32) {
        softmax_inplace(cl);
        let mut order: Vec<usize> = (0..cl.len()).collect();
        order.sort_by(|&a, &b| cl[b].partial_cmp(&cl[a]).unwrap());
        let mut csum = 0.0f32;
        let mut selected = Vec::with_capacity(self.k_max);
        for &ci in &order {
            selected.push(ci);
            csum += cl[ci];
            if (csum >= self.p_min && selected.len() >= self.k_min)
                || selected.len() >= self.k_max
            {
                break;
            }
        }
        (selected, csum)
    }

    /// Step 3: spread the pseudo logit (Eq. 9) over tokens of unselected
    /// clusters.  From softmax algebra:
    ///   S_known = sum_{known} exp(l);  P_known = csum (cluster head)
    ///   S_unknown = S_known * (1 - P_known) / P_known
    ///   pseudo = ln(S_unknown / N_unknown)
    fn pseudo_fill(
        &self,
        selected: &[usize],
        csum: f32,
        max_known: f32,
        n_loaded: usize,
        out: &mut [f32],
    ) {
        let n_unknown = out.len() - n_loaded;
        if n_unknown == 0 {
            return;
        }
        let mut s_known = 0.0f64;
        for &ci in selected {
            for &tok in &self.clusters[ci] {
                s_known += ((out[tok as usize] - max_known) as f64).exp();
            }
        }
        let p_known = csum.clamp(1e-4, 1.0 - 1e-6) as f64;
        let s_unknown = s_known * (1.0 - p_known) / p_known;
        let pseudo = (s_unknown / n_unknown as f64).ln() as f32 + max_known;
        let mut selected_mask = vec![false; self.clusters.len()];
        for &ci in selected {
            selected_mask[ci] = true;
        }
        for (tok, o) in out.iter_mut().enumerate() {
            if self
                .assign
                .get(tok)
                .map(|&cc| !selected_mask[cc as usize])
                .unwrap_or(true)
            {
                *o = pseudo;
            }
        }
    }

    /// Compute the (approximate) full-vocabulary logits for `hidden`.
    pub fn logits(
        &mut self,
        store: &WeightStore,
        tracker: &MemTracker,
        hidden: &[f32],
        out: &mut [f32],
    ) -> Result<HeadStats> {
        let c = self.h1.rows();
        // Step 1: cluster probabilities (Eq. 7)
        let mut cl = vec![0.0f32; c];
        matvec_rows(&self.h1, hidden, &mut cl);
        let (selected, csum) = self.select_clusters(&mut cl);
        // Step 2: exact logits for tokens of selected clusters (Eq. 8)
        let head = store.row_view("head")?;
        let mut n_loaded = 0usize;
        let mut max_known = f32::NEG_INFINITY;
        for &ci in &selected {
            for &tok in &self.clusters[ci] {
                let lg = head.dot(tok as usize, hidden);
                out[tok as usize] = lg;
                max_known = max_known.max(lg);
                n_loaded += 1;
            }
        }
        self.pseudo_fill(&selected, csum, max_known, n_loaded, out);
        let bytes = n_loaded as u64 * head.row_bytes();
        tracker.load(Group::Head, bytes);
        tracker.unload(Group::Head, bytes);
        self.tokens += 1;
        self.rows_loaded_sum += n_loaded as u64;
        self.bytes_streamed += bytes;
        Ok(HeadStats { clusters_selected: selected.len(), tokens_loaded: n_loaded, bytes })
    }

    /// Batched-round logits: one H1 streaming pass scores every slot's
    /// clusters, then the exact per-(slot, token) head rows are scored
    /// across the pool (bit-identical to [`HierHead::logits`] per slot —
    /// each dot product is one whole reduction, the pool only picks who
    /// computes it).  Exact head-row bytes are accounted as the cross-slot
    /// union — a row streams once per round.  Returns aggregated stats:
    /// `clusters_selected` summed over slots, `tokens_loaded` / `bytes`
    /// for the union.
    pub fn logits_batch(
        &mut self,
        store: &WeightStore,
        tracker: &MemTracker,
        hiddens: &[f32],
        outs: &mut [Vec<f32>],
        par: Par<'_>,
    ) -> Result<HeadStats> {
        let c = self.h1.rows();
        let d = self.h1.cols();
        let b = outs.len();
        debug_assert_eq!(hiddens.len(), b * d);
        let mut cls = vec![0.0f32; b * c];
        matmat_rows(&self.h1, hiddens, &mut cls, par);
        // per-slot cluster selection (cheap serial math), flattened into
        // one (slot, token) job list in per-slot selection order
        let mut selections: Vec<(Vec<usize>, f32)> = Vec::with_capacity(b);
        let mut jobs: Vec<(u32, u32)> = Vec::new();
        let mut slot_job0: Vec<usize> = Vec::with_capacity(b + 1);
        for s in 0..b {
            let (selected, csum) = self.select_clusters(&mut cls[s * c..(s + 1) * c]);
            slot_job0.push(jobs.len());
            for &ci in &selected {
                for &tok in &self.clusters[ci] {
                    jobs.push((s as u32, tok));
                }
            }
            selections.push((selected, csum));
        }
        slot_job0.push(jobs.len());
        // exact-row scoring sharded over flat job positions — the
        // streamed-row analogue of `matmat_rows_indexed`: each lane
        // owns a disjoint contiguous slice of output positions and
        // streams only the head rows those positions name
        let head = store.row_view("head")?;
        let mut scores = vec![0.0f32; jobs.len()];
        {
            let view = SharedSliceMut::new(&mut scores);
            par.run(jobs.len(), &|_lane, k0, k1| {
                view.debug_claim(k0, k1);
                // SAFETY: each lane writes only score positions [k0, k1)
                // — disjoint ranges, claimed above in debug builds.
                let scores = unsafe { view.get() };
                for (k, &(s, tok)) in jobs.iter().enumerate().take(k1).skip(k0) {
                    let s = s as usize;
                    scores[k] = head.dot(tok as usize, &hiddens[s * d..(s + 1) * d]);
                }
            });
        }
        // scatter + pseudo logits per slot, in the exact per-slot order of
        // the serial path
        let mut loaded_union: Vec<u32> = Vec::new();
        let mut clusters_sum = 0usize;
        for (s, out) in outs.iter_mut().enumerate() {
            let (selected, csum) = &selections[s];
            let js = &jobs[slot_job0[s]..slot_job0[s + 1]];
            let sc = &scores[slot_job0[s]..slot_job0[s + 1]];
            let mut max_known = f32::NEG_INFINITY;
            for (&(_, tok), &lg) in js.iter().zip(sc) {
                out[tok as usize] = lg;
                max_known = max_known.max(lg);
                loaded_union.push(tok);
            }
            self.pseudo_fill(selected, *csum, max_known, js.len(), out);
            clusters_sum += selected.len();
        }
        self.tokens += b as u64;
        self.rows_loaded_sum += jobs.len() as u64;
        loaded_union.sort_unstable();
        loaded_union.dedup();
        let bytes = loaded_union.len() as u64 * head.row_bytes();
        tracker.load(Group::Head, bytes);
        tracker.unload(Group::Head, bytes);
        self.bytes_streamed += bytes;
        Ok(HeadStats {
            clusters_selected: clusters_sum,
            tokens_loaded: loaded_union.len(),
            bytes,
        })
    }

    pub fn mean_tokens_loaded(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            self.rows_loaded_sum as f64 / self.tokens as f64
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }
}

#[cfg(test)]
mod tests {
    // The pseudo-logit math is exercised end-to-end in rust/tests/
    // (needs a real checkpoint); unit-test the selection rule shape here.
    #[test]
    fn selection_rule_bounds() {
        // mirrors the loop logic: cumulative probability with k_min/k_max
        let probs = [0.5f32, 0.3, 0.1, 0.05, 0.05];
        let (p_min, k_min, k_max) = (0.8f32, 2usize, 3usize);
        let mut csum = 0.0;
        let mut sel = vec![];
        for (i, &p) in probs.iter().enumerate() {
            sel.push(i);
            csum += p;
            if (csum >= p_min && sel.len() >= k_min) || sel.len() >= k_max {
                break;
            }
        }
        assert_eq!(sel, vec![0, 1]); // 0.8 mass with 2 clusters
    }
}
