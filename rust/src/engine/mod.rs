//! The RWKV-Lite inference engine (L3's core).
//!
//! Composes the paper's techniques around the RWKV v5 recurrence:
//! * SVD / enhanced-SVD projections (§3.1) — transparent via [`weights::ProjW`].
//! * Sparse FFN with the MLP+1-bit predictor ensemble (§3.2).
//! * Embedding LRU cache + hierarchical head (§3.3).
//! * Loading strategies full / layerwise (§5.1) with auditable residency.
//! * Backends: pure-rust kernels (native) or AOT HLO via PJRT (xla).
//!
//! The engine has ONE fused entry point for serving work: a *round*
//! ([`RwkvEngine::step_round`], see [`session`]) advances a mixed set of
//! sessions — prefill sessions by a chunk of up to `prefill_chunk` prompt
//! tokens, decode sessions by one token — through a single pass over the
//! weights.  Internally every session contributes a contiguous run of
//! token rows to one `(N, D)` activation batch (a [`SegSpan`] each), all
//! projections / FFN matrices / the head stream once per round through the
//! tensor::matmat kernels, and the §3.2 sparse FFN unions predicted rows
//! across every row of the round.  The head runs only on rows that must
//! emit a token (decode rows and prompt-final rows).
//!
//! The per-slot path ([`RwkvEngine::forward_token`]) and the one-token
//! batched path ([`RwkvEngine::forward_tokens_batch`]) remain as thin
//! views of the same math; every path is bit-identical per slot.
//!
//! Intra-round parallelism: with a compute pool ([`crate::pool`], the
//! `threads` knob) every heavy section of a round fans out across cores —
//! the weight-streaming matmuls shard over output ranges (each lane
//! streams a disjoint weight slice), the per-slot WKV recurrence and the
//! §3.2 predictor shard over segments/rows with per-lane scratch, and the
//! union-fused sparse FFN shards its two passes over union rows and slots.
//! Rounds are BIT-IDENTICAL for every `threads` value (enforced by
//! `tests/thread_equivalence.rs`): sharding never cuts through a
//! floating-point reduction, it only changes which core computes which
//! output range.  Per-phase timing lands in the engine registry as
//! `round_wkv_secs` / `round_matmul_secs` / `round_pred_secs` /
//! `round_head_secs`.  Within each lane the inner loops run on the
//! runtime-dispatched SIMD kernel table ([`crate::tensor::simd`], the
//! `--simd` knob, resolved once at load) — every backend is
//! bit-identical to scalar, so ISA choice never changes output either.
//!
//! Prefix-state cache: because the recurrent state is O(1) in sequence
//! length, a processed prompt prefix caches as ONE `RwkvState` snapshot
//! regardless of prefix length.  [`state_cache::StateCache`] is a
//! token-trie-keyed LRU of such snapshots with byte-budgeted eviction;
//! [`session::Session::new_with_cache`] forks a request off the longest
//! cached prefix (prefill starts at `pos = matched_len`), and
//! [`RwkvEngine::step_round_cached`] inserts snapshots at prefill chunk
//! boundaries.  Warm-cache decode is BIT-IDENTICAL to cold prefill
//! (`tests/state_cache_equivalence.rs`) — the fork copies the exact f32
//! state the cold path would have computed.
//!
//! Layerwise streaming overlap: under `LoadStrategy::Layerwise` with
//! `cfg.prefetch` (the default) a [`weights::BlockPrefetcher`]
//! double-buffers the block stream — a dedicated I/O worker loads block
//! N+1 while block N computes, and both layer loops acquire blocks
//! through the same `layerwise_block()` swap point.  Bit-identical to
//! synchronous loading (`tests/prefetch_equivalence.rs`); the exposed
//! stall is observable as `round_block_load_secs` /
//! `round_prefetch_wait_secs` (+ the `blocks_prefetched` counter).

pub mod emb_cache;
pub mod hier_head;
pub mod sampler;
pub mod session;
pub mod sparse_ffn;
pub mod state;
pub mod state_cache;
pub mod transformer;
pub mod weights;
pub mod xla_backend;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{Backend, EngineConfig, LoadStrategy};
use crate::metrics::{MemTracker, Registry};
use crate::pool::{Par, SharedSliceMut, ThreadPool};
use crate::tensor::{
    group_norm_heads, layer_norm, lerp_shift, matmat_in_out, matmat_rows, matvec_in_out,
    matvec_rows, sigmoid, silu, simd, sqrelu_inplace, Mat, SimdBackend,
};
use emb_cache::EmbCache;
use hier_head::HierHead;
use sampler::Sampler;
use sparse_ffn::SparsePredictor;
use state::RwkvState;
use weights::{BlockPrefetcher, BlockW, LnW, WeightStore};
use xla_backend::XlaRwkv;

/// Static shape info (from the manifest).
#[derive(Clone, Copy, Debug)]
pub struct ModelInfo {
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_size: usize,
    pub ffn: usize,
    pub vocab: usize,
}

/// Per-token telemetry (drives fig3 / fig7 / fig9).
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub emb_secs: f64,
    pub timemix_secs: f64,
    pub chanmix_secs: f64,
    pub head_secs: f64,
    /// Per-phase split of a fused round (subsets of timemix/chanmix):
    /// the per-slot WKV recurrence; the weight-streaming matmul blocks
    /// (`matmul_secs` also covers the elementwise mix math interleaved
    /// with them — norms, token-shift lerps, activations, FFN stats);
    /// and the per-row sparsity predictor.  Observed per round as
    /// `round_wkv_secs` / `round_matmul_secs` / `round_pred_secs` in the
    /// engine registry (alongside `round_head_secs`).
    pub wkv_secs: f64,
    pub matmul_secs: f64,
    pub pred_secs: f64,
    /// Layerwise loading: total time the round thread spent stalled
    /// acquiring blocks (synchronous loads + prefetch waits).  With
    /// prefetch on this collapses to `prefetch_wait_secs`; with it off it
    /// is the full per-round block streaming cost.
    pub block_load_secs: f64,
    /// Layerwise prefetch: the subset of `block_load_secs` spent waiting
    /// for an in-flight background load to land (the UN-hidden remainder
    /// of the block's streaming latency).
    pub prefetch_wait_secs: f64,
    /// Blocks served from a completed background prefetch this pass.
    pub blocks_prefetched: usize,
    pub ffn_active: usize,
    pub ffn_total: usize,
    pub head_rows: usize,
}

pub struct RwkvEngine {
    pub info: ModelInfo,
    pub cfg: EngineConfig,
    pub store: Arc<WeightStore>,
    pub metrics: Arc<Registry>,
    /// Intra-round compute pool (`None` == single-threaded).  Rounds are
    /// bit-identical for every pool size; the pool only changes which
    /// core computes which output range.
    pool: Option<Arc<ThreadPool>>,
    /// Effective compute-lane count (`pool` lanes, or 1).
    pub threads: usize,
    /// Active SIMD kernel backend ([`crate::tensor::simd`]) — resolved
    /// once at load from `cfg.simd` (forced or auto-detected) and
    /// reported in telemetry.  Every backend is bit-identical to scalar,
    /// so this only changes throughput, never output.
    pub simd: SimdBackend,
    ln0: LnW,
    ln_out: LnW,
    blocks: Vec<Option<BlockW>>,
    emb_mat: Option<Arc<Mat>>, // resident table when cache disabled
    pub emb_cache: Option<EmbCache>,
    head_mat: Option<Arc<Mat>>, // resident dense head when HH disabled
    pub hier: Option<HierHead>,
    pub preds: Vec<Option<SparsePredictor>>,
    /// Layerwise double-buffered block streaming (`cfg.prefetch`, native
    /// backend): block N+1 loads on a background I/O worker while block N
    /// computes.  `None` == synchronous per-layer loads.
    prefetcher: Option<BlockPrefetcher>,
    xla: Option<XlaRwkv>,
    buf: Scratch,      // allocation-free per-slot hot loop
    bbuf: BatchScratch, // allocation-free batched-round hot loop
    pub last_stats: StepStats,
    /// Weight bytes streamed by the most recent batched decode round
    /// (each dense matrix counted once per round regardless of B).
    pub last_round_weight_bytes: u64,
    /// Cumulative per-layer FFN activation telemetry (drives Figure 3):
    /// (active, total) pairs counted on the dense path (true relu mask)
    /// and on the sparse path (predicted rows).
    pub ffn_active_by_layer: Vec<u64>,
    pub ffn_count_by_layer: Vec<u64>,
}

struct Scratch {
    x: Vec<f32>,
    xa: Vec<f32>,
    xf: Vec<f32>,
    t1: Vec<f32>,
    t2: Vec<f32>,
    r: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    g: Vec<f32>,
    att_out: Vec<f32>,
    rank: Vec<f32>,
    acc: Vec<f32>, // i8 matvec dequant accumulator
    pred_n: Vec<f32>,
    pred_f: Vec<f32>,
    pred_f2: Vec<f32>,
    idx: Vec<u32>,
    h_act: Vec<f32>,
    ffn_out: Vec<f32>,
}

impl Scratch {
    fn new(d: usize, f: usize) -> Self {
        Self {
            x: vec![0.0; d],
            xa: vec![0.0; d],
            xf: vec![0.0; d],
            t1: vec![0.0; d],
            t2: vec![0.0; d],
            r: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            g: vec![0.0; d],
            att_out: vec![0.0; d],
            rank: Vec::new(),
            acc: Vec::with_capacity(d),
            pred_n: Vec::new(),
            pred_f: Vec::with_capacity(f),
            pred_f2: Vec::with_capacity(f),
            idx: Vec::with_capacity(f),
            h_act: Vec::with_capacity(f),
            ffn_out: vec![0.0; d],
        }
    }
}

/// Round-persistent scratch for the fused segment rounds: activations
/// live in `(N, D)` row-major flat buffers so the matmat kernels stream
/// each weight row once for the whole round.  Everything here is reused
/// across rounds and layers — after warm-up the per-layer hot loop
/// performs no heap allocation; only per-round planning (span/flag vecs)
/// and the returned logits allocate.
struct BatchScratch {
    x: Vec<f32>,       // (B, D) residual stream
    xa: Vec<f32>,      // (B, D) ln1 output / final hidden
    xf: Vec<f32>,      // (B, D) ln2 output
    t1: Vec<f32>,      // (B, D) shifted key input
    t2: Vec<f32>,      // (B, D) shifted receptance input
    r: Vec<f32>,       // (B, D)
    k: Vec<f32>,       // (B, D)
    v: Vec<f32>,       // (B, D)
    g: Vec<f32>,       // (B, D)
    att_out: Vec<f32>, // (B, D)
    ffn_out: Vec<f32>, // (B, D)
    rank: Vec<f32>,    // (B, rank) low-rank projection intermediate
    /// Per-LANE matmat kernel scratch (f16 row decode / i8 accumulators):
    /// sharded kernels hand entry `i` to lane `i`, so no locks sit on the
    /// hot path.
    accs: Vec<Vec<f32>>,
    h: Vec<f32>, // (B, U) sparse activations or (B, F)/(B, V) dense
    /// Per-LANE predictor scratch (the predictor itself is per-row math
    /// run across the pool).
    pred_lanes: Vec<PredScratch>,
    /// Per-slot predicted row sets, reused every layer (no per-layer
    /// clone/realloc — the vectors keep their capacity across rounds).
    slot_idx: Vec<Vec<u32>>,
    union_idx: Vec<u32>,
    /// Per-lane × per-slot merge cursors for the union-fused sparse FFN.
    cursors: Vec<usize>,
}

/// One lane's sparsity-predictor scratch (§3.2 MLP + shadow buffers).
#[derive(Default)]
struct PredScratch {
    n: Vec<f32>,
    f: Vec<f32>,
    f2: Vec<f32>,
}

impl BatchScratch {
    fn new() -> Self {
        Self {
            x: Vec::new(),
            xa: Vec::new(),
            xf: Vec::new(),
            t1: Vec::new(),
            t2: Vec::new(),
            r: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            g: Vec::new(),
            att_out: Vec::new(),
            ffn_out: Vec::new(),
            rank: Vec::new(),
            accs: Vec::new(),
            h: Vec::new(),
            pred_lanes: Vec::new(),
            slot_idx: Vec::new(),
            union_idx: Vec::new(),
            cursors: Vec::new(),
        }
    }

    /// Size every `(B, D)` buffer for an `n`-slot round (exact lengths —
    /// the matmat kernels infer B from them) and make sure one scratch
    /// lane exists per compute lane.
    fn ensure(&mut self, n: usize, d: usize, lanes: usize) {
        while self.pred_lanes.len() < lanes {
            self.pred_lanes.push(PredScratch::default());
        }
        let len = n * d;
        for buf in [
            &mut self.x,
            &mut self.xa,
            &mut self.xf,
            &mut self.t1,
            &mut self.t2,
            &mut self.r,
            &mut self.k,
            &mut self.v,
            &mut self.g,
            &mut self.att_out,
            &mut self.ffn_out,
        ] {
            buf.resize(len, 0.0);
        }
        while self.slot_idx.len() < n {
            self.slot_idx.push(Vec::new());
        }
    }
}

/// One decode step of the WKV recurrence (shared by the per-slot and the
/// batched paths so the two stay bit-identical by construction).
fn wkv_decode_step(
    heads: usize,
    head_size: usize,
    decay: &[f32],
    first: &[f32],
    r: &[f32],
    k: &[f32],
    v: &[f32],
    wkv: &mut [f32],
    out: &mut [f32],
) {
    let s = head_size;
    out.fill(0.0);
    for hh in 0..heads {
        let base = hh * s * s;
        for i in 0..s {
            let ki = k[hh * s + i];
            let ri = r[hh * s + i];
            let wi = decay[hh * s + i];
            let ui = first[hh * s + i];
            let srow = &mut wkv[base + i * s..base + (i + 1) * s];
            let vrow = &v[hh * s..(hh + 1) * s];
            let orow = &mut out[hh * s..(hh + 1) * s];
            for j in 0..s {
                let a = ki * vrow[j];
                orow[j] += ri * (ui * a + srow[j]);
                srow[j] = wi * srow[j] + a;
            }
        }
    }
}

/// One session's contiguous run of token rows inside a fused round batch.
///
/// Decode sessions contribute a single row (`len == 1`); prefill sessions
/// contribute up to `prefill_chunk` rows processed teacher-forced in
/// sequence order.  `sess` indexes the `states` slice the segment
/// advances; `start` is the segment's first row in the flat `(N, D)`
/// activation buffers.
#[derive(Clone, Copy, Debug)]
pub struct SegSpan {
    pub sess: usize,
    pub start: usize,
    pub len: usize,
}

/// Which per-layer shift state a segment token-shift reads.
#[derive(Clone, Copy)]
enum ShiftCarry {
    /// Time-mix shift (`RwkvState::att_x`).
    Att,
    /// Channel-mix shift (`RwkvState::ffn_x`).
    Ffn,
}

/// Token-shift over segment rows: row `t` of a segment mixes with row
/// `t-1` of the same segment; each segment's first row mixes with that
/// session's carried shift state (read straight from `states`, so the
/// hot loop stays allocation-free).  Bit-identical to the per-token
/// [`lerp_shift`] because each row runs the exact scalar loop.
#[allow(clippy::too_many_arguments)]
fn lerp_shift_seq(
    d: usize,
    spans: &[SegSpan],
    states: &[RwkvState],
    layer: usize,
    carry: ShiftCarry,
    src: &[f32],
    mu: &[f32],
    out: &mut [f32],
) {
    for sp in spans {
        for t in 0..sp.len {
            let row = sp.start + t;
            let prev: &[f32] = if t == 0 {
                match carry {
                    ShiftCarry::Att => &states[sp.sess].att_x[layer],
                    ShiftCarry::Ffn => &states[sp.sess].ffn_x[layer],
                }
            } else {
                &src[(row - 1) * d..row * d]
            };
            lerp_shift(&src[row * d..(row + 1) * d], prev, mu, &mut out[row * d..(row + 1) * d]);
        }
    }
}

impl RwkvEngine {
    /// Open a model by name (e.g. "rwkv-ours-small") under `cfg.artifacts`,
    /// building the intra-round compute pool from `cfg.threads`
    /// (see [`crate::pool::for_threads`]).
    pub fn load(cfg: EngineConfig) -> Result<Self> {
        let pool = crate::pool::for_threads(cfg.threads);
        Self::load_with_pool(cfg, pool)
    }

    /// Open a model sharing an externally constructed compute pool — the
    /// serving stack builds ONE pool and threads the handle through
    /// coordinator/engine construction so every round fans out over the
    /// same workers.  `None` runs rounds single-threaded (the bit-identical
    /// reference path).
    pub fn load_with_pool(cfg: EngineConfig, pool: Option<Arc<ThreadPool>>) -> Result<Self> {
        let threads = pool.as_ref().map_or(1, |p| p.workers() + 1);
        // Resolve the SIMD kernel backend before touching any weights:
        // a forced-but-unavailable backend must fail loudly at load, not
        // mid-decode.  `select` pins the process-wide kernel table.
        let simd_backend = simd::select(cfg.simd.requested())?;
        let manifest_path: PathBuf = cfg
            .artifacts
            .join("models")
            .join(format!("{}.json", cfg.model));
        let store = Arc::new(WeightStore::open(&manifest_path)?);
        let m = store.manifest.clone();
        if !m.is_rwkv() {
            bail!("{} is not an RWKV checkpoint (arch={})", cfg.model, m.arch);
        }
        let info = ModelInfo {
            dim: m.dim,
            layers: m.layers,
            heads: m.heads,
            head_size: m.head_size,
            ffn: m.ffn_dim,
            vocab: m.vocab,
        };
        if cfg.sparse_ffn && !m.has_predictors {
            bail!("{}: sparse_ffn requested but checkpoint has no predictors", cfg.model);
        }
        if cfg.hier_head && !m.has_hier_head {
            bail!("{}: hier_head requested but checkpoint has no hh tensors", cfg.model);
        }

        let ln0 = LnW::load(&store, "ln0")?;
        let ln_out = LnW::load(&store, "ln_out")?;

        // embedding path (§3.3 cache vs resident table)
        let (emb_mat, emb_cache) = if cfg.emb_cache {
            let cap = if cfg.emb_cache_capacity > 0 {
                cfg.emb_cache_capacity
            } else {
                m.emb_cache_capacity
            };
            let row_bytes = store.rkv.entry("emb")?.nbytes / m.vocab as u64;
            (None, Some(EmbCache::new(cap, m.dim, row_bytes)))
        } else {
            (Some(store.mat("emb")?), None)
        };

        // head path (§3.3 hierarchical vs dense)
        let (head_mat, hier) = if cfg.hier_head {
            let p_min = if cfg.hh_p_min > 0.0 { cfg.hh_p_min } else { m.hh_p_min };
            (None, Some(HierHead::load(&store, p_min, m.hh_k_min, m.hh_k_max)?))
        } else {
            (Some(store.mat("head")?), None)
        };

        // sparse predictors (§3.2)
        let mut preds: Vec<Option<SparsePredictor>> = Vec::new();
        for i in 0..m.layers {
            preds.push(if cfg.sparse_ffn {
                Some(SparsePredictor::load(&store, i, m.t_mlp, m.t_quant)?)
            } else {
                None
            });
        }

        // blocks (full strategy preloads; layerwise streams per round)
        let mut blocks: Vec<Option<BlockW>> = (0..m.layers).map(|_| None).collect();
        if cfg.strategy == LoadStrategy::Full && cfg.backend == Backend::Native {
            for (i, b) in blocks.iter_mut().enumerate() {
                *b = Some(BlockW::load(&store, i, !cfg.sparse_ffn)?);
            }
        }
        // layerwise: double-buffer the block stream unless disabled (a
        // 1-layer model would only ever prefetch the block it is about to
        // unload, so it stays synchronous too)
        let prefetcher = (cfg.strategy == LoadStrategy::Layerwise
            && cfg.backend == Backend::Native
            && cfg.prefetch
            && m.layers > 1)
            .then(|| BlockPrefetcher::new(Arc::clone(&store), !cfg.sparse_ffn, m.layers));

        let xla = if cfg.backend == Backend::Xla {
            Some(XlaRwkv::load(&store, &cfg.artifacts, info)?)
        } else {
            None
        };

        let buf = Scratch::new(info.dim, info.ffn);
        let metrics = Arc::new(Registry::new());
        metrics.set("simd_backend_id", simd_backend.as_u8() as u64);
        Ok(Self {
            info,
            cfg,
            store,
            metrics,
            pool,
            threads,
            simd: simd_backend,
            ln0,
            ln_out,
            blocks,
            emb_mat,
            emb_cache,
            head_mat,
            hier,
            preds,
            prefetcher,
            xla,
            buf,
            bbuf: BatchScratch::new(),
            last_stats: StepStats::default(),
            last_round_weight_bytes: 0,
            ffn_active_by_layer: vec![0; info.layers],
            ffn_count_by_layer: vec![0; info.layers],
        })
    }

    /// Re-home the engine's telemetry onto a shared registry (the serving
    /// coordinator passes its own so one scrape covers engine-side series
    /// — `simd_backend_id`, `round_*_secs`, prefetch counters — alongside
    /// the request-lifecycle histograms).  Engine-set gauges are replayed
    /// onto the adopted registry.
    pub fn adopt_metrics(&mut self, shared: Arc<Registry>) {
        shared.set("simd_backend_id", self.simd.as_u8() as u64);
        self.metrics = shared;
    }

    /// Switch the sparsity-predictor mode for every layer (Figure 9).
    pub fn set_pred_mode(&mut self, mode: sparse_ffn::PredMode) -> Result<()> {
        for p in self.preds.iter_mut().flatten() {
            if mode == sparse_ffn::PredMode::Quant4Only {
                p.load_q4(&self.store)?;
            }
            p.mode = mode;
        }
        Ok(())
    }

    pub fn new_state(&self) -> RwkvState {
        RwkvState::zero(self.info.layers, self.info.dim, self.info.heads, self.info.head_size)
    }

    pub fn tracker(&self) -> &MemTracker {
        &self.store.tracker
    }

    // ------------------------------------------------------------------
    // Embedding
    // ------------------------------------------------------------------

    fn embed(&mut self, token: u32, out: &mut [f32]) -> Result<()> {
        if let Some(cache) = &mut self.emb_cache {
            cache.fetch(&self.store, &self.store.tracker, token, out)?;
        } else if let Some(emb) = &self.emb_mat {
            emb.decode_row(token as usize, out);
        } else {
            bail!("no embedding source");
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Per-layer math (native backend, per-slot path)
    // ------------------------------------------------------------------

    fn time_mix(&mut self, b: &BlockW, layer: usize, state: &mut RwkvState) {
        let (h, s) = (self.info.heads, self.info.head_size);
        let d = self.info.dim;
        let buf = &mut self.buf;
        layer_norm(&buf.x, &b.ln1.scale, &b.ln1.bias, 1e-5, &mut buf.xa);
        let prev = &state.att_x[layer];
        lerp_shift(&buf.xa, prev, &b.att.mu_r, &mut buf.t1);
        b.att.wr.apply(&buf.t1, &mut buf.r, &mut buf.rank, &mut buf.acc);
        lerp_shift(&buf.xa, prev, &b.att.mu_k, &mut buf.t1);
        b.att.wk.apply(&buf.t1, &mut buf.k, &mut buf.rank, &mut buf.acc);
        lerp_shift(&buf.xa, prev, &b.att.mu_v, &mut buf.t1);
        b.att.wv.apply(&buf.t1, &mut buf.v, &mut buf.rank, &mut buf.acc);
        lerp_shift(&buf.xa, prev, &b.att.mu_g, &mut buf.t1);
        b.att.wg.apply(&buf.t1, &mut buf.g, &mut buf.rank, &mut buf.acc);
        for v in buf.g.iter_mut() {
            *v = silu(*v);
        }
        // WKV recurrence (decode step of the L1 kernel)
        wkv_decode_step(
            h,
            s,
            &b.att.decay,
            &b.att.first,
            &buf.r,
            &buf.k,
            &buf.v,
            &mut state.wkv[layer],
            &mut buf.att_out,
        );
        group_norm_heads(&mut buf.att_out, h, &b.att.lnx.scale, &b.att.lnx.bias);
        for i in 0..d {
            buf.att_out[i] *= buf.g[i];
        }
        matvec_in_out(&buf.att_out, &b.att.wo, &mut buf.x, &mut buf.acc); // += residual
        state.att_x[layer].copy_from_slice(&buf.xa);
    }

    fn chan_mix(&mut self, b: &BlockW, layer: usize, state: &mut RwkvState) -> Result<()> {
        let d = self.info.dim;
        let buf = &mut self.buf;
        layer_norm(&buf.x, &b.ln2.scale, &b.ln2.bias, 1e-5, &mut buf.xf);
        let prev = &state.ffn_x[layer];
        lerp_shift(&buf.xf, prev, &b.ffn.mu_k, &mut buf.t1); // xk
        lerp_shift(&buf.xf, prev, &b.ffn.mu_r, &mut buf.t2); // xr
        b.ffn.wr.apply(&buf.t2, &mut buf.r, &mut buf.rank, &mut buf.acc);
        for v in buf.r.iter_mut() {
            *v = sigmoid(*v);
        }
        if let Some(pred) = &mut self.preds[layer] {
            // §3.2 sparse path: predict -> stream selected rows
            if pred.mode == sparse_ffn::PredMode::GroundTruth {
                buf.idx = SparsePredictor::ground_truth(&self.store, layer, &buf.t1)?;
                let total = self.info.ffn;
                pred.note_external(buf.idx.len(), total);
            } else {
                pred.predict(
                    &buf.t1,
                    &mut buf.pred_n,
                    &mut buf.pred_f,
                    &mut buf.pred_f2,
                    &mut buf.idx,
                );
            }
            let stats = sparse_ffn::sparse_ffn_apply(
                &self.store,
                &self.store.tracker,
                layer,
                &buf.idx,
                &buf.t1,
                &mut buf.ffn_out,
                &mut buf.h_act,
            )?;
            self.last_stats.ffn_active += stats.active;
            self.last_stats.ffn_total += stats.total;
            self.ffn_active_by_layer[layer] += stats.active as u64;
            self.ffn_count_by_layer[layer] += stats.total as u64;
        } else {
            let wk_t = b.ffn.wk_t.as_ref().context("dense FFN weights not loaded")?;
            let f = wk_t.rows();
            buf.pred_f.clear();
            buf.pred_f.resize(f, 0.0);
            matvec_rows(wk_t, &buf.t1, &mut buf.pred_f);
            sqrelu_inplace(&mut buf.pred_f);
            // true activation sparsity (Figure 3 measures the dense model)
            let nz = buf.pred_f.iter().filter(|&&v| v > 0.0).count();
            self.ffn_active_by_layer[layer] += nz as u64;
            self.ffn_count_by_layer[layer] += f as u64;
            self.last_stats.ffn_active += nz;
            self.last_stats.ffn_total += f;
            buf.ffn_out.fill(0.0);
            let wv = b.ffn.wv.as_ref().context("dense FFN wv not loaded")?;
            matvec_in_out(&buf.pred_f, wv, &mut buf.ffn_out, &mut buf.acc);
        }
        for i in 0..d {
            buf.x[i] += buf.r[i] * buf.ffn_out[i];
        }
        state.ffn_x[layer].copy_from_slice(&buf.xf);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Full-model step (per-slot path)
    // ------------------------------------------------------------------

    /// Acquire block `layer` for a layerwise pass — from the prefetcher's
    /// double buffer when enabled, synchronously otherwise — timing the
    /// round thread's exposed stall into `last_stats.block_load_secs`.
    /// Bit-identical either way: the same stored bytes are decoded.
    fn layerwise_block(&mut self, layer: usize) -> Result<BlockW> {
        let t = crate::util::Stopwatch::start();
        let block = match self.prefetcher.as_mut() {
            Some(pf) => pf.take(layer)?,
            None => BlockW::load(&self.store, layer, !self.cfg.sparse_ffn)?,
        };
        self.last_stats.block_load_secs += t.elapsed_secs();
        Ok(block)
    }

    /// Fold the prefetcher's counters into `last_stats` (once per pass,
    /// after the layer loop — the background task itself is telemetry-
    /// free so no locks sit on the I/O path).
    fn drain_prefetch_stats(&mut self) {
        if let Some(pf) = self.prefetcher.as_mut() {
            let (wait, hits, _sync) = pf.drain_round_stats();
            self.last_stats.prefetch_wait_secs = wait;
            self.last_stats.blocks_prefetched = hits as usize;
        }
    }

    /// Advance one token; returns the final hidden state (post ln_out).
    pub fn forward_hidden(&mut self, token: u32, state: &mut RwkvState) -> Result<Vec<f32>> {
        self.last_stats = StepStats::default();
        let t_emb = crate::util::Stopwatch::start();
        let mut x_emb = vec![0.0f32; self.info.dim];
        self.embed(token, &mut x_emb)?;
        self.last_stats.emb_secs = t_emb.elapsed_secs();

        if self.xla.is_some() {
            let xla = self.xla.as_mut().unwrap();
            return xla.step(&x_emb, &self.ln0, &self.ln_out, state);
        }

        layer_norm(&x_emb, &self.ln0.scale, &self.ln0.bias, 1e-5, &mut self.buf.x);
        let layerwise = self.cfg.strategy == LoadStrategy::Layerwise;
        for layer in 0..self.info.layers {
            let block = if layerwise {
                self.layerwise_block(layer)?
            } else {
                self.blocks[layer].clone().context("block not preloaded")?
            };
            let t_tm = crate::util::Stopwatch::start();
            self.time_mix(&block, layer, state);
            self.last_stats.timemix_secs += t_tm.elapsed_secs();
            let t_cm = crate::util::Stopwatch::start();
            self.chan_mix(&block, layer, state)?;
            self.last_stats.chanmix_secs += t_cm.elapsed_secs();
            if layerwise {
                drop(block);
                self.store.unload_prefix(&format!("b{layer}."));
            }
        }
        self.drain_prefetch_stats();
        let mut hidden = vec![0.0f32; self.info.dim];
        layer_norm(&self.buf.x, &self.ln_out.scale, &self.ln_out.bias, 1e-5, &mut hidden);
        Ok(hidden)
    }

    /// Logits from a hidden state, via the configured head path.
    pub fn head_logits(&mut self, hidden: &[f32]) -> Result<Vec<f32>> {
        let t = crate::util::Stopwatch::start();
        let mut logits = vec![0.0f32; self.info.vocab];
        if let Some(h) = &mut self.hier {
            let stats = h.logits(&self.store, &self.store.tracker, hidden, &mut logits)?;
            self.last_stats.head_rows = stats.tokens_loaded;
        } else if let Some(hm) = &self.head_mat {
            matvec_rows(hm, hidden, &mut logits);
            self.last_stats.head_rows = self.info.vocab;
        } else if let Some(xla) = &mut self.xla {
            logits = xla.head(hidden)?;
            self.last_stats.head_rows = self.info.vocab;
        } else {
            bail!("no head path configured");
        }
        self.last_stats.head_secs = t.elapsed_secs();
        Ok(logits)
    }

    /// One full decode step: token in, logits out.
    pub fn forward_token(&mut self, token: u32, state: &mut RwkvState) -> Result<Vec<f32>> {
        let hidden = self.forward_hidden(token, state)?;
        self.head_logits(&hidden)
    }

    // ------------------------------------------------------------------
    // Fused segment round (weight-streaming path)
    // ------------------------------------------------------------------

    /// Batched decode round: advance each slot one token with ONE pass over
    /// the weights.  A thin view of the fused segment pass (see
    /// [`Self::step_round`]) where every session contributes a single row;
    /// numerically BIT-IDENTICAL to calling [`Self::forward_token`] per
    /// slot.
    ///
    /// Telemetry: `batch_rounds`, `batch_round_weight_bytes` (dense-layer
    /// bytes are constant in B — that is the point), `batch_union_rows` /
    /// `batch_individual_rows`, and the `batch_round_secs` timing series.
    pub fn forward_tokens_batch(
        &mut self,
        tokens: &[u32],
        states: &mut [RwkvState],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(tokens.len() == states.len(), "tokens/states mismatch");
        anyhow::ensure!(self.xla.is_none(), "batched decode is native-backend only");
        let n = tokens.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let round = crate::util::Stopwatch::start();
        let spans: Vec<SegSpan> = (0..n).map(|i| SegSpan { sess: i, start: i, len: 1 }).collect();
        let need: Vec<bool> = vec![true; n];
        let (logits, round_bytes) = self.forward_segments(tokens, &spans, states, &need)?;
        self.metrics.inc("batch_rounds", 1);
        self.metrics.inc("batch_round_weight_bytes", round_bytes);
        self.metrics.inc("batch_slot_tokens", n as u64);
        self.metrics.observe("batch_round_secs", round.elapsed_secs());
        Ok(logits)
    }

    /// The fused round core: advance every segment of token rows through
    /// one pass over the weights.
    ///
    /// Activations for all `N = Σ len` rows live in `(N, D)` flat buffers
    /// (`BatchScratch`) and every projection / FFN matrix / head matrix
    /// is applied through the tensor::matmat multi-vector kernels, so each
    /// weight row streams once per round and serves every row (decode
    /// slots AND prompt chunks) while hot.  The §3.2 sparse FFN is fused
    /// across the whole round: per-row predictor index sets are unioned
    /// and one pass over the union rows computes every row's activations
    /// (each row masked to its own predicted set).  Only the WKV state
    /// recurrence and the element-wise norms/shifts stay per-row — and
    /// within a segment those run in sequence order, so a prefill chunk is
    /// bit-identical to feeding its tokens through [`Self::forward_hidden`]
    /// one at a time.
    ///
    /// The head runs only on the FINAL row of segments flagged in
    /// `need_logits` (decode rows, prompt-completing rows); non-final
    /// prompt positions skip ln_out + head entirely.  Returns the logits
    /// for flagged segments (in span order) and the round's weight bytes
    /// (dense matrices counted once regardless of N).
    pub(crate) fn forward_segments(
        &mut self,
        tokens: &[u32],
        spans: &[SegSpan],
        states: &mut [RwkvState],
        need_logits: &[bool],
    ) -> Result<(Vec<Vec<f32>>, u64)> {
        debug_assert_eq!(spans.len(), need_logits.len());
        let n = tokens.len();
        debug_assert_eq!(n, spans.iter().map(|sp| sp.len).sum::<usize>());
        anyhow::ensure!(self.xla.is_none(), "fused rounds are native-backend only");
        if n == 0 {
            return Ok((Vec::new(), 0));
        }
        let d = self.info.dim;
        self.last_stats = StepStats::default();
        self.bbuf.ensure(n, d, self.threads);
        let mut round_bytes: u64 = 0;

        // embed + ln0 into the (N, D) residual stream
        let t_emb = crate::util::Stopwatch::start();
        let mut xbuf = std::mem::take(&mut self.bbuf.x);
        let mut row = std::mem::take(&mut self.bbuf.t1);
        row.clear();
        row.resize(d, 0.0);
        for (r, &tok) in tokens.iter().enumerate() {
            self.embed(tok, &mut row)?;
            layer_norm(&row, &self.ln0.scale, &self.ln0.bias, 1e-5, &mut xbuf[r * d..(r + 1) * d]);
        }
        row.clear();
        row.resize(n * d, 0.0);
        self.bbuf.t1 = row;
        self.bbuf.x = xbuf;
        self.last_stats.emb_secs = t_emb.elapsed_secs();

        let layerwise = self.cfg.strategy == LoadStrategy::Layerwise;
        for layer in 0..self.info.layers {
            let block = if layerwise {
                self.layerwise_block(layer)?
            } else {
                self.blocks[layer].clone().context("block not preloaded")?
            };
            let t_tm = crate::util::Stopwatch::start();
            self.time_mix_seq(&block, layer, spans, states);
            self.last_stats.timemix_secs += t_tm.elapsed_secs();
            round_bytes += block.att.wr.nbytes()
                + block.att.wk.nbytes()
                + block.att.wv.nbytes()
                + block.att.wg.nbytes()
                + block.att.wo.nbytes();
            let t_cm = crate::util::Stopwatch::start();
            round_bytes += self.chan_mix_seq(&block, layer, spans, states)?;
            self.last_stats.chanmix_secs += t_cm.elapsed_secs();
            if layerwise {
                drop(block);
                self.store.unload_prefix(&format!("b{layer}."));
            }
        }
        self.drain_prefetch_stats();

        // ln_out + head only for rows that must emit: gather the final row
        // of each flagged segment into a compact (Bh, D) hidden buffer
        let flagged: Vec<usize> = spans
            .iter()
            .zip(need_logits)
            .filter(|(_, &f)| f)
            .map(|(sp, _)| sp.start + sp.len - 1)
            .collect();
        let bh = flagged.len();
        let mut logits_out: Vec<Vec<f32>> = Vec::new();
        if bh > 0 {
            {
                let bb = &mut self.bbuf;
                for (j, &row) in flagged.iter().enumerate() {
                    layer_norm(
                        &bb.x[row * d..(row + 1) * d],
                        &self.ln_out.scale,
                        &self.ln_out.bias,
                        1e-5,
                        &mut bb.xa[j * d..(j + 1) * d],
                    );
                }
            }
            let t_head = crate::util::Stopwatch::start();
            let vocab = self.info.vocab;
            logits_out = (0..bh).map(|_| vec![0.0f32; vocab]).collect();
            if let Some(hh) = &mut self.hier {
                let stats = hh.logits_batch(
                    &self.store,
                    &self.store.tracker,
                    &self.bbuf.xa[..bh * d],
                    &mut logits_out,
                    Par::new(self.pool.as_deref()),
                )?;
                self.last_stats.head_rows = stats.tokens_loaded;
                round_bytes += hh.h1_nbytes() + stats.bytes;
            } else if let Some(hm) = &self.head_mat {
                // dense head: stream the vocab matrix once for the round,
                // output rows sharded across the pool
                let mut flat = std::mem::take(&mut self.bbuf.h);
                flat.clear();
                flat.resize(bh * vocab, 0.0);
                let par = Par::new(self.pool.as_deref());
                matmat_rows(hm, &self.bbuf.xa[..bh * d], &mut flat, par);
                for (s, out) in logits_out.iter_mut().enumerate() {
                    out.copy_from_slice(&flat[s * vocab..(s + 1) * vocab]);
                }
                self.bbuf.h = flat;
                self.last_stats.head_rows = vocab;
                round_bytes += hm.nbytes();
            } else {
                bail!("no head path configured");
            }
            self.last_stats.head_secs = t_head.elapsed_secs();
        }

        self.last_round_weight_bytes = round_bytes;
        Ok((logits_out, round_bytes))
    }

    /// Segment time-mix: shared projections go through the sharded matmat
    /// kernels (one weight pass for all rows, output ranges split across
    /// the pool); the WKV recurrence, norms and shifts run per row in
    /// segment order on that session's state — segments are independent,
    /// so they fan out across the pool one-segment-per-lane-chunk.
    fn time_mix_seq(
        &mut self,
        b: &BlockW,
        layer: usize,
        spans: &[SegSpan],
        states: &mut [RwkvState],
    ) {
        let (h, hs) = (self.info.heads, self.info.head_size);
        let d = self.info.dim;
        let n: usize = spans.iter().map(|sp| sp.len).sum();
        let par = Par::new(self.pool.as_deref());
        let t_mm = crate::util::Stopwatch::start();
        {
            let bb = &mut self.bbuf;
            // ln1 over every row FIRST: within-segment shifts read the
            // previous row's xa
            for r in 0..n {
                layer_norm(
                    &bb.x[r * d..(r + 1) * d],
                    &b.ln1.scale,
                    &b.ln1.bias,
                    1e-5,
                    &mut bb.xa[r * d..(r + 1) * d],
                );
            }
            let ca = ShiftCarry::Att;
            lerp_shift_seq(d, spans, states, layer, ca, &bb.xa, &b.att.mu_r, &mut bb.t1);
            b.att.wr.apply_batch(&bb.t1, n, &mut bb.r, &mut bb.rank, &mut bb.accs, par);
            lerp_shift_seq(d, spans, states, layer, ca, &bb.xa, &b.att.mu_k, &mut bb.t1);
            b.att.wk.apply_batch(&bb.t1, n, &mut bb.k, &mut bb.rank, &mut bb.accs, par);
            lerp_shift_seq(d, spans, states, layer, ca, &bb.xa, &b.att.mu_v, &mut bb.t1);
            b.att.wv.apply_batch(&bb.t1, n, &mut bb.v, &mut bb.rank, &mut bb.accs, par);
            lerp_shift_seq(d, spans, states, layer, ca, &bb.xa, &b.att.mu_g, &mut bb.t1);
            b.att.wg.apply_batch(&bb.t1, n, &mut bb.g, &mut bb.rank, &mut bb.accs, par);
        }
        self.last_stats.matmul_secs += t_mm.elapsed_secs();
        // per-slot WKV recurrence across the pool: each lane owns a chunk
        // of whole segments (disjoint rows of g/att_out, disjoint states)
        let t_wkv = crate::util::Stopwatch::start();
        {
            let bb = &mut self.bbuf;
            let g_view = SharedSliceMut::new(&mut bb.g);
            let out_view = SharedSliceMut::new(&mut bb.att_out);
            let st_view = SharedSliceMut::new(states);
            let (rr, kk, vv, xa) = (&bb.r[..], &bb.k[..], &bb.v[..], &bb.xa[..]);
            par.run(spans.len(), &|_lane, sp0, sp1| {
                g_view.debug_claim(sp0, sp1);
                out_view.debug_claim(sp0, sp1);
                st_view.debug_claim(sp0, sp1);
                // SAFETY: a segment's rows and its session state are
                // touched by exactly one lane (spans partition the rows,
                // sessions are unique per span); the span-range claims
                // above assert the partition in debug builds.
                let g = unsafe { g_view.get() };
                // SAFETY: as above — disjoint span ranges per lane.
                let att_out = unsafe { out_view.get() };
                // SAFETY: as above — one session state per span.
                let states = unsafe { st_view.get() };
                for sp in &spans[sp0..sp1] {
                    let st = &mut states[sp.sess];
                    for t in 0..sp.len {
                        let row = sp.start + t;
                        for v in g[row * d..(row + 1) * d].iter_mut() {
                            *v = silu(*v);
                        }
                        wkv_decode_step(
                            h,
                            hs,
                            &b.att.decay,
                            &b.att.first,
                            &rr[row * d..(row + 1) * d],
                            &kk[row * d..(row + 1) * d],
                            &vv[row * d..(row + 1) * d],
                            &mut st.wkv[layer],
                            &mut att_out[row * d..(row + 1) * d],
                        );
                        group_norm_heads(
                            &mut att_out[row * d..(row + 1) * d],
                            h,
                            &b.att.lnx.scale,
                            &b.att.lnx.bias,
                        );
                        for i in 0..d {
                            att_out[row * d + i] *= g[row * d + i];
                        }
                    }
                    // carry the shift state: xa of the segment's LAST row
                    let last = sp.start + sp.len - 1;
                    st.att_x[layer].copy_from_slice(&xa[last * d..(last + 1) * d]);
                }
            });
        }
        self.last_stats.wkv_secs += t_wkv.elapsed_secs();
        // one streaming pass of wo for the whole round (+= residual)
        let t_wo = crate::util::Stopwatch::start();
        let bb = &mut self.bbuf;
        matmat_in_out(&bb.att_out, &b.att.wo, &mut bb.x, &mut bb.accs, par);
        self.last_stats.matmul_secs += t_wo.elapsed_secs();
    }

    /// Segment channel-mix.  Sparse configs predict per row, then compute
    /// on the round-wide UNION of predicted rows in one streaming pass;
    /// dense configs run wk_t/wv through the matmat kernels.  Returns the
    /// channel-mix weight bytes streamed this round.
    fn chan_mix_seq(
        &mut self,
        b: &BlockW,
        layer: usize,
        spans: &[SegSpan],
        states: &mut [RwkvState],
    ) -> Result<u64> {
        let d = self.info.dim;
        let n: usize = spans.iter().map(|sp| sp.len).sum();
        let par = Par::new(self.pool.as_deref());
        let t_mm = crate::util::Stopwatch::start();
        {
            let bb = &mut self.bbuf;
            for r in 0..n {
                layer_norm(
                    &bb.x[r * d..(r + 1) * d],
                    &b.ln2.scale,
                    &b.ln2.bias,
                    1e-5,
                    &mut bb.xf[r * d..(r + 1) * d],
                );
            }
            let cf = ShiftCarry::Ffn;
            lerp_shift_seq(d, spans, states, layer, cf, &bb.xf, &b.ffn.mu_k, &mut bb.t1); // xk
            lerp_shift_seq(d, spans, states, layer, cf, &bb.xf, &b.ffn.mu_r, &mut bb.t2); // xr
            b.ffn.wr.apply_batch(&bb.t2, n, &mut bb.r, &mut bb.rank, &mut bb.accs, par);
            for v in bb.r.iter_mut() {
                *v = sigmoid(*v);
            }
        }
        self.last_stats.matmul_secs += t_mm.elapsed_secs();
        let mut bytes = b.ffn.wr.nbytes();
        if self.cfg.sparse_ffn {
            // predict per row into the round-persistent index sets.  The
            // predictor is independent per token row, so non-oracle modes
            // fan the rows out across the pool with per-lane scratch; the
            // oracle (GroundTruth) mode reads the store and stays serial.
            let t_pred = crate::util::Stopwatch::start();
            let gt = self.preds[layer].as_ref().context("predictor missing")?.mode
                == sparse_ffn::PredMode::GroundTruth;
            if gt {
                for r in 0..n {
                    let bb = &mut self.bbuf;
                    let pred = self.preds[layer].as_mut().context("predictor missing")?;
                    let xk = &bb.t1[r * d..(r + 1) * d];
                    bb.slot_idx[r] = SparsePredictor::ground_truth(&self.store, layer, xk)?;
                    pred.note_external(bb.slot_idx[r].len(), self.info.ffn);
                }
            } else {
                {
                    let pred = self.preds[layer].as_ref().context("predictor missing")?;
                    let bb = &mut self.bbuf;
                    let slot_view = SharedSliceMut::new(&mut bb.slot_idx[..n]);
                    let lane_view = SharedSliceMut::new(&mut bb.pred_lanes);
                    let t1 = &bb.t1[..];
                    par.run(n, &|lane, r0, r1| {
                        slot_view.debug_claim(r0, r1);
                        lane_view.debug_claim(lane, lane + 1);
                        // SAFETY: each row's index set is written by
                        // exactly one lane (disjoint [r0, r1) ranges,
                        // claimed above in debug builds).
                        let slots = unsafe { slot_view.get() };
                        // SAFETY: scratch entry `lane` belongs to this
                        // lane alone (claimed above).
                        let ps = &mut unsafe { lane_view.get() }[lane];
                        for r in r0..r1 {
                            pred.predict_into(
                                &t1[r * d..(r + 1) * d],
                                &mut ps.n,
                                &mut ps.f,
                                &mut ps.f2,
                                &mut slots[r],
                            );
                        }
                    });
                }
                // telemetry on the round thread (the parallel core is
                // telemetry-free so no locks sit on the hot path)
                let pred = self.preds[layer].as_mut().context("predictor missing")?;
                for r in 0..n {
                    pred.note_external(self.bbuf.slot_idx[r].len(), self.info.ffn);
                }
            }
            self.last_stats.pred_secs += t_pred.elapsed_secs();
            let bb = &mut self.bbuf;
            bb.union_idx.clear();
            for r in 0..n {
                let (union, slots) = (&mut bb.union_idx, &bb.slot_idx);
                union.extend_from_slice(&slots[r]);
            }
            bb.union_idx.sort_unstable();
            bb.union_idx.dedup();
            // §3.2 round accounting: the union rows stream from storage
            // once and serve every row in the round
            let row_bytes = sparse_ffn::ffn_row_pair_bytes(&self.store, layer)?;
            let union_bytes = bb.union_idx.len() as u64 * row_bytes;
            self.store.tracker.load(crate::metrics::Group::ChanMix, union_bytes);
            self.store.tracker.unload(crate::metrics::Group::ChanMix, union_bytes);
            self.metrics.inc("batch_union_rows", bb.union_idx.len() as u64);
            self.metrics.inc(
                "batch_individual_rows",
                bb.slot_idx[..n].iter().map(|v| v.len() as u64).sum(),
            );
            bytes += union_bytes;
            // union-fused compute: one pass over union rows for all rows,
            // sharded across the pool (see sparse_ffn_apply_batch)
            let t_sp = crate::util::Stopwatch::start();
            let total = sparse_ffn::sparse_ffn_apply_batch(
                &self.store,
                layer,
                &bb.union_idx,
                &bb.slot_idx[..n],
                &bb.t1,
                &mut bb.ffn_out,
                &mut bb.h,
                &mut bb.cursors,
                par,
            )?;
            self.last_stats.matmul_secs += t_sp.elapsed_secs();
            for r in 0..n {
                let active = bb.slot_idx[r].len();
                self.last_stats.ffn_active += active;
                self.last_stats.ffn_total += total;
                self.ffn_active_by_layer[layer] += active as u64;
                self.ffn_count_by_layer[layer] += total as u64;
            }
        } else {
            let wk_t = b.ffn.wk_t.as_ref().context("dense FFN weights not loaded")?;
            let wv = b.ffn.wv.as_ref().context("dense FFN wv not loaded")?;
            let f = wk_t.rows();
            let bb = &mut self.bbuf;
            bb.h.clear();
            bb.h.resize(n * f, 0.0);
            let t_ff = crate::util::Stopwatch::start();
            matmat_rows(wk_t, &bb.t1, &mut bb.h, par);
            sqrelu_inplace(&mut bb.h);
            for r in 0..n {
                let nz = bb.h[r * f..(r + 1) * f].iter().filter(|&&v| v > 0.0).count();
                self.ffn_active_by_layer[layer] += nz as u64;
                self.ffn_count_by_layer[layer] += f as u64;
                self.last_stats.ffn_active += nz;
                self.last_stats.ffn_total += f;
            }
            let bb = &mut self.bbuf;
            bb.ffn_out.fill(0.0);
            matmat_in_out(&bb.h, wv, &mut bb.ffn_out, &mut bb.accs, par);
            self.last_stats.matmul_secs += t_ff.elapsed_secs();
            bytes += wk_t.nbytes() + wv.nbytes();
        }
        let bb = &mut self.bbuf;
        for sp in spans {
            for t in 0..sp.len {
                let row = sp.start + t;
                for i in 0..d {
                    bb.x[row * d + i] += bb.r[row * d + i] * bb.ffn_out[row * d + i];
                }
            }
            let last = sp.start + sp.len - 1;
            states[sp.sess].ffn_x[layer].copy_from_slice(&bb.xf[last * d..(last + 1) * d]);
        }
        Ok(bytes)
    }

    /// Consume a prompt (teacher-forced), then sample `n` tokens.
    ///
    /// A thin wrapper over the session API: the prompt prefills in fused
    /// chunks of `cfg.prefill_chunk` through [`Self::step_round`] —
    /// bit-identical to the old per-token loop, several times fewer weight
    /// passes.  No implicit stop tokens: exactly `n` tokens come back.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n: usize,
        sampler: &mut Sampler,
        state: &mut RwkvState,
    ) -> Result<Vec<u32>> {
        let mut sess = session::Session::new(self, 0, prompt);
        sess.max_tokens = n;
        sess.sampler = sampler.clone();
        sess.swap_state(state);
        let result = self.run_session(&mut sess);
        // hand the (possibly partially advanced) state back even on error
        sess.swap_state(state);
        *sampler = sess.sampler.clone();
        result
    }

    /// (current, peak) weight-residency bytes.
    pub fn memory_report(&self) -> (u64, u64) {
        (self.store.tracker.current(), self.store.tracker.peak())
    }

    /// Mean FFN sparsity per layer (fraction of *inactive* neurons).
    pub fn sparsity_by_layer(&self) -> Vec<f64> {
        self.preds
            .iter()
            .map(|p| p.as_ref().map(|p| 1.0 - p.mean_kept()).unwrap_or(0.0))
            .collect()
    }
}
