//! The RWKV-Lite inference engine (L3's core).
//!
//! Composes the paper's techniques around the RWKV v5 recurrence:
//! * SVD / enhanced-SVD projections (§3.1) — transparent via [`weights::ProjW`].
//! * Sparse FFN with the MLP+1-bit predictor ensemble (§3.2).
//! * Embedding LRU cache + hierarchical head (§3.3).
//! * Loading strategies full / layerwise (§5.1) with auditable residency.
//! * Backends: pure-rust kernels (native) or AOT HLO via PJRT (xla).

pub mod emb_cache;
pub mod hier_head;
pub mod sampler;
pub mod sparse_ffn;
pub mod state;
pub mod transformer;
pub mod weights;
pub mod xla_backend;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{Backend, EngineConfig, LoadStrategy};
use crate::metrics::{MemTracker, Registry};
use crate::tensor::{
    group_norm_heads, layer_norm, lerp_shift, matvec_in_out, matvec_rows, sigmoid, silu,
    sqrelu_inplace, Mat,
};
use emb_cache::EmbCache;
use hier_head::HierHead;
use sampler::Sampler;
use sparse_ffn::SparsePredictor;
use state::RwkvState;
use weights::{BlockW, LnW, WeightStore};
use xla_backend::XlaRwkv;

/// Static shape info (from the manifest).
#[derive(Clone, Copy, Debug)]
pub struct ModelInfo {
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_size: usize,
    pub ffn: usize,
    pub vocab: usize,
}

/// Per-token telemetry (drives fig3 / fig7 / fig9).
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub emb_secs: f64,
    pub timemix_secs: f64,
    pub chanmix_secs: f64,
    pub head_secs: f64,
    pub ffn_active: usize,
    pub ffn_total: usize,
    pub head_rows: usize,
}

pub struct RwkvEngine {
    pub info: ModelInfo,
    pub cfg: EngineConfig,
    pub store: Arc<WeightStore>,
    pub metrics: Registry,
    ln0: LnW,
    ln_out: LnW,
    blocks: Vec<Option<BlockW>>,
    emb_mat: Option<Arc<Mat>>, // resident table when cache disabled
    pub emb_cache: Option<EmbCache>,
    head_mat: Option<Arc<Mat>>, // resident dense head when HH disabled
    pub hier: Option<HierHead>,
    pub preds: Vec<Option<SparsePredictor>>,
    xla: Option<XlaRwkv>,
    buf: Scratch, // allocation-free hot loop
    pub last_stats: StepStats,
    /// Cumulative per-layer FFN activation telemetry (drives Figure 3):
    /// (active, total) pairs counted on the dense path (true relu mask)
    /// and on the sparse path (predicted rows).
    pub ffn_active_by_layer: Vec<u64>,
    pub ffn_count_by_layer: Vec<u64>,
}

struct Scratch {
    x: Vec<f32>,
    xa: Vec<f32>,
    xf: Vec<f32>,
    t1: Vec<f32>,
    t2: Vec<f32>,
    r: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    g: Vec<f32>,
    att_out: Vec<f32>,
    rank: Vec<f32>,
    pred_n: Vec<f32>,
    pred_f: Vec<f32>,
    pred_f2: Vec<f32>,
    idx: Vec<u32>,
    h_act: Vec<f32>,
    ffn_out: Vec<f32>,
}

impl Scratch {
    fn new(d: usize, f: usize) -> Self {
        Self {
            x: vec![0.0; d],
            xa: vec![0.0; d],
            xf: vec![0.0; d],
            t1: vec![0.0; d],
            t2: vec![0.0; d],
            r: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            g: vec![0.0; d],
            att_out: vec![0.0; d],
            rank: Vec::new(),
            pred_n: Vec::new(),
            pred_f: Vec::with_capacity(f),
            pred_f2: Vec::with_capacity(f),
            idx: Vec::with_capacity(f),
            h_act: Vec::with_capacity(f),
            ffn_out: vec![0.0; d],
        }
    }
}

impl RwkvEngine {
    /// Open a model by name (e.g. "rwkv-ours-small") under `cfg.artifacts`.
    pub fn load(cfg: EngineConfig) -> Result<Self> {
        let manifest_path: PathBuf = cfg
            .artifacts
            .join("models")
            .join(format!("{}.json", cfg.model));
        let store = Arc::new(WeightStore::open(&manifest_path)?);
        let m = store.manifest.clone();
        if !m.is_rwkv() {
            bail!("{} is not an RWKV checkpoint (arch={})", cfg.model, m.arch);
        }
        let info = ModelInfo {
            dim: m.dim,
            layers: m.layers,
            heads: m.heads,
            head_size: m.head_size,
            ffn: m.ffn_dim,
            vocab: m.vocab,
        };
        if cfg.sparse_ffn && !m.has_predictors {
            bail!("{}: sparse_ffn requested but checkpoint has no predictors", cfg.model);
        }
        if cfg.hier_head && !m.has_hier_head {
            bail!("{}: hier_head requested but checkpoint has no hh tensors", cfg.model);
        }

        let ln0 = LnW::load(&store, "ln0")?;
        let ln_out = LnW::load(&store, "ln_out")?;

        // embedding path (§3.3 cache vs resident table)
        let (emb_mat, emb_cache) = if cfg.emb_cache {
            let cap = if cfg.emb_cache_capacity > 0 {
                cfg.emb_cache_capacity
            } else {
                m.emb_cache_capacity
            };
            let row_bytes = store.rkv.entry("emb")?.nbytes / m.vocab as u64;
            (None, Some(EmbCache::new(cap, m.dim, row_bytes)))
        } else {
            (Some(store.mat("emb")?), None)
        };

        // head path (§3.3 hierarchical vs dense)
        let (head_mat, hier) = if cfg.hier_head {
            let p_min = if cfg.hh_p_min > 0.0 { cfg.hh_p_min } else { m.hh_p_min };
            (None, Some(HierHead::load(&store, p_min, m.hh_k_min, m.hh_k_max)?))
        } else {
            (Some(store.mat("head")?), None)
        };

        // sparse predictors (§3.2)
        let mut preds: Vec<Option<SparsePredictor>> = Vec::new();
        for i in 0..m.layers {
            preds.push(if cfg.sparse_ffn {
                Some(SparsePredictor::load(&store, i, m.t_mlp, m.t_quant)?)
            } else {
                None
            });
        }

        // blocks (full strategy preloads; layerwise streams per token)
        let mut blocks: Vec<Option<BlockW>> = (0..m.layers).map(|_| None).collect();
        if cfg.strategy == LoadStrategy::Full && cfg.backend == Backend::Native {
            for (i, b) in blocks.iter_mut().enumerate() {
                *b = Some(BlockW::load(&store, i, !cfg.sparse_ffn)?);
            }
        }

        let xla = if cfg.backend == Backend::Xla {
            Some(XlaRwkv::load(&store, &cfg.artifacts, info)?)
        } else {
            None
        };

        let buf = Scratch::new(info.dim, info.ffn);
        Ok(Self {
            info,
            cfg,
            store,
            metrics: Registry::new(),
            ln0,
            ln_out,
            blocks,
            emb_mat,
            emb_cache,
            head_mat,
            hier,
            preds,
            xla,
            buf,
            last_stats: StepStats::default(),
            ffn_active_by_layer: vec![0; info.layers],
            ffn_count_by_layer: vec![0; info.layers],
        })
    }

    /// Switch the sparsity-predictor mode for every layer (Figure 9).
    pub fn set_pred_mode(&mut self, mode: sparse_ffn::PredMode) -> Result<()> {
        for p in self.preds.iter_mut().flatten() {
            if mode == sparse_ffn::PredMode::Quant4Only {
                p.load_q4(&self.store)?;
            }
            p.mode = mode;
        }
        Ok(())
    }

    pub fn new_state(&self) -> RwkvState {
        RwkvState::zero(self.info.layers, self.info.dim, self.info.heads, self.info.head_size)
    }

    pub fn tracker(&self) -> &MemTracker {
        &self.store.tracker
    }

    // ------------------------------------------------------------------
    // Embedding
    // ------------------------------------------------------------------

    fn embed(&mut self, token: u32, out: &mut [f32]) -> Result<()> {
        if let Some(cache) = &mut self.emb_cache {
            cache.fetch(&self.store, &self.store.tracker, token, out)?;
        } else if let Some(emb) = &self.emb_mat {
            emb.decode_row(token as usize, out);
        } else {
            bail!("no embedding source");
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Per-layer math (native backend)
    // ------------------------------------------------------------------

    fn time_mix(&mut self, b: &BlockW, layer: usize, state: &mut RwkvState) {
        let (h, s) = (self.info.heads, self.info.head_size);
        let d = self.info.dim;
        let buf = &mut self.buf;
        layer_norm(&buf.x, &b.ln1.scale, &b.ln1.bias, 1e-5, &mut buf.xa);
        let prev = &state.att_x[layer];
        lerp_shift(&buf.xa, prev, &b.att.mu_r, &mut buf.t1);
        b.att.wr.apply(&buf.t1, &mut buf.r, &mut buf.rank);
        lerp_shift(&buf.xa, prev, &b.att.mu_k, &mut buf.t1);
        b.att.wk.apply(&buf.t1, &mut buf.k, &mut buf.rank);
        lerp_shift(&buf.xa, prev, &b.att.mu_v, &mut buf.t1);
        b.att.wv.apply(&buf.t1, &mut buf.v, &mut buf.rank);
        lerp_shift(&buf.xa, prev, &b.att.mu_g, &mut buf.t1);
        b.att.wg.apply(&buf.t1, &mut buf.g, &mut buf.rank);
        for v in buf.g.iter_mut() {
            *v = silu(*v);
        }
        // WKV recurrence (decode step of the L1 kernel)
        let wkv = &mut state.wkv[layer];
        buf.att_out.fill(0.0);
        for hh in 0..h {
            let base = hh * s * s;
            for i in 0..s {
                let ki = buf.k[hh * s + i];
                let ri = buf.r[hh * s + i];
                let wi = b.att.decay[hh * s + i];
                let ui = b.att.first[hh * s + i];
                let srow = &mut wkv[base + i * s..base + (i + 1) * s];
                let vrow = &buf.v[hh * s..(hh + 1) * s];
                let orow = &mut buf.att_out[hh * s..(hh + 1) * s];
                for j in 0..s {
                    let a = ki * vrow[j];
                    orow[j] += ri * (ui * a + srow[j]);
                    srow[j] = wi * srow[j] + a;
                }
            }
        }
        group_norm_heads(&mut buf.att_out, h, &b.att.lnx.scale, &b.att.lnx.bias);
        for i in 0..d {
            buf.att_out[i] *= buf.g[i];
        }
        matvec_in_out(&buf.att_out, &b.att.wo, &mut buf.x); // += residual
        state.att_x[layer].copy_from_slice(&buf.xa);
    }

    fn chan_mix(&mut self, b: &BlockW, layer: usize, state: &mut RwkvState) -> Result<()> {
        let d = self.info.dim;
        let buf = &mut self.buf;
        layer_norm(&buf.x, &b.ln2.scale, &b.ln2.bias, 1e-5, &mut buf.xf);
        let prev = &state.ffn_x[layer];
        lerp_shift(&buf.xf, prev, &b.ffn.mu_k, &mut buf.t1); // xk
        lerp_shift(&buf.xf, prev, &b.ffn.mu_r, &mut buf.t2); // xr
        b.ffn.wr.apply(&buf.t2, &mut buf.r, &mut buf.rank);
        for v in buf.r.iter_mut() {
            *v = sigmoid(*v);
        }
        if let Some(pred) = &mut self.preds[layer] {
            // §3.2 sparse path: predict -> stream selected rows
            if pred.mode == sparse_ffn::PredMode::GroundTruth {
                buf.idx = SparsePredictor::ground_truth(&self.store, layer, &buf.t1)?;
                let total = self.info.ffn;
                pred.note_external(buf.idx.len(), total);
            } else {
                pred.predict(
                    &buf.t1,
                    &mut buf.pred_n,
                    &mut buf.pred_f,
                    &mut buf.pred_f2,
                    &mut buf.idx,
                );
            }
            let stats = sparse_ffn::sparse_ffn_apply(
                &self.store,
                &self.store.tracker,
                layer,
                &buf.idx,
                &buf.t1,
                &mut buf.ffn_out,
                &mut buf.h_act,
                true,
            )?;
            self.last_stats.ffn_active += stats.active;
            self.last_stats.ffn_total += stats.total;
            self.ffn_active_by_layer[layer] += stats.active as u64;
            self.ffn_count_by_layer[layer] += stats.total as u64;
        } else {
            let wk_t = b.ffn.wk_t.as_ref().context("dense FFN weights not loaded")?;
            let f = wk_t.rows();
            buf.pred_f.clear();
            buf.pred_f.resize(f, 0.0);
            matvec_rows(wk_t, &buf.t1, &mut buf.pred_f);
            sqrelu_inplace(&mut buf.pred_f);
            // true activation sparsity (Figure 3 measures the dense model)
            let nz = buf.pred_f.iter().filter(|&&v| v > 0.0).count();
            self.ffn_active_by_layer[layer] += nz as u64;
            self.ffn_count_by_layer[layer] += f as u64;
            self.last_stats.ffn_active += nz;
            self.last_stats.ffn_total += f;
            buf.ffn_out.fill(0.0);
            let wv = b.ffn.wv.as_ref().context("dense FFN wv not loaded")?;
            matvec_in_out(&buf.pred_f, wv, &mut buf.ffn_out);
        }
        for i in 0..d {
            buf.x[i] += buf.r[i] * buf.ffn_out[i];
        }
        state.ffn_x[layer].copy_from_slice(&buf.xf);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Full-model step
    // ------------------------------------------------------------------

    /// Advance one token; returns the final hidden state (post ln_out).
    pub fn forward_hidden(&mut self, token: u32, state: &mut RwkvState) -> Result<Vec<f32>> {
        self.last_stats = StepStats::default();
        let t_emb = crate::util::Stopwatch::start();
        let mut x_emb = vec![0.0f32; self.info.dim];
        self.embed(token, &mut x_emb)?;
        self.last_stats.emb_secs = t_emb.elapsed_secs();

        if self.xla.is_some() {
            let xla = self.xla.as_mut().unwrap();
            return xla.step(&x_emb, &self.ln0, &self.ln_out, state);
        }

        layer_norm(&x_emb, &self.ln0.scale, &self.ln0.bias, 1e-5, &mut self.buf.x);
        let layerwise = self.cfg.strategy == LoadStrategy::Layerwise;
        for layer in 0..self.info.layers {
            let block = if layerwise {
                BlockW::load(&self.store, layer, !self.cfg.sparse_ffn)?
            } else {
                self.blocks[layer].clone().context("block not preloaded")?
            };
            let t_tm = crate::util::Stopwatch::start();
            self.time_mix(&block, layer, state);
            self.last_stats.timemix_secs += t_tm.elapsed_secs();
            let t_cm = crate::util::Stopwatch::start();
            self.chan_mix(&block, layer, state)?;
            self.last_stats.chanmix_secs += t_cm.elapsed_secs();
            if layerwise {
                drop(block);
                self.store.unload_prefix(&format!("b{layer}."));
            }
        }
        let mut hidden = vec![0.0f32; self.info.dim];
        layer_norm(&self.buf.x, &self.ln_out.scale, &self.ln_out.bias, 1e-5, &mut hidden);
        Ok(hidden)
    }

    /// Logits from a hidden state, via the configured head path.
    pub fn head_logits(&mut self, hidden: &[f32]) -> Result<Vec<f32>> {
        let t = crate::util::Stopwatch::start();
        let mut logits = vec![0.0f32; self.info.vocab];
        if let Some(h) = &mut self.hier {
            let stats = h.logits(&self.store, &self.store.tracker, hidden, &mut logits)?;
            self.last_stats.head_rows = stats.tokens_loaded;
        } else if let Some(hm) = &self.head_mat {
            matvec_rows(hm, hidden, &mut logits);
            self.last_stats.head_rows = self.info.vocab;
        } else if let Some(xla) = &mut self.xla {
            logits = xla.head(hidden)?;
            self.last_stats.head_rows = self.info.vocab;
        } else {
            bail!("no head path configured");
        }
        self.last_stats.head_secs = t.elapsed_secs();
        Ok(logits)
    }

    /// One full decode step: token in, logits out.
    pub fn forward_token(&mut self, token: u32, state: &mut RwkvState) -> Result<Vec<f32>> {
        let hidden = self.forward_hidden(token, state)?;
        self.head_logits(&hidden)
    }

    /// Batched decode round: advance each slot one token, layer by layer.
    ///
    /// Numerically IDENTICAL to calling [`Self::forward_token`] per slot —
    /// each slot computes with its own predicted row set — but the §3.2
    /// sparse-row *loading* is accounted as the cross-slot UNION once per
    /// layer per round: on a real device the rows stream from flash once
    /// and serve every request in the round (the PowerInfer-style batching
    /// amortization, here for the coordinator's dynamic batches).
    pub fn forward_tokens_batch(
        &mut self,
        tokens: &[u32],
        states: &mut [RwkvState],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(tokens.len() == states.len(), "tokens/states mismatch");
        anyhow::ensure!(self.xla.is_none(), "batched decode is native-backend only");
        let n = tokens.len();
        let d = self.info.dim;
        // per-slot working x
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n);
        for &t in tokens {
            let mut x_emb = vec![0.0f32; d];
            self.embed(t, &mut x_emb)?;
            let mut x = vec![0.0f32; d];
            layer_norm(&x_emb, &self.ln0.scale, &self.ln0.bias, 1e-5, &mut x);
            xs.push(x);
        }
        let layerwise = self.cfg.strategy == LoadStrategy::Layerwise;
        let mut union_scratch: Vec<u32> = Vec::new();
        for layer in 0..self.info.layers {
            let block = if layerwise {
                BlockW::load(&self.store, layer, !self.cfg.sparse_ffn)?
            } else {
                self.blocks[layer].clone().context("block not preloaded")?
            };
            // time-mix per slot (weights shared, state per slot)
            for s in 0..n {
                self.buf.x.copy_from_slice(&xs[s]);
                self.time_mix(&block, layer, &mut states[s]);
                xs[s].copy_from_slice(&self.buf.x);
            }
            // channel-mix: predict per slot first, then account the union
            if self.cfg.sparse_ffn {
                union_scratch.clear();
                let mut per_slot_idx: Vec<Vec<u32>> = Vec::with_capacity(n);
                for s in 0..n {
                    self.buf.x.copy_from_slice(&xs[s]);
                    // replicate chan_mix's xk computation for prediction
                    let buf = &mut self.buf;
                    layer_norm(&buf.x, &block.ln2.scale, &block.ln2.bias, 1e-5, &mut buf.xf);
                    lerp_shift(&buf.xf, &states[s].ffn_x[layer], &block.ffn.mu_k, &mut buf.t1);
                    let pred = self.preds[layer].as_mut().unwrap();
                    if pred.mode == sparse_ffn::PredMode::GroundTruth {
                        buf.idx = SparsePredictor::ground_truth(&self.store, layer, &buf.t1)?;
                        pred.note_external(buf.idx.len(), self.info.ffn);
                    } else {
                        pred.predict(
                            &buf.t1,
                            &mut buf.pred_n,
                            &mut buf.pred_f,
                            &mut buf.pred_f2,
                            &mut buf.idx,
                        );
                    }
                    union_scratch.extend_from_slice(&buf.idx);
                    per_slot_idx.push(buf.idx.clone());
                }
                union_scratch.sort_unstable();
                union_scratch.dedup();
                let row_bytes = sparse_ffn::ffn_row_pair_bytes(&self.store, layer)?;
                let union_bytes = union_scratch.len() as u64 * row_bytes;
                self.store.tracker.load(crate::metrics::Group::ChanMix, union_bytes);
                self.store.tracker.unload(crate::metrics::Group::ChanMix, union_bytes);
                self.metrics.inc("batch_union_rows", union_scratch.len() as u64);
                self.metrics.inc(
                    "batch_individual_rows",
                    per_slot_idx.iter().map(|v| v.len() as u64).sum(),
                );
                // now the actual math, per slot, unaccounted (union covered it)
                for s in 0..n {
                    self.buf.x.copy_from_slice(&xs[s]);
                    self.chan_mix_with_idx(&block, layer, &mut states[s], &per_slot_idx[s])?;
                    xs[s].copy_from_slice(&self.buf.x);
                }
            } else {
                for s in 0..n {
                    self.buf.x.copy_from_slice(&xs[s]);
                    self.chan_mix(&block, layer, &mut states[s])?;
                    xs[s].copy_from_slice(&self.buf.x);
                }
            }
            if layerwise {
                drop(block);
                self.store.unload_prefix(&format!("b{layer}."));
            }
        }
        let mut out = Vec::with_capacity(n);
        for x in &xs {
            let mut hidden = vec![0.0f32; d];
            layer_norm(x, &self.ln_out.scale, &self.ln_out.bias, 1e-5, &mut hidden);
            out.push(self.head_logits(&hidden)?);
        }
        Ok(out)
    }

    /// Channel-mix with a pre-computed active index set (batched path).
    fn chan_mix_with_idx(
        &mut self,
        b: &BlockW,
        layer: usize,
        state: &mut RwkvState,
        idx: &[u32],
    ) -> Result<()> {
        let d = self.info.dim;
        let buf = &mut self.buf;
        layer_norm(&buf.x, &b.ln2.scale, &b.ln2.bias, 1e-5, &mut buf.xf);
        let prev = &state.ffn_x[layer];
        lerp_shift(&buf.xf, prev, &b.ffn.mu_k, &mut buf.t1);
        lerp_shift(&buf.xf, prev, &b.ffn.mu_r, &mut buf.t2);
        b.ffn.wr.apply(&buf.t2, &mut buf.r, &mut buf.rank);
        for v in buf.r.iter_mut() {
            *v = sigmoid(*v);
        }
        let stats = sparse_ffn::sparse_ffn_apply(
            &self.store,
            &self.store.tracker,
            layer,
            idx,
            &buf.t1,
            &mut buf.ffn_out,
            &mut buf.h_act,
            false,
        )?;
        self.last_stats.ffn_active += stats.active;
        self.last_stats.ffn_total += stats.total;
        self.ffn_active_by_layer[layer] += stats.active as u64;
        self.ffn_count_by_layer[layer] += stats.total as u64;
        for i in 0..d {
            buf.x[i] += buf.r[i] * buf.ffn_out[i];
        }
        state.ffn_x[layer].copy_from_slice(&buf.xf);
        Ok(())
    }

    /// Consume a prompt (teacher-forced), then sample `n` tokens.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n: usize,
        sampler: &mut Sampler,
        state: &mut RwkvState,
    ) -> Result<Vec<u32>> {
        let mut last = crate::text::BOS;
        for &t in prompt {
            self.forward_hidden(last, state)?;
            last = t;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut logits = self.forward_token(last, state)?;
            let tok = sampler.sample(&mut logits);
            out.push(tok);
            last = tok;
            self.metrics.inc("tokens_generated", 1);
        }
        Ok(out)
    }

    /// (current, peak) weight-residency bytes.
    pub fn memory_report(&self) -> (u64, u64) {
        (self.store.tracker.current(), self.store.tracker.peak())
    }

    /// Mean FFN sparsity per layer (fraction of *inactive* neurons).
    pub fn sparsity_by_layer(&self) -> Vec<f64> {
        self.preds
            .iter()
            .map(|p| p.as_ref().map(|p| 1.0 - p.mean_kept()).unwrap_or(0.0))
            .collect()
    }
}
