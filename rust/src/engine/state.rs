//! RWKV recurrent state: O(1) memory across timesteps (no KV cache —
//! the architectural advantage Figure 5's comparison leans on).

#[derive(Clone, Debug)]
pub struct RwkvState {
    pub dim: usize,
    pub heads: usize,
    pub head_size: usize,
    /// Per layer: previous ln1-ed x (token shift input), (D,).
    pub att_x: Vec<Vec<f32>>,
    /// Per layer: WKV state, (H*S*S,) laid out [h][i][j].
    pub wkv: Vec<Vec<f32>>,
    /// Per layer: previous ln2-ed x, (D,).
    pub ffn_x: Vec<Vec<f32>>,
}

impl RwkvState {
    /// Bytes per state element — the payload is f32 everywhere.  The ONE
    /// place element width is defined: [`RwkvState::nbytes`] and the
    /// `io::statefile` serializer both derive from it, so a future
    /// precision change cannot desynchronize byte accounting from the
    /// on-disk format.
    pub const ELEM_BYTES: usize = std::mem::size_of::<f32>();

    pub fn zero(layers: usize, dim: usize, heads: usize, head_size: usize) -> Self {
        Self {
            dim,
            heads,
            head_size,
            att_x: vec![vec![0.0; dim]; layers],
            wkv: vec![vec![0.0; heads * head_size * head_size]; layers],
            ffn_x: vec![vec![0.0; dim]; layers],
        }
    }

    pub fn layers(&self) -> usize {
        self.att_x.len()
    }

    /// Bytes of state memory (for the O(1)-state accounting in fig5/fig6,
    /// and the prefix-state cache's byte budget).
    pub fn nbytes(&self) -> u64 {
        let per_layer = self.dim * 2 + self.heads * self.head_size * self.head_size;
        (Self::ELEM_BYTES * per_layer * self.layers()) as u64
    }

    /// Same model shape (dims AND layer count) — the single predicate
    /// behind every "can this state stand in for that one" check: the
    /// prefix-state cache's fork guard, stale-snapshot replacement,
    /// statefile load filtering, and the equality helpers below.
    pub fn same_shape(&self, other: &Self) -> bool {
        self.dim == other.dim
            && self.heads == other.heads
            && self.head_size == other.head_size
            && self.layers() == other.layers()
    }

    /// Same shape AND bit-identical payloads (exact f32 bit equality) —
    /// the contract the prefix-state cache equivalence tests assert.
    pub fn bitwise_eq(&self, other: &Self) -> bool {
        fn bits_eq(a: &[Vec<f32>], b: &[Vec<f32>]) -> bool {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| {
                    x.len() == y.len()
                        && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                })
        }
        self.same_shape(other)
            && bits_eq(&self.att_x, &other.att_x)
            && bits_eq(&self.wkv, &other.wkv)
            && bits_eq(&self.ffn_x, &other.ffn_x)
    }

    /// Same shape and every element within absolute tolerance `tol` (for
    /// tests that cross a lossy boundary and cannot expect bit equality).
    pub fn approx_eq(&self, other: &Self, tol: f32) -> bool {
        fn close(a: &[Vec<f32>], b: &[Vec<f32>], tol: f32) -> bool {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| {
                    x.len() == y.len()
                        && x.iter().zip(y).all(|(p, q)| (p - q).abs() <= tol)
                })
        }
        self.same_shape(other)
            && close(&self.att_x, &other.att_x, tol)
            && close(&self.wkv, &other.wkv, tol)
            && close(&self.ffn_x, &other.ffn_x, tol)
    }

    pub fn reset(&mut self) {
        for v in self
            .att_x
            .iter_mut()
            .chain(self.wkv.iter_mut())
            .chain(self.ffn_x.iter_mut())
        {
            v.fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_state_shapes() {
        let s = RwkvState::zero(4, 128, 8, 16);
        assert_eq!(s.layers(), 4);
        assert_eq!(s.att_x[0].len(), 128);
        assert_eq!(s.wkv[0].len(), 8 * 16 * 16);
        assert_eq!(s.nbytes(), 4 * 4 * (256 + 2048));
    }

    #[test]
    fn nbytes_derives_from_elem_width() {
        let s = RwkvState::zero(3, 8, 2, 4);
        let per_layer = 8 * 2 + 2 * 4 * 4;
        assert_eq!(s.nbytes(), (RwkvState::ELEM_BYTES * per_layer * 3) as u64);
    }

    #[test]
    fn bitwise_and_approx_eq() {
        let mut a = RwkvState::zero(2, 8, 2, 4);
        let mut b = a.clone();
        assert!(a.bitwise_eq(&b) && a.approx_eq(&b, 0.0));
        b.wkv[1][3] = 1e-6;
        assert!(!a.bitwise_eq(&b));
        assert!(a.approx_eq(&b, 1e-5));
        assert!(!a.approx_eq(&b, 1e-7));
        // shape mismatch is never equal
        a.dim = 9;
        assert!(!a.bitwise_eq(&b) && !a.approx_eq(&b, 1.0));
    }

    #[test]
    fn reset_clears() {
        let mut s = RwkvState::zero(2, 8, 2, 4);
        s.wkv[1][5] = 3.0;
        s.reset();
        assert_eq!(s.wkv[1][5], 0.0);
    }
}
