//! RWKV recurrent state: O(1) memory across timesteps (no KV cache —
//! the architectural advantage Figure 5's comparison leans on).

#[derive(Clone, Debug)]
pub struct RwkvState {
    pub dim: usize,
    pub heads: usize,
    pub head_size: usize,
    /// Per layer: previous ln1-ed x (token shift input), (D,).
    pub att_x: Vec<Vec<f32>>,
    /// Per layer: WKV state, (H*S*S,) laid out [h][i][j].
    pub wkv: Vec<Vec<f32>>,
    /// Per layer: previous ln2-ed x, (D,).
    pub ffn_x: Vec<Vec<f32>>,
}

impl RwkvState {
    pub fn zero(layers: usize, dim: usize, heads: usize, head_size: usize) -> Self {
        Self {
            dim,
            heads,
            head_size,
            att_x: vec![vec![0.0; dim]; layers],
            wkv: vec![vec![0.0; heads * head_size * head_size]; layers],
            ffn_x: vec![vec![0.0; dim]; layers],
        }
    }

    pub fn layers(&self) -> usize {
        self.att_x.len()
    }

    /// Bytes of state memory (for the O(1)-state accounting in fig5/fig6).
    pub fn nbytes(&self) -> u64 {
        let per_layer = self.dim * 2 + self.heads * self.head_size * self.head_size;
        (4 * per_layer * self.layers()) as u64
    }

    pub fn reset(&mut self) {
        for v in self
            .att_x
            .iter_mut()
            .chain(self.wkv.iter_mut())
            .chain(self.ffn_x.iter_mut())
        {
            v.fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_state_shapes() {
        let s = RwkvState::zero(4, 128, 8, 16);
        assert_eq!(s.layers(), 4);
        assert_eq!(s.att_x[0].len(), 128);
        assert_eq!(s.wkv[0].len(), 8 * 16 * 16);
        assert_eq!(s.nbytes(), 4 * 4 * (256 + 2048));
    }

    #[test]
    fn reset_clears() {
        let mut s = RwkvState::zero(2, 8, 2, 4);
        s.wkv[1][5] = 3.0;
        s.reset();
        assert_eq!(s.wkv[1][5], 0.0);
    }
}
