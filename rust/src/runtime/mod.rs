//! PJRT runtime (S20): load AOT-lowered HLO text, compile once, execute on
//! the request path with pre-uploaded weight buffers.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* -> `HloModuleProto::
//! from_text_file` -> `XlaComputation::from_proto` -> `client.compile`.
//! Text is the interchange format because jax >= 0.5 serialized protos use
//! 64-bit instruction ids that xla_extension 0.5.1 rejects.
//!
//! Weights upload to device buffers ONCE (`execute_b` takes buffers); per
//! token only the activations/state cross the host-device boundary.

use std::path::Path;

use anyhow::{bail, Context, Result};

pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    /// Load + compile one HLO component.
    pub fn load_component(&self, hlo_path: &Path, param_names: Vec<String>) -> Result<Component> {
        if !hlo_path.exists() {
            bail!("HLO artifact missing: {} (run `make artifacts`)", hlo_path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", hlo_path.display()))?;
        Ok(Component { exe, param_names })
    }

    /// Upload an f32 buffer to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }
}

/// A compiled HLO component with its ordered parameter names
/// (manifest `hlo.<component>.params`).
pub struct Component {
    pub exe: xla::PjRtLoadedExecutable,
    pub param_names: Vec<String>,
}

impl Component {
    /// Execute on pre-built buffers; returns the flattened output tuple.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))
    }
}

/// Read an f32 literal into a Vec.
pub fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal_f32: {e:?}"))
}
