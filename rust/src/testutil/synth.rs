//! Synthetic RWKV checkpoints — deterministic random models written in the
//! real `.rkv` + manifest format so engine paths (dense, sparse-FFN,
//! hierarchical head, batched decode) are exercised by `cargo test` alone,
//! without `make artifacts`.  Weights are random but well-scaled; these
//! models generate noise, not language — the tests assert *consistency*
//! between execution paths, never quality.

use std::path::Path;

use anyhow::Result;

use crate::io::{write_rkv, RkvTensor};
use crate::json::{self, Value};
use crate::util::XorShift;

/// Shape + feature knobs for a synthetic model.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub layers: usize,
    pub heads: usize,
    pub head_size: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub clusters: usize,
    /// Store matrices as f16 (else f32).
    pub f16: bool,
    /// Group-quantize the large dense matrices to Q4/Q4_1 (RWKVQuant-style
    /// hybrid recipe: dense projections, `wo.w`, `ffn.wk_t` and `head` go
    /// Q4, `ffn.wv` goes Q4_1; embeddings, low-rank factors, predictors
    /// and all vectors stay in `f16`/`f32`).  Composes with `f16`, which
    /// then governs only the non-quantized tensors.
    pub q4: bool,
    /// Use low-rank + enhanced-SVD time-mix projections (else dense).
    pub lowrank: bool,
    pub predictors: bool,
    pub hier_head: bool,
    pub seed: u64,
}

impl SynthSpec {
    /// A tiny model with every technique available (~fast to generate).
    pub fn tiny() -> Self {
        Self {
            layers: 2,
            heads: 2,
            head_size: 8,
            ffn: 40,
            vocab: 96,
            clusters: 6,
            f16: false,
            q4: false,
            lowrank: false,
            predictors: true,
            hier_head: true,
            seed: 0x5EED,
        }
    }

    pub fn dim(&self) -> usize {
        self.heads * self.head_size
    }
}

/// Storage encoding of a synthetic matrix.  The RNG draw order is
/// identical for every encoding (the same `rows * cols` normals are
/// drawn first, then encoded), so flipping `q4` on a spec changes the
/// representation of selected tensors, never the underlying values.
#[derive(Clone, Copy)]
enum Enc {
    F32,
    F16,
    Q4,
    Q41,
}

fn mat(
    rng: &mut XorShift,
    name: &str,
    rows: usize,
    cols: usize,
    gain: f32,
    enc: Enc,
) -> Vec<RkvTensor> {
    let sc = gain / (rows as f32).sqrt();
    let v: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * sc).collect();
    match enc {
        Enc::F32 => vec![RkvTensor::f32(name, vec![rows, cols], &v)],
        Enc::F16 => vec![RkvTensor::f16_from_f32(name, vec![rows, cols], &v)],
        Enc::Q4 => RkvTensor::q4_from_f32(name, rows, cols, &v),
        Enc::Q41 => RkvTensor::q4_1_from_f32(name, rows, cols, &v),
    }
}

fn vecf<F: FnMut(&mut XorShift) -> f32>(
    rng: &mut XorShift,
    name: &str,
    n: usize,
    mut f: F,
) -> RkvTensor {
    let v: Vec<f32> = (0..n).map(|_| f(rng)).collect();
    RkvTensor::f32(name, vec![n], &v)
}

fn ln_pair(rng: &mut XorShift, ts: &mut Vec<RkvTensor>, prefix: &str, n: usize) {
    ts.push(vecf(rng, &format!("{prefix}.scale"), n, |r| 1.0 + 0.05 * r.normal()));
    ts.push(vecf(rng, &format!("{prefix}.bias"), n, |r| 0.02 * r.normal()));
}

/// Emit a projection under `prefix`: dense (`.w`), low-rank (`.l`/`.r`) or
/// enhanced (`.l`/`.r`/`.d`) per the flags — covers every `ProjW` variant.
/// Only the dense `.w` takes the quantized encoding; low-rank factors are
/// small and outlier-dense, so the hybrid recipe keeps them in float.
fn proj(
    rng: &mut XorShift,
    ts: &mut Vec<RkvTensor>,
    prefix: &str,
    d: usize,
    form: ProjForm,
    fenc: Enc,
    wenc: Enc,
) {
    let rank = (d / 4).max(2);
    match form {
        ProjForm::Dense => ts.extend(mat(rng, &format!("{prefix}.w"), d, d, 0.8, wenc)),
        ProjForm::LowRank => {
            ts.extend(mat(rng, &format!("{prefix}.l"), d, rank, 0.8, fenc));
            ts.extend(mat(rng, &format!("{prefix}.r"), rank, d, 0.8, fenc));
        }
        ProjForm::Enhanced => {
            ts.extend(mat(rng, &format!("{prefix}.l"), d, rank, 0.8, fenc));
            ts.extend(mat(rng, &format!("{prefix}.r"), rank, d, 0.8, fenc));
            ts.push(vecf(rng, &format!("{prefix}.d"), d, |r| 0.5 + 0.1 * r.normal()));
        }
    }
}

#[derive(Clone, Copy)]
enum ProjForm {
    Dense,
    LowRank,
    Enhanced,
}

/// Write `<artifacts>/models/<name>.json` + `.rkv` for a synthetic model.
pub fn write_synth_rwkv(artifacts: &Path, name: &str, spec: &SynthSpec) -> Result<()> {
    let d = spec.dim();
    let (f, v, c) = (spec.ffn, spec.vocab, spec.clusters.max(1));
    // fenc: tensors the hybrid recipe keeps in float; wenc: the large
    // dense matrices that take the quantized encoding when `q4` is set
    let fenc = if spec.f16 { Enc::F16 } else { Enc::F32 };
    let wenc = if spec.q4 { Enc::Q4 } else { fenc };
    let mut rng = XorShift::new(spec.seed);
    let mut ts: Vec<RkvTensor> = Vec::new();

    ln_pair(&mut rng, &mut ts, "ln0", d);
    ln_pair(&mut rng, &mut ts, "ln_out", d);
    // embeddings are row-streamed through `emb_row` (f16/f32/i8 only) and
    // are outlier-heavy — they always stay in float
    ts.extend(mat(&mut rng, "emb", v, d, 3.0, fenc));
    ts.extend(mat(&mut rng, "head", v, d, 1.0, wenc));
    if spec.hier_head {
        ts.extend(mat(&mut rng, "hh.h1", c, d, 1.0, fenc));
        let assign: Vec<i32> = (0..v as i32).map(|t| t % c as i32).collect();
        ts.push(RkvTensor::i32("hh.assign", vec![v], &assign));
    }
    for i in 0..spec.layers {
        let p = format!("b{i}");
        ln_pair(&mut rng, &mut ts, &format!("{p}.ln1"), d);
        ln_pair(&mut rng, &mut ts, &format!("{p}.ln2"), d);
        ln_pair(&mut rng, &mut ts, &format!("{p}.att.lnx"), d);
        for mu in ["mu_r", "mu_k", "mu_v", "mu_g"] {
            ts.push(vecf(&mut rng, &format!("{p}.att.{mu}"), d, |r| r.next_f32()));
        }
        ts.push(vecf(&mut rng, &format!("{p}.att.decay"), d, |r| {
            0.55 + 0.4 * r.next_f32()
        }));
        ts.push(vecf(&mut rng, &format!("{p}.att.first"), d, |r| 0.05 * r.normal()));
        let (fr, fk, fv2, fg) = if spec.lowrank {
            // cover every ProjW variant across the four projections
            (ProjForm::LowRank, ProjForm::LowRank, ProjForm::LowRank, ProjForm::Enhanced)
        } else {
            (ProjForm::Dense, ProjForm::Dense, ProjForm::Dense, ProjForm::Dense)
        };
        proj(&mut rng, &mut ts, &format!("{p}.att.wr"), d, fr, fenc, wenc);
        proj(&mut rng, &mut ts, &format!("{p}.att.wk"), d, fk, fenc, wenc);
        proj(&mut rng, &mut ts, &format!("{p}.att.wv"), d, fv2, fenc, wenc);
        proj(&mut rng, &mut ts, &format!("{p}.att.wg"), d, fg, fenc, wenc);
        ts.extend(mat(&mut rng, &format!("{p}.att.wo.w"), d, d, 0.6, wenc));
        for mu in ["mu_k", "mu_r"] {
            ts.push(vecf(&mut rng, &format!("{p}.ffn.{mu}"), d, |r| r.next_f32()));
        }
        proj(
            &mut rng,
            &mut ts,
            &format!("{p}.ffn.wr"),
            d,
            if spec.lowrank { ProjForm::LowRank } else { ProjForm::Dense },
            fenc,
            wenc,
        );
        ts.extend(mat(&mut rng, &format!("{p}.ffn.wk_t"), f, d, 0.8, wenc));
        // wv accumulates (in,out)-style; the offset-carrying Q4_1 variant
        // covers that kernel family end to end
        ts.extend(mat(
            &mut rng,
            &format!("{p}.ffn.wv"),
            f,
            d,
            0.8,
            if spec.q4 { Enc::Q41 } else { fenc },
        ));
        if spec.predictors {
            let n = (d / 2).max(4);
            ts.extend(mat(&mut rng, &format!("{p}.pred.l1"), d, n, 1.0, fenc));
            ts.extend(mat(&mut rng, &format!("{p}.pred.l2"), n, f, 1.0, fenc));
            let packed: Vec<u8> = (0..d.div_ceil(8) * f)
                .map(|_| (rng.next_u64() & 0xff) as u8)
                .collect();
            ts.push(RkvTensor::u8(
                &format!("{p}.pred.sign"),
                vec![d.div_ceil(8), f],
                packed,
            ));
            ts.push(vecf(&mut rng, &format!("{p}.pred.scale"), f, |r| {
                0.05 + 0.1 * r.next_f32()
            }));
        }
    }

    let models = artifacts.join("models");
    std::fs::create_dir_all(&models)?;
    write_rkv(&models.join(format!("{name}.rkv")), &ts)?;

    let manifest = json::obj(vec![
        ("name", json::s(name)),
        (
            "precision",
            json::s(if spec.q4 {
                "q4"
            } else if spec.f16 {
                "f16"
            } else {
                "f32"
            }),
        ),
        (
            "config",
            json::obj(vec![
                ("arch", json::s("rwkv")),
                ("variant", json::s("synthetic")),
                ("dim", json::num(d as f64)),
                ("layers", json::num(spec.layers as f64)),
                ("vocab", json::num(v as f64)),
                ("head_size", json::num(spec.head_size as f64)),
            ]),
        ),
        ("heads", json::num(spec.heads as f64)),
        ("ffn_dim", json::num(f as f64)),
        ("has_predictors", Value::Bool(spec.predictors)),
        ("has_hier_head", Value::Bool(spec.hier_head)),
        (
            "runtime",
            json::obj(vec![
                ("t_mlp", json::num(0.6)),
                ("t_quant", json::num(0.8)),
                ("hh_p_min", json::num(0.9)),
                ("hh_k_min", json::num(2.0)),
                ("hh_k_max", json::num(4.0)),
                ("emb_cache_capacity", json::num(8.0)),
            ]),
        ),
    ]);
    std::fs::write(models.join(format!("{name}.json")), manifest.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::weights::WeightStore;

    #[test]
    fn synth_checkpoint_loads_through_store() {
        let dir = std::env::temp_dir().join(format!("rwkv-synth-{}", std::process::id()));
        let spec = SynthSpec::tiny();
        write_synth_rwkv(&dir, "synth-unit", &spec).unwrap();
        let store = WeightStore::open(&dir.join("models/synth-unit.json")).unwrap();
        assert!(store.manifest.is_rwkv());
        assert_eq!(store.manifest.dim, spec.dim());
        assert_eq!(store.manifest.ffn_dim, spec.ffn);
        assert!(store.rkv.has("b0.pred.sign"));
        assert!(store.rkv.has("hh.h1"));
        let emb = store.rkv.entry("emb").unwrap();
        assert_eq!(emb.shape, vec![spec.vocab, spec.dim()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn q4_synth_checkpoint_places_formats_per_hybrid_recipe() {
        use crate::tensor::{DType, Mat};
        let dir = std::env::temp_dir().join(format!("rwkv-synth-q4-{}", std::process::id()));
        let mut spec = SynthSpec::tiny();
        spec.q4 = true;
        write_synth_rwkv(&dir, "synth-q4", &spec).unwrap();
        let store = WeightStore::open(&dir.join("models/synth-q4.json")).unwrap();
        let dt = |n: &str| store.rkv.entry(n).unwrap().dtype;
        // quantized: dense projections, wo, wk_t (Q4) and wv (Q4_1),
        // each with f16 per-group siblings alongside
        assert_eq!(dt("b0.att.wr.w"), DType::Q4);
        assert_eq!(dt("b0.att.wo.w"), DType::Q4);
        assert_eq!(dt("b0.ffn.wk_t"), DType::Q4);
        assert_eq!(dt("b0.ffn.wk_t.scale"), DType::F16);
        assert_eq!(dt("b0.ffn.wv"), DType::Q41);
        assert!(store.rkv.has("b0.ffn.wv.min"));
        assert_eq!(dt("head"), DType::Q4);
        // float per the hybrid recipe: embeddings, predictors, vectors
        assert_eq!(dt("emb"), DType::F32);
        assert_eq!(dt("b0.pred.l1"), DType::F32);
        // the store loads quantized mats (siblings resolved + validated)
        assert!(matches!(&*store.mat("b0.att.wo.w").unwrap(), Mat::Q4 { .. }));
        assert!(matches!(&*store.mat("b0.ffn.wv").unwrap(), Mat::Q41 { .. }));
        // and row-streams them: a RowView dot over a Q4 row is bitwise
        // the dense f32 dot over that row's dequantized values
        let rv = store.row_view("b0.ffn.wk_t").unwrap();
        let x: Vec<f32> = (0..spec.dim()).map(|i| 0.1 * i as f32 - 0.7).collect();
        let mut want = vec![0.0f32; spec.dim()];
        store.mat("b0.ffn.wk_t").unwrap().decode_row(3, &mut want);
        assert_eq!(rv.dot(3, &x), crate::tensor::dot_f32(&want, &x));
        std::fs::remove_dir_all(&dir).ok();
    }
}
