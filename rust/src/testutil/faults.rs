//! Deterministic fault injection for the serving stack (TEST-ONLY).
//!
//! A [`FaultPlan`] rides into the coordinator through
//! `CoordinatorConfig::faults` and is consulted once per scheduling
//! round, BEFORE the engine call: a round listed as slow sleeps first
//! (widening race windows so cancellation/disconnect tests are
//! deterministic instead of timing-lucky), and a round listed as failing
//! skips the engine entirely and behaves exactly like
//! `step_round_cached` returning `Err` — exercising the engine-global
//! error path (every in-flight stream gets `Error` then a terminal
//! `Done`).  Round indices are 0-based over the coordinator's lifetime
//! and count every stepped round, prefill or decode.
//!
//! The statefile helpers ([`truncate_file`], [`corrupt_byte`]) damage
//! on-disk artifacts so the corrupt/truncated-statefile recovery paths
//! (`io::statefile` load is best-effort, never fatal) are exercised in
//! `tests/faults.rs` without hand-crafted binary fixtures.
//!
//! Production code never constructs a plan; the hook costs one `Option`
//! check per round when unset.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::time::Duration;

/// Deterministic per-round fault schedule.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Rounds (0-based) whose engine call is replaced by an error.
    fail_rounds: Vec<u64>,
    /// `(round, sleep_ms)`: rounds that sleep before stepping.
    slow_rounds: Vec<(u64, u64)>,
    /// Message carried by injected errors (a recognizable default).
    message: String,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Inject an engine-round failure at `round` (0-based).
    pub fn fail_round(mut self, round: u64) -> Self {
        self.fail_rounds.push(round);
        self
    }

    /// Sleep `ms` before stepping `round` (0-based).  Only the listed
    /// round sleeps; use [`FaultPlan::slow_rounds_from`] for a sustained
    /// window.
    pub fn slow_round(mut self, round: u64, ms: u64) -> Self {
        self.slow_rounds.push((round, ms));
        self
    }

    /// Sleep `ms` before EVERY round from `start` (0-based) through
    /// `start + count - 1` — a sustained slowdown window.
    pub fn slow_rounds_from(mut self, start: u64, count: u64, ms: u64) -> Self {
        for r in start..start + count {
            self.slow_rounds.push((r, ms));
        }
        self
    }

    /// Override the injected error message.
    pub fn with_message(mut self, msg: &str) -> Self {
        self.message = msg.to_string();
        self
    }

    /// Sleep to apply before `round`, if any (the coordinator hook).
    pub fn slow_round_delay(&self, round: u64) -> Option<Duration> {
        self.slow_rounds
            .iter()
            .find(|(r, _)| *r == round)
            .map(|&(_, ms)| Duration::from_millis(ms))
    }

    /// Error replacing the engine call at `round`, if scheduled.
    pub fn round_error(&self, round: u64) -> Option<anyhow::Error> {
        self.fail_rounds.contains(&round).then(|| {
            let msg = if self.message.is_empty() {
                format!("injected fault: round {round} failed")
            } else {
                self.message.clone()
            };
            anyhow::anyhow!(msg)
        })
    }
}

/// Truncate `path` to its first `keep` bytes (a crash mid-write).
pub fn truncate_file(path: &Path, keep: u64) -> std::io::Result<()> {
    let f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.set_len(keep)?;
    Ok(())
}

/// Flip every bit of the byte at `offset` in `path` (silent corruption).
pub fn corrupt_byte(path: &Path, offset: u64) -> std::io::Result<()> {
    let mut f = std::fs::OpenOptions::new().read(true).write(true).open(path)?;
    f.seek(SeekFrom::Start(offset))?;
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    b[0] = !b[0];
    f.seek(SeekFrom::Start(offset))?;
    f.write_all(&b)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_schedules_failures_and_slowdowns() {
        let p = FaultPlan::new().fail_round(3).slow_round(1, 20).slow_rounds_from(5, 2, 7);
        assert!(p.round_error(3).is_some());
        assert!(p.round_error(2).is_none());
        assert_eq!(p.slow_round_delay(1), Some(Duration::from_millis(20)));
        assert_eq!(p.slow_round_delay(5), Some(Duration::from_millis(7)));
        assert_eq!(p.slow_round_delay(6), Some(Duration::from_millis(7)));
        assert_eq!(p.slow_round_delay(7), None);
        assert_eq!(p.slow_round_delay(0), None);
    }

    #[test]
    fn injected_error_carries_round_or_custom_message() {
        let p = FaultPlan::new().fail_round(0);
        assert!(p.round_error(0).unwrap().to_string().contains("round 0"));
        let p = FaultPlan::new().fail_round(0).with_message("disk on fire");
        assert_eq!(p.round_error(0).unwrap().to_string(), "disk on fire");
    }

    #[test]
    fn file_damage_helpers() {
        let dir = std::env::temp_dir().join(format!("rwkv-faults-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("victim.bin");
        std::fs::write(&path, [1u8, 2, 3, 4, 5]).unwrap();
        truncate_file(&path, 2).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2]);
        corrupt_byte(&path, 1).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, !2u8]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
