//! Property-testing harness (substrate S27 — no proptest in this
//! environment).  Deterministic generator-driven checks with failure-case
//! minimization by re-running on progressively smaller sizes.
//!
//! ```ignore
//! testutil::check("sorted stays sorted", 200, |g| {
//!     let mut v = g.vec_f32(0..64, -1e3..1e3);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     testutil::ensure(v.windows(2).all(|w| w[0] <= w[1]), "order")
//! });
//! ```

pub mod faults;
pub mod fuzz;
pub mod synth;

use crate::util::XorShift;

/// Generation context handed to each property iteration.
pub struct Gen {
    pub rng: XorShift,
    pub size_hint: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.rng.below((hi - lo).min(self.size_hint.max(1)))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn vec_f32(&mut self, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(1, max_len.max(2));
        (0..n).map(|_| self.rng.range_f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_f32() < 0.5
    }

    pub fn indices(&mut self, n: usize, max_count: usize) -> Vec<u32> {
        let count = self.usize_in(1, max_count.min(n).max(2));
        let mut all: Vec<u32> = (0..n as u32).collect();
        self.rng.shuffle(&mut all);
        all.truncate(count);
        all.sort_unstable();
        all
    }
}

/// Result of one property iteration.
pub type PropResult = Result<(), String>;

pub fn ensure(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn ensure_close(a: f32, b: f32, tol: f32, msg: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` for `iters` random cases; on failure, retry with shrinking
/// size hints to report the smallest failing size, then panic with the
/// seed so the case is reproducible.
pub fn check<F: FnMut(&mut Gen) -> PropResult>(name: &str, iters: usize, mut prop: F) {
    for i in 0..iters {
        let seed = 0xC0FFEE ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + (i * 97) % 256; // sweep sizes deterministically
        let mut g = Gen { rng: XorShift::new(seed), size_hint: size };
        if let Err(msg) = prop(&mut g) {
            // shrink: find the smallest size_hint that still fails
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut g = Gen { rng: XorShift::new(seed), size_hint: s };
                match prop(&mut g) {
                    Err(m) => {
                        smallest = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (iter {i}, seed {seed:#x}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 100, |g| {
            let x = g.f32_in(-100.0, 100.0);
            ensure(x.abs() >= 0.0, "abs")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn indices_sorted_unique() {
        check("indices sorted+unique", 50, |g| {
            let idx = g.indices(100, 20);
            ensure(idx.windows(2).all(|w| w[0] < w[1]), "strictly increasing")
        });
    }
}
