//! Offline structure-aware byte fuzzer (PR 7's parser hardening).
//!
//! The container has no cargo-fuzz / libFuzzer, so this is a
//! self-contained deterministic mutation engine over seed corpora: each
//! iteration picks a seed, applies a small burst of mutations (bit
//! flips, interesting bytes, interesting little-endian words, truncation,
//! insertion, cross-seed splicing — the classic AFL menu), and feeds the
//! result to the target under `catch_unwind`.
//!
//! A "crash" is any panic escaping the target.  Parsers under test return
//! `Result` for malformed input, so every panic is a bug by contract —
//! the harness collects up to [`MAX_CRASHES`] of them (iteration, input
//! hex, panic message) for the regression suite in `tests/fuzz_smoke.rs`
//! to report.
//!
//! Determinism: same seeds + same `iters` + same `seed` ⇒ the same byte
//! sequences, so a CI failure reproduces locally byte-for-byte.  Note
//! stack overflows are NOT catchable by `catch_unwind` — recursion-depth
//! bugs must be prevented at the parser level (see `json::MAX_DEPTH`);
//! the fuzzer would simply abort on one, which still fails CI.

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::XorShift;

/// Upper bound on collected crashes: past this the input space is
/// clearly broken and more examples add noise, not signal.
pub const MAX_CRASHES: usize = 8;

/// Hex dump cap — enough to reproduce small inputs verbatim and to
/// locate big ones in the corpus without megabyte test logs.
const HEX_CAP: usize = 256;

/// One panicking input, captured for the failure report.
#[derive(Debug)]
pub struct FuzzCrash {
    pub iteration: u64,
    /// Hex of the first [`HEX_CAP`] bytes of the offending input.
    pub input_hex: String,
    /// The panic payload, when it was a string.
    pub message: String,
}

/// Aggregate result of a fuzz run.
#[derive(Debug)]
pub struct FuzzOutcome {
    pub iters: u64,
    pub crashes: Vec<FuzzCrash>,
}

impl FuzzOutcome {
    /// Panic with a reproduction report if any input crashed the target.
    pub fn assert_clean(&self, target_name: &str) {
        assert!(
            self.crashes.is_empty(),
            "fuzz target '{target_name}': {} crashing input(s) in {} iterations:\n{}",
            self.crashes.len(),
            self.iters,
            self.crashes
                .iter()
                .map(|c| format!(
                    "  iter {}: {}\n    input: {}",
                    c.iteration, c.message, c.input_hex
                ))
                .collect::<Vec<_>>()
                .join("\n"),
        );
    }
}

fn hex(bytes: &[u8]) -> String {
    let shown = &bytes[..bytes.len().min(HEX_CAP)];
    let mut s: String = shown.iter().map(|b| format!("{b:02x}")).collect();
    if bytes.len() > HEX_CAP {
        s.push_str(&format!("… ({} bytes total)", bytes.len()));
    }
    s
}

const INTERESTING_BYTES: [u8; 8] = [0x00, 0x01, 0x10, 0x7f, 0x80, 0xef, 0xfe, 0xff];
const INTERESTING_U32: [u32; 8] =
    [0, 1, 0x7fff_ffff, 0x8000_0000, 0xffff_ffff, 0xffff_fffe, 64, 0x0100_0000];
const INTERESTING_U64: [u64; 8] = [
    0,
    1,
    u64::MAX,
    i64::MAX as u64,
    1 << 32,
    (1 << 32) + 1,
    u64::MAX - 64,
    1 << 63,
];

/// Apply one random mutation in place.
fn mutate(input: &mut Vec<u8>, seeds: &[Vec<u8>], rng: &mut XorShift) {
    if input.is_empty() {
        input.push(rng.next_u64() as u8);
        return;
    }
    match rng.below(7) {
        // single bit flip
        0 => {
            let i = rng.below(input.len());
            input[i] ^= 1 << rng.below(8);
        }
        // interesting byte
        1 => {
            let i = rng.below(input.len());
            input[i] = INTERESTING_BYTES[rng.below(INTERESTING_BYTES.len())];
        }
        // interesting u32, little-endian (length/offset fields)
        2 => {
            let w = INTERESTING_U32[rng.below(INTERESTING_U32.len())].to_le_bytes();
            let i = rng.below(input.len());
            for (k, &b) in w.iter().enumerate() {
                if let Some(slot) = input.get_mut(i + k) {
                    *slot = b;
                }
            }
        }
        // interesting u64, little-endian (the .rkv/statefile field width)
        3 => {
            let w = INTERESTING_U64[rng.below(INTERESTING_U64.len())].to_le_bytes();
            let i = rng.below(input.len());
            for (k, &b) in w.iter().enumerate() {
                if let Some(slot) = input.get_mut(i + k) {
                    *slot = b;
                }
            }
        }
        // truncate (header/payload cut mid-field)
        4 => {
            let keep = rng.below(input.len() + 1);
            input.truncate(keep);
        }
        // insert a short burst of random bytes
        5 => {
            let i = rng.below(input.len() + 1);
            let n = 1 + rng.below(9);
            for k in 0..n {
                input.insert(i + k, rng.next_u64() as u8);
            }
        }
        // splice a window from another seed (structure transplant)
        _ => {
            let donor = &seeds[rng.below(seeds.len())];
            if donor.is_empty() {
                return;
            }
            let from = rng.below(donor.len());
            let len = (1 + rng.below(32)).min(donor.len() - from);
            let at = rng.below(input.len() + 1);
            for (k, &b) in donor[from..from + len].iter().enumerate() {
                if at + k < input.len() {
                    input[at + k] = b;
                } else {
                    input.push(b);
                }
            }
        }
    }
}

/// Drive `target` with `iters` mutated inputs derived from `seeds`.
///
/// Iteration 0..seeds.len() replays each seed VERBATIM first (the corpus
/// itself must never crash), then every iteration mutates a fresh copy of
/// a random seed with a burst of 1–8 mutations.  The target must
/// tolerate arbitrary bytes; any escaping panic is recorded as a crash.
pub fn fuzz_bytes<F: FnMut(&[u8])>(
    seeds: &[Vec<u8>],
    iters: u64,
    seed: u64,
    mut target: F,
) -> FuzzOutcome {
    assert!(!seeds.is_empty(), "fuzz_bytes needs at least one seed input");
    let mut rng = XorShift::new(seed ^ 0xF0_5EED);
    let mut crashes = Vec::new();
    let mut run = |it: u64, input: &[u8], crashes: &mut Vec<FuzzCrash>| {
        let r = catch_unwind(AssertUnwindSafe(|| target(input)));
        if let Err(payload) = r {
            let message = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            crashes.push(FuzzCrash { iteration: it, input_hex: hex(input), message });
        }
    };
    let mut it = 0u64;
    for s in seeds {
        run(it, s, &mut crashes);
        it += 1;
    }
    while it < iters && crashes.len() < MAX_CRASHES {
        let mut input = seeds[rng.below(seeds.len())].clone();
        let edits = 1 + rng.below(8);
        for _ in 0..edits {
            mutate(&mut input, seeds, &mut rng);
        }
        run(it, &input, &mut crashes);
        it += 1;
    }
    FuzzOutcome { iters: it, crashes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_target_reports_no_crashes() {
        let seeds = vec![b"hello".to_vec(), vec![0u8; 16]];
        let out = fuzz_bytes(&seeds, 500, 7, |bytes| {
            // arbitrary total computation that cannot panic
            let _ = bytes.iter().fold(0u64, |a, &b| a.wrapping_add(b as u64));
        });
        assert_eq!(out.iters, 500);
        out.assert_clean("fold");
    }

    #[test]
    fn panicking_target_is_caught_and_reported() {
        let seeds = vec![vec![1u8, 2, 3, 4]];
        let out = fuzz_bytes(&seeds, 300, 11, |bytes| {
            // deliberately fragile: panics whenever a mutation zeroes
            // the first byte
            if bytes.first() == Some(&0) {
                panic!("boom on zero");
            }
        });
        assert!(!out.crashes.is_empty(), "mutations should hit byte[0] == 0");
        assert!(out.crashes.len() <= MAX_CRASHES);
        assert!(out.crashes[0].message.contains("boom"));
    }

    #[test]
    fn deterministic_across_runs() {
        let seeds = vec![b"seed-a".to_vec(), b"seed-b".to_vec()];
        let trace = |seed: u64| {
            let mut sum = 0u64;
            fuzz_bytes(&seeds, 200, seed, |b| {
                sum = sum
                    .wrapping_mul(31)
                    .wrapping_add(b.iter().fold(0u64, |a, &x| a.wrapping_add(x as u64)));
            });
            sum
        };
        assert_eq!(trace(42), trace(42));
        assert_ne!(trace(42), trace(43));
    }

    #[test]
    fn truncation_can_empty_then_regrow() {
        // regression guard for the empty-input path in `mutate`
        let seeds = vec![vec![9u8]];
        let out = fuzz_bytes(&seeds, 400, 3, |_| {});
        out.assert_clean("noop");
    }
}
