//! Source-text lints rustc/clippy can't express, run in CI next to the
//! compiler lints (PR 7's unsafe audit):
//!
//! - `lint-unchecked` — `src/tensor/` is the hot-loop core where an
//!   out-of-bounds index silently corrupts activations; it must use
//!   checked indexing (or the audited `SharedSliceMut` protocol), never
//!   `get_unchecked` / `from_raw_parts` / `unwrap_unchecked`.
//! - `lint-safety` — every `unsafe` block or `unsafe impl` in `src/`
//!   needs a `SAFETY:` comment within the preceding few lines, so the
//!   justification lives next to the obligation it discharges.
//!
//! Both walk the committed source text, so they hold for cfg'd-out code
//! (miri/loom paths) that a compiler-based lint would never see.
//!
//! `metrics-smoke` is the CI end-to-end scrape check: it boots a TCP
//! server over a synthetic checkpoint with the scrape endpoints enabled,
//! runs one completion, and validates `GET /metrics` + `GET /stats`
//! really serve parseable telemetry on the live port.

#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]
#![warn(clippy::disallowed_types)]

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const UNCHECKED_PATTERNS: [&str; 4] =
    ["get_unchecked", "from_raw_parts", "unwrap_unchecked", "unchecked_mul"];

fn main() -> Result<()> {
    let task = std::env::args().nth(1).unwrap_or_default();
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    match task.as_str() {
        "lint-unchecked" => lint_unchecked(&src.join("tensor")),
        "lint-safety" => lint_safety(&src),
        "metrics-smoke" => metrics_smoke(),
        _ => bail!("usage: xtask <lint-unchecked|lint-safety|metrics-smoke>"),
    }
}

/// Boot a synthetic-model server with the scrape endpoints on, run one
/// completion, and check `/metrics` and `/stats` serve real telemetry.
fn metrics_smoke() -> Result<()> {
    use rwkv_lite::coordinator::{batcher::BatchPolicy, Coordinator, CoordinatorConfig};
    use rwkv_lite::server::{http_get, Client, ServeOptions, Server};
    use rwkv_lite::testutil::synth::{write_synth_rwkv, SynthSpec};

    let dir = std::env::temp_dir().join(format!("rwkv-metrics-smoke-{}", std::process::id()));
    let spec = SynthSpec::tiny();
    write_synth_rwkv(&dir, "m", &spec).context("write synth model")?;
    let mut cfg = rwkv_lite::config::EngineConfig::vanilla("m", dir.clone());
    cfg.sparse_ffn = spec.predictors;
    cfg.hier_head = spec.hier_head;
    let coordinator = Coordinator::spawn_cfg(
        move || rwkv_lite::engine::RwkvEngine::load(cfg),
        CoordinatorConfig {
            policy: BatchPolicy { max_batch: 4, window_ms: 1 },
            ..CoordinatorConfig::default()
        },
    );
    let mut words: Vec<String> =
        ["<pad>", "<unk>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
    for i in 4..96 {
        words.push(format!("w{i}"));
    }
    let server =
        std::sync::Arc::new(Server::new(coordinator, rwkv_lite::text::Vocab::from_words(words)));
    let addr = "127.0.0.1:17391";
    let s2 = std::sync::Arc::clone(&server);
    let serve_thread = std::thread::spawn(move || {
        s2.serve(
            addr,
            ServeOptions {
                max_total_conns: Some(3),
                metrics_endpoint: true,
                ..ServeOptions::default()
            },
        )
    });
    std::thread::sleep(std::time::Duration::from_millis(150));

    let mut client = Client::connect(addr).context("connect")?;
    let done = client.complete("w5 w6", 4, 0.0).context("completion")?;
    if done.tokens == 0 {
        bail!("smoke completion produced no tokens");
    }
    drop(client);

    let (status, body) = http_get(addr, "/metrics").context("scrape /metrics")?;
    if status != 200 {
        bail!("/metrics returned {status}");
    }
    for needle in [
        "# TYPE rwkv_ttft_seconds histogram",
        "rwkv_requests_completed 1",
        "rwkv_request_total_seconds_count 1",
    ] {
        if !body.contains(needle) {
            bail!("/metrics is missing '{needle}':\n{body}");
        }
    }
    let rounds = body
        .lines()
        .find_map(|l| l.strip_prefix("rwkv_rounds "))
        .and_then(|v| v.parse::<u64>().ok())
        .context("/metrics carries the rounds counter")?;
    if rounds == 0 {
        bail!("rounds counter stayed zero after a completion");
    }

    let (status, body) = http_get(addr, "/stats").context("scrape /stats")?;
    if status != 200 {
        bail!("/stats returned {status}");
    }
    let v = rwkv_lite::json::parse(body.trim()).context("/stats body parses as JSON")?;
    if v.f64_at(&["counters", "requests_completed"]) != Some(1.0) {
        bail!("/stats counters disagree with the completion:\n{body}");
    }
    if v.f64_at(&["histograms", "ttft_secs", "p99_secs"]).unwrap_or(0.0) <= 0.0 {
        bail!("/stats TTFT summary is empty:\n{body}");
    }

    // the third allowed connection: an unknown path must 404, not hang
    let (status, _) = http_get(addr, "/nope").context("scrape unknown path")?;
    if status != 404 {
        bail!("unknown path returned {status}, want 404");
    }

    serve_thread.join().expect("serve thread").context("serve")?;
    std::fs::remove_dir_all(&dir).ok();
    println!("metrics-smoke: /metrics + /stats live, rounds={rounds}");
    Ok(())
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            rust_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    out.sort();
    Ok(())
}

/// Strip `//` comments so a pattern *mentioned* in prose (like this
/// file's own docs) doesn't trip the lint.  Good enough for this
/// codebase: no raw strings or block comments contain the patterns.
fn code_part(line: &str) -> &str {
    line.split("//").next().unwrap_or(line)
}

fn lint_unchecked(tensor_dir: &Path) -> Result<()> {
    let mut files = Vec::new();
    rust_files(tensor_dir, &mut files)?;
    let mut bad = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        for (i, line) in text.lines().enumerate() {
            let code = code_part(line);
            for pat in UNCHECKED_PATTERNS {
                if code.contains(pat) {
                    bad.push(format!("{}:{}: `{pat}`", path.display(), i + 1));
                }
            }
        }
    }
    if !bad.is_empty() {
        bail!(
            "unchecked indexing in src/tensor/ ({} site(s)) — use checked slices or the \
             SharedSliceMut protocol:\n  {}",
            bad.len(),
            bad.join("\n  ")
        );
    }
    println!("lint-unchecked: {} tensor files clean", files.len());
    Ok(())
}

/// Is the site at `lines[i]` justified by the contiguous run of
/// comments/attributes directly above it?  A `// SAFETY:` comment covers
/// any site; a rustdoc `# Safety` section covers `unsafe fn`
/// declarations (the caller-obligation idiom — the body's own blocks
/// still need their own `SAFETY:`).  Also accepts `SAFETY:` on the site
/// line itself (one-line `unsafe { ... } // SAFETY: ...` style).
fn has_safety_justification(lines: &[&str], i: usize) -> bool {
    if lines[i].contains("SAFETY:") {
        return true;
    }
    let mut k = i;
    while k > 0 {
        k -= 1;
        let t = lines[k].trim_start();
        let is_meta =
            t.is_empty() || t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!");
        if !is_meta {
            return false;
        }
        if t.contains("SAFETY:") || t.contains("# Safety") {
            return true;
        }
    }
    false
}

fn lint_safety(src: &Path) -> Result<()> {
    let mut files = Vec::new();
    rust_files(src, &mut files)?;
    // this binary's own sources hold pattern text in docs; the lint is
    // about the library and its kernels
    files.retain(|p| !p.components().any(|c| c.as_os_str() == "bin"));
    let mut bad = Vec::new();
    let mut sites = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            let code = code_part(line);
            let is_site = code.contains("unsafe {")
                || code.contains("unsafe impl")
                || code.contains("unsafe fn");
            if !is_site {
                continue;
            }
            sites += 1;
            if !has_safety_justification(&lines, i) {
                bad.push(format!("{}:{}: {}", path.display(), i + 1, line.trim()));
            }
        }
    }
    if !bad.is_empty() {
        bail!(
            "{} unsafe site(s) without a `// SAFETY:` (or `# Safety` doc) justification:\n  {}",
            bad.len(),
            bad.join("\n  ")
        );
    }
    println!("lint-safety: {sites} unsafe sites documented across {} files", files.len());
    Ok(())
}
