//! Source-text lints rustc/clippy can't express, run in CI next to the
//! compiler lints (PR 7's unsafe audit):
//!
//! - `lint-unchecked` — `src/tensor/` is the hot-loop core where an
//!   out-of-bounds index silently corrupts activations; it must use
//!   checked indexing (or the audited `SharedSliceMut` protocol), never
//!   `get_unchecked` / `from_raw_parts` / `unwrap_unchecked`.
//! - `lint-safety` — every `unsafe` block or `unsafe impl` in `src/`
//!   needs a `SAFETY:` comment within the preceding few lines, so the
//!   justification lives next to the obligation it discharges.
//!
//! Both walk the committed source text, so they hold for cfg'd-out code
//! (miri/loom paths) that a compiler-based lint would never see.

#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]
#![warn(clippy::disallowed_types)]

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const UNCHECKED_PATTERNS: [&str; 4] =
    ["get_unchecked", "from_raw_parts", "unwrap_unchecked", "unchecked_mul"];

fn main() -> Result<()> {
    let task = std::env::args().nth(1).unwrap_or_default();
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    match task.as_str() {
        "lint-unchecked" => lint_unchecked(&src.join("tensor")),
        "lint-safety" => lint_safety(&src),
        _ => bail!("usage: xtask <lint-unchecked|lint-safety>"),
    }
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            rust_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    out.sort();
    Ok(())
}

/// Strip `//` comments so a pattern *mentioned* in prose (like this
/// file's own docs) doesn't trip the lint.  Good enough for this
/// codebase: no raw strings or block comments contain the patterns.
fn code_part(line: &str) -> &str {
    line.split("//").next().unwrap_or(line)
}

fn lint_unchecked(tensor_dir: &Path) -> Result<()> {
    let mut files = Vec::new();
    rust_files(tensor_dir, &mut files)?;
    let mut bad = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        for (i, line) in text.lines().enumerate() {
            let code = code_part(line);
            for pat in UNCHECKED_PATTERNS {
                if code.contains(pat) {
                    bad.push(format!("{}:{}: `{pat}`", path.display(), i + 1));
                }
            }
        }
    }
    if !bad.is_empty() {
        bail!(
            "unchecked indexing in src/tensor/ ({} site(s)) — use checked slices or the \
             SharedSliceMut protocol:\n  {}",
            bad.len(),
            bad.join("\n  ")
        );
    }
    println!("lint-unchecked: {} tensor files clean", files.len());
    Ok(())
}

/// Is the site at `lines[i]` justified by the contiguous run of
/// comments/attributes directly above it?  A `// SAFETY:` comment covers
/// any site; a rustdoc `# Safety` section covers `unsafe fn`
/// declarations (the caller-obligation idiom — the body's own blocks
/// still need their own `SAFETY:`).  Also accepts `SAFETY:` on the site
/// line itself (one-line `unsafe { ... } // SAFETY: ...` style).
fn has_safety_justification(lines: &[&str], i: usize) -> bool {
    if lines[i].contains("SAFETY:") {
        return true;
    }
    let mut k = i;
    while k > 0 {
        k -= 1;
        let t = lines[k].trim_start();
        let is_meta =
            t.is_empty() || t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!");
        if !is_meta {
            return false;
        }
        if t.contains("SAFETY:") || t.contains("# Safety") {
            return true;
        }
    }
    false
}

fn lint_safety(src: &Path) -> Result<()> {
    let mut files = Vec::new();
    rust_files(src, &mut files)?;
    // this binary's own sources hold pattern text in docs; the lint is
    // about the library and its kernels
    files.retain(|p| !p.components().any(|c| c.as_os_str() == "bin"));
    let mut bad = Vec::new();
    let mut sites = 0usize;
    for path in &files {
        let text = std::fs::read_to_string(path)?;
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            let code = code_part(line);
            let is_site = code.contains("unsafe {")
                || code.contains("unsafe impl")
                || code.contains("unsafe fn");
            if !is_site {
                continue;
            }
            sites += 1;
            if !has_safety_justification(&lines, i) {
                bad.push(format!("{}:{}: {}", path.display(), i + 1, line.trim()));
            }
        }
    }
    if !bad.is_empty() {
        bail!(
            "{} unsafe site(s) without a `// SAFETY:` (or `# Safety` doc) justification:\n  {}",
            bad.len(),
            bad.join("\n  ")
        );
    }
    println!("lint-safety: {sites} unsafe sites documented across {} files", files.len());
    Ok(())
}
