//! Minimal JSON parser + writer (substrate — no serde in this environment).
//!
//! Consumes the python-written manifests (`artifacts/models/*.json`),
//! vocab/tasks files, and runtime configs; writes experiment reports.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for our ASCII artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `v.at(&["runtime", "t_mlp"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn f64_at(&self, path: &[&str]) -> Option<f64> {
        self.at(path)?.as_f64()
    }

    pub fn str_at(&self, path: &[&str]) -> Option<&str> {
        self.at(path)?.as_str()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no Infinity/NaN tokens; `null` keeps the
                    // writer's output always re-parseable
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Containers deeper than this are rejected: the parser recurses per
/// nesting level, and unbounded input (the TCP server feeds this parser)
/// must not be able to overflow the stack — an uncatchable abort, unlike
/// the `Err` this limit produces.  128 is far beyond any manifest or
/// request this crate exchanges.
pub const MAX_DEPTH: usize = 128;

pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.nested(Self::object),
            Some(b'[') => self.nested(Self::array),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    /// Run a container parser one nesting level down, enforcing
    /// [`MAX_DEPTH`] (stack-overflow guard; see its docs).
    fn nested(&mut self, f: fn(&mut Self) -> Result<Value>) -> Result<Value> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} levels at byte {}", self.i);
        }
        let r = f(self);
        self.depth -= 1;
        r
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).context("short \\u")?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).context("bad \\u escape")?);
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                other => bail!("expected ',' or ']' got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                other => bail!("expected ',' or '}}' got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "e": "x\ny"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.f64_at(&["a"]).is_none(), true);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Value::Num(2.5));
        assert_eq!(v.at(&["b", "d"]).unwrap(), &Value::Bool(true));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested_deep() {
        let v = parse("[[[[[1]]]]]").unwrap();
        let mut cur = &v;
        for _ in 0..5 {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur, &Value::Num(1.0));
    }

    #[test]
    fn escapes() {
        let v = parse(r#""aA\t\"b\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aA\t\"b\"");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn writer_escapes_and_ints() {
        let v = obj(vec![("k", s("a\"b")), ("n", num(3.0))]);
        assert_eq!(v.to_string(), r#"{"k":"a\"b","n":3}"#);
    }

    #[test]
    fn writer_emits_null_for_non_finite() {
        // "inf"/"NaN" are not JSON; the writer's output must always
        // re-parse (a property the json fuzz target checks at scale)
        for bad in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let v = arr(vec![num(bad), num(1.0)]);
            assert_eq!(v.to_string(), "[null,1]");
            assert!(parse(&v.to_string()).is_ok());
        }
    }

    #[test]
    fn depth_limit_rejects_instead_of_overflowing() {
        // exactly at the limit: fine
        let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(parse(&ok).is_ok());
        // one past: Err
        let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(parse(&deep).is_err());
        // pathological input must come back as Err, not a stack overflow
        assert!(parse(&"[".repeat(200_000)).is_err());
        assert!(parse(&"{\"a\":".repeat(50_000)).is_err());
        // depth is per-path, not cumulative: wide-but-shallow stays legal
        let wide = format!("[{}1]", "[1],".repeat(1_000));
        assert!(parse(&wide).is_ok());
    }

    #[test]
    fn huge_numeric_literals_parse_to_infinity() {
        // f64 semantics: 1e999 overflows to +inf — the *parser* accepts
        // it; consumers (the server's request validation) must reject
        // non-finite where it matters
        let v = parse("1e999").unwrap();
        assert_eq!(v.as_f64(), Some(f64::INFINITY));
        let v = parse("-1e999").unwrap();
        assert_eq!(v.as_f64(), Some(f64::NEG_INFINITY));
        // and the writer round-trips them as null (valid JSON)
        assert_eq!(parse(&v.to_string()).unwrap(), Value::Null);
    }
}
