//! Read-only memory map (libc; no memmap2 crate in this environment).
//!
//! The `.rkv` weight file is mapped, not read: layerwise / sparse loading
//! strategies copy *only the touched rows* into RAM, which is exactly the
//! paper's "load only a small subset of the model parameters" model — the
//! file-backed pages behind untouched weights never count against the
//! inference footprint.
//!
//! The mapping is `PROT_READ`/`MAP_PRIVATE` for its whole lifetime, so it
//! doubles as the *shared file handle* for concurrent per-block reads:
//! any number of threads may fault pages simultaneously (the layerwise
//! prefetcher streams block N+1 on an I/O thread while the round thread
//! still reads block N).  [`Mmap::advise_willneed`] hands the kernel an
//! explicit readahead hint for a byte range so a background prefetch
//! starts disk I/O for a whole block instead of faulting page by page.

use std::fs::File;
use std::os::unix::io::AsRawFd;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub struct Mmap {
    ptr: *mut libc::c_void,
    len: usize,
}

// SAFETY: the mapping is read-only (PROT_READ, MAP_PRIVATE) for its
// whole lifetime, so shared references across threads are sound.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            bail!("cannot mmap empty file {}", path.display());
        }
        // SAFETY: valid fd, length checked; mapping is read-only/private.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap({}) failed: {}", path.display(), std::io::Error::last_os_error());
        }
        Ok(Self { ptr, len })
    }

    pub fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len come from a successful mmap; mapping lives as
        // long as self.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Best-effort `madvise(MADV_WILLNEED)` on `[offset, offset + len)`:
    /// asks the kernel to start reading the backing pages now, so a
    /// later copy out of the range faults warm pages instead of cold
    /// disk.  Bounds are clamped and page-aligned; failures are ignored
    /// (the copy still works, just colder).
    pub fn advise_willneed(&self, offset: usize, len: usize) {
        if len == 0 || offset >= self.len {
            return;
        }
        // SAFETY: sysconf is always safe to call.
        let page = unsafe { libc::sysconf(libc::_SC_PAGESIZE) }.max(1) as usize;
        let start = offset - offset % page;
        let end = (offset + len).min(self.len);
        // SAFETY: [start, end) lies inside the live mapping; madvise with
        // WILLNEED never alters the mapping's contents or protection.
        unsafe {
            libc::madvise(
                (self.ptr as *mut u8).add(start) as *mut libc::c_void,
                end - start,
                libc::MADV_WILLNEED,
            );
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: ptr/len from mmap; unmapped exactly once.
        unsafe {
            libc::munmap(self.ptr, self.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_file_contents() {
        let dir = std::env::temp_dir().join(format!("rkvlite-mmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let mut f = File::create(&path).unwrap();
        f.write_all(b"hello mmap").unwrap();
        drop(f);
        let m = Mmap::open(&path).unwrap();
        assert_eq!(m.bytes(), b"hello mmap");
        assert_eq!(m.len(), 10);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rkvlite-empty-{}", std::process::id()));
        File::create(&path).unwrap();
        assert!(Mmap::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
