//! Read-only memory map (libc; no memmap2 crate in this environment).
//!
//! The `.rkv` weight file is mapped, not read: layerwise / sparse loading
//! strategies copy *only the touched rows* into RAM, which is exactly the
//! paper's "load only a small subset of the model parameters" model — the
//! file-backed pages behind untouched weights never count against the
//! inference footprint.
//!
//! The mapping is `PROT_READ`/`MAP_PRIVATE` for its whole lifetime, so it
//! doubles as the *shared file handle* for concurrent per-block reads:
//! any number of threads may fault pages simultaneously (the layerwise
//! prefetcher streams block N+1 on an I/O thread while the round thread
//! still reads block N).  [`Mmap::advise_willneed`] hands the kernel an
//! explicit readahead hint for a byte range so a background prefetch
//! starts disk I/O for a whole block instead of faulting page by page.
//!
//! Two backings share the same API: the real `mmap`, and an owned
//! 8-byte-aligned in-memory copy ([`Mmap::from_bytes`]).  Under Miri —
//! which has no `mmap`/`madvise` — `open` transparently reads the file
//! into the owned backing, so every `io` test runs under the interpreter
//! unchanged; the fuzzers feed mutated buffers through the same path.

use std::fs::File;
use std::os::unix::io::AsRawFd;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::cast::AlignedBytes;

enum Backing {
    /// A live `PROT_READ`/`MAP_PRIVATE` mapping, unmapped exactly once in
    /// `Drop`.
    Map { ptr: *mut libc::c_void, len: usize },
    /// Owned aligned copy: Miri runs, in-memory checkpoints, fuzz inputs.
    Owned(AlignedBytes),
}

pub struct Mmap {
    backing: Backing,
}

// SAFETY: `Mmap` is an immutable byte buffer for its entire lifetime.
// The `Map` backing is PROT_READ/MAP_PRIVATE — no API returns `&mut`,
// nothing ever writes through the mapping, and `munmap` runs exactly once
// in `Drop`, strictly after every `bytes()` borrow has ended (they borrow
// `&self`).  The `Owned` backing is a plain heap buffer with the same
// read-only API.  Concurrent readers therefore cannot race; moving the
// struct between threads moves only the pointer/length.  (A concurrent
// truncation of the *file* by another process can SIGBUS a mapped read —
// an accepted operational hazard of file mapping, not a memory-safety
// issue introduced by these impls.)
unsafe impl Send for Mmap {}
// SAFETY: see `Send` above — the shared-reference API is read-only.
unsafe impl Sync for Mmap {}

impl Mmap {
    pub fn open(path: &Path) -> Result<Self> {
        if cfg!(miri) {
            // Miri cannot model mmap; an owned copy preserves the API
            // (and the alignment guarantees) for interpreted tests.
            return Self::open_copied(path);
        }
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            bail!("cannot mmap empty file {}", path.display());
        }
        // SAFETY: valid fd, non-zero length; the kernel picks the address
        // (null hint) and the mapping is read-only/private.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap({}) failed: {}", path.display(), std::io::Error::last_os_error());
        }
        Ok(Self { backing: Backing::Map { ptr, len } })
    }

    /// Read the whole file into the owned backing (the Miri path; also
    /// useful for tiny checkpoints where mapping buys nothing).
    fn open_copied(path: &Path) -> Result<Self> {
        let data = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if data.is_empty() {
            bail!("cannot mmap empty file {}", path.display());
        }
        Ok(Self::from_bytes(&data))
    }

    /// An in-memory "mapping" over a copy of `data` (8-byte aligned, so
    /// typed views behave exactly like the mmap'd file, which is
    /// page-aligned).  Used by the parser fuzzers and Miri tests.
    pub fn from_bytes(data: &[u8]) -> Self {
        Self { backing: Backing::Owned(AlignedBytes::from_slice(data)) }
    }

    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            // SAFETY: ptr/len come from a successful mmap that lives as
            // long as self; the mapping is never written through or
            // remapped, so a shared byte view is sound for `&self`'s
            // lifetime.
            Backing::Map { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr as *const u8, *len)
            },
            Backing::Owned(a) => a.bytes(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            Backing::Map { len, .. } => *len,
            Backing::Owned(a) => a.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Best-effort `madvise(MADV_WILLNEED)` on `[offset, offset + len)`:
    /// asks the kernel to start reading the backing pages now, so a
    /// later copy out of the range faults warm pages instead of cold
    /// disk.  Bounds are overflow-checked and clamped to the mapping,
    /// the start is page-aligned; failures are ignored (the copy still
    /// works, just colder).  A no-op on the owned backing.
    pub fn advise_willneed(&self, offset: usize, len: usize) {
        let Backing::Map { ptr, len: map_len } = &self.backing else {
            return;
        };
        if len == 0 || offset >= *map_len {
            return;
        }
        // SAFETY: sysconf is always safe to call.
        let page = unsafe { libc::sysconf(libc::_SC_PAGESIZE) }.max(1) as usize;
        let start = offset - offset % page;
        // `offset < map_len` already; saturating add caps a huge `len`
        // request at the end of the mapping instead of wrapping around.
        let end = offset.saturating_add(len).min(*map_len);
        // SAFETY: start < end <= map_len, so [start, end) lies inside the
        // live mapping; madvise with WILLNEED never alters the mapping's
        // contents or protection.
        unsafe {
            libc::madvise(
                (*ptr as *mut u8).add(start) as *mut libc::c_void,
                end - start,
                libc::MADV_WILLNEED,
            );
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        if let Backing::Map { ptr, len } = &self.backing {
            // SAFETY: ptr/len from a successful mmap; Drop runs once, and
            // no borrow of the mapping can outlive self.
            unsafe {
                libc::munmap(*ptr, *len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_file_contents() {
        let dir = std::env::temp_dir().join(format!("rkvlite-mmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let mut f = File::create(&path).unwrap();
        f.write_all(b"hello mmap").unwrap();
        drop(f);
        let m = Mmap::open(&path).unwrap();
        assert_eq!(m.bytes(), b"hello mmap");
        assert_eq!(m.len(), 10);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rkvlite-empty-{}", std::process::id()));
        File::create(&path).unwrap();
        assert!(Mmap::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn owned_backing_matches_source() {
        let m = Mmap::from_bytes(b"in-memory map");
        assert_eq!(m.bytes(), b"in-memory map");
        assert_eq!(m.len(), 13);
        assert!(!m.is_empty());
        // advise is a documented no-op here — including absurd ranges
        m.advise_willneed(usize::MAX - 1, usize::MAX);
    }

    #[test]
    fn advise_overflow_ranges_are_safe() {
        let dir = std::env::temp_dir().join(format!("rkvlite-adv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("adv.bin");
        std::fs::write(&path, vec![7u8; 8192]).unwrap();
        let m = Mmap::open(&path).unwrap();
        // offset + len would overflow usize: must clamp, not wrap
        m.advise_willneed(4096, usize::MAX);
        m.advise_willneed(usize::MAX, 1); // offset past the end: no-op
        m.advise_willneed(0, 0); // empty: no-op
        assert_eq!(m.bytes()[0], 7);
        std::fs::remove_file(&path).ok();
    }
}
