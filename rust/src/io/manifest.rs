//! Model manifest (`artifacts/models/<name>.json`) — config + runtime
//! thresholds + the HLO component parameter-order mapping.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::{self, Value};

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub precision: String,
    pub arch: String,      // "rwkv" | "transformer"
    pub variant: String,   // tiny | small | medium | regular
    pub dim: usize,
    pub layers: usize,
    pub vocab: usize,
    pub head_size: usize,
    pub heads: usize,
    pub ffn_dim: usize,
    pub svd_rank_div: usize,
    pub enhanced_svd: bool,
    pub has_predictors: bool,
    pub has_hier_head: bool,
    // runtime thresholds (paper defaults; §5.1 / §3.3)
    pub t_mlp: f32,
    pub t_quant: f32,
    pub hh_p_min: f32,
    pub hh_k_min: usize,
    pub hh_k_max: usize,
    pub emb_cache_capacity: usize,
    /// HLO component -> ordered weight names (empty for transformer).
    pub hlo: Value,
    pub raw: Value,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(json_path: &Path) -> Result<Self> {
        let v = json::parse_file(json_path)?;
        let cfg = v.get("config").context("manifest missing 'config'")?;
        let dim = cfg.f64_at(&["dim"]).context("config.dim")? as usize;
        let head_size = cfg.f64_at(&["head_size"]).unwrap_or(16.0) as usize;
        let heads = v.f64_at(&["heads"]).unwrap_or((dim / head_size) as f64) as usize;
        let rt = |k: &str, d: f64| v.f64_at(&["runtime", k]).unwrap_or(d);
        Ok(Self {
            name: v.str_at(&["name"]).context("name")?.to_string(),
            precision: v.str_at(&["precision"]).unwrap_or("f16").to_string(),
            arch: cfg.str_at(&["arch"]).unwrap_or("rwkv").to_string(),
            variant: cfg.str_at(&["variant"]).unwrap_or("?").to_string(),
            dim,
            layers: cfg.f64_at(&["layers"]).context("config.layers")? as usize,
            vocab: cfg.f64_at(&["vocab"]).unwrap_or(1024.0) as usize,
            head_size,
            heads,
            ffn_dim: v.f64_at(&["ffn_dim"]).unwrap_or((dim as f64) * 3.5) as usize,
            svd_rank_div: cfg.f64_at(&["svd_rank_div"]).unwrap_or(0.0) as usize,
            enhanced_svd: cfg.at(&["enhanced_svd"]).and_then(|b| b.as_bool()).unwrap_or(false),
            has_predictors: v.get("has_predictors").and_then(|b| b.as_bool()).unwrap_or(false),
            has_hier_head: v.get("has_hier_head").and_then(|b| b.as_bool()).unwrap_or(false),
            t_mlp: rt("t_mlp", 0.7) as f32,
            t_quant: rt("t_quant", 0.8) as f32,
            hh_p_min: rt("hh_p_min", 0.95) as f32,
            hh_k_min: rt("hh_k_min", 3.0) as usize,
            hh_k_max: rt("hh_k_max", 16.0) as usize,
            emb_cache_capacity: rt("emb_cache_capacity", 64.0) as usize,
            hlo: v.get("hlo").cloned().unwrap_or(Value::Null),
            raw: v.clone(),
            dir: json_path.parent().unwrap_or(Path::new(".")).to_path_buf(),
        })
    }

    /// Path of the sibling `.rkv` checkpoint.
    pub fn rkv_path(&self) -> PathBuf {
        self.dir.join(format!("{}.rkv", self.name))
    }

    /// Ordered HLO parameter names for a component ("timemix"/"chanmix"/"head").
    pub fn hlo_params(&self, component: &str) -> Option<Vec<String>> {
        let arr = self.hlo.at(&[component, "params"])?.as_arr()?;
        Some(arr.iter().filter_map(|v| v.as_str().map(String::from)).collect())
    }

    /// HLO text file path for a component (relative to artifacts/hlo).
    pub fn hlo_path(&self, artifacts_root: &Path, component: &str) -> Option<PathBuf> {
        let rel = self.hlo.at(&[component, "path"])?.as_str()?;
        Some(artifacts_root.join("hlo").join(rel))
    }

    pub fn is_rwkv(&self) -> bool {
        self.arch.starts_with("rwkv")
    }
}
