//! `.rkv` checkpoint reader — mirrors python/compile/export.py exactly.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"RKV1"
//! u32    version (=1)
//! u32    n_tensors
//! u64    data_offset (absolute)
//! index  n_tensors x { u16 name_len, name, u8 dtype, u8 ndim,
//!                      u32 dims[ndim], u64 offset(rel), u64 nbytes }
//! data   64-byte-aligned tensor payloads
//! ```

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::io::Mmap;
use crate::tensor::{DType, Mat};
use crate::util::f16::f16_to_f32;

#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub offset: u64, // relative to data section
    pub nbytes: u64,
}

impl TensorEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

pub struct RkvFile {
    map: Arc<Mmap>,
    data_offset: usize,
    index: BTreeMap<String, TensorEntry>,
}

fn rd_u16(b: &[u8], o: usize) -> u16 {
    u16::from_le_bytes([b[o], b[o + 1]])
}
fn rd_u32(b: &[u8], o: usize) -> u32 {
    u32::from_le_bytes([b[o], b[o + 1], b[o + 2], b[o + 3]])
}
fn rd_u64(b: &[u8], o: usize) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[o..o + 8]);
    u64::from_le_bytes(a)
}

impl RkvFile {
    pub fn open(path: &Path) -> Result<Self> {
        let map = Arc::new(Mmap::open(path)?);
        let b = map.bytes();
        if b.len() < 20 || &b[0..4] != b"RKV1" {
            bail!("{}: not an RKV1 file", path.display());
        }
        let version = rd_u32(b, 4);
        if version != 1 {
            bail!("unsupported rkv version {version}");
        }
        let n = rd_u32(b, 8) as usize;
        let data_offset = rd_u64(b, 12) as usize;
        let mut pos = 20usize;
        let mut index = BTreeMap::new();
        for _ in 0..n {
            let nl = rd_u16(b, pos) as usize;
            pos += 2;
            let name = std::str::from_utf8(&b[pos..pos + nl])?.to_string();
            pos += nl;
            let dtype = DType::from_code(b[pos])?;
            let ndim = b[pos + 1] as usize;
            pos += 2;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(rd_u32(b, pos) as usize);
                pos += 4;
            }
            let offset = rd_u64(b, pos);
            let nbytes = rd_u64(b, pos + 8);
            pos += 16;
            if data_offset as u64 + offset + nbytes > b.len() as u64 {
                bail!("tensor '{name}' exceeds file bounds");
            }
            index.insert(
                name.clone(),
                TensorEntry { name, dtype, shape, offset, nbytes },
            );
        }
        Ok(Self { map, data_offset, index })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.index.keys().map(|s| s.as_str())
    }

    pub fn entry(&self, name: &str) -> Result<&TensorEntry> {
        self.index
            .get(name)
            .with_context(|| format!("tensor '{name}' not in checkpoint"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Raw bytes of a tensor (zero-copy view into the map).
    pub fn raw(&self, name: &str) -> Result<&[u8]> {
        let e = self.entry(name)?;
        let start = self.data_offset + e.offset as usize;
        Ok(&self.map.bytes()[start..start + e.nbytes as usize])
    }

    fn typed<T: Copy>(&self, name: &str) -> Result<&[T]> {
        let raw = self.raw(name)?;
        let size = std::mem::size_of::<T>();
        if raw.len() % size != 0 {
            bail!("tensor '{name}' size not a multiple of element size");
        }
        if raw.as_ptr() as usize % std::mem::align_of::<T>() != 0 {
            bail!("tensor '{name}' misaligned"); // export aligns to 64
        }
        // SAFETY: alignment and length checked; T is Copy/POD here (f32,
        // u16, i8, i32) and the mapping outlives self.
        Ok(unsafe { std::slice::from_raw_parts(raw.as_ptr() as *const T, raw.len() / size) })
    }

    /// Load a 1-D f32 vector (copies; counted by the caller's tracker).
    pub fn vec_f32(&self, name: &str) -> Result<Vec<f32>> {
        let e = self.entry(name)?;
        match e.dtype {
            DType::F32 => Ok(self.typed::<f32>(name)?.to_vec()),
            DType::F16 => Ok(self
                .typed::<u16>(name)?
                .iter()
                .map(|&h| f16_to_f32(h))
                .collect()),
            _ => bail!("tensor '{name}' is not float"),
        }
    }

    pub fn vec_i32(&self, name: &str) -> Result<Vec<i32>> {
        let e = self.entry(name)?;
        match e.dtype {
            DType::I32 => Ok(self.typed::<i32>(name)?.to_vec()),
            _ => bail!("tensor '{name}' is not i32"),
        }
    }

    /// Load a 2-D matrix in its storage precision.  For `I8` tensors the
    /// sibling `<name>.scale` vector is loaded alongside.
    pub fn mat(&self, name: &str) -> Result<Mat> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            bail!("tensor '{name}' is {}-D, want 2-D", e.shape.len());
        }
        let (rows, cols) = (e.shape[0], e.shape[1]);
        Ok(match e.dtype {
            DType::F32 => Mat::F32 { rows, cols, data: self.typed::<f32>(name)?.to_vec() },
            DType::F16 => Mat::F16 { rows, cols, data: self.typed::<u16>(name)?.to_vec() },
            DType::I8 => {
                let scale = self.vec_f32(&format!("{name}.scale"))?;
                Mat::I8 { rows, cols, data: self.typed::<i8>(name)?.to_vec(), scale }
            }
            other => bail!("tensor '{name}': dtype {:?} is not a matrix type", other),
        })
    }

    /// Zero-copy row view of an f16 matrix (embedding cache fast path).
    pub fn row_f16(&self, name: &str, row: usize) -> Result<&[u16]> {
        let e = self.entry(name)?;
        let cols = *e.shape.last().unwrap();
        let all = self.typed::<u16>(name)?;
        Ok(&all[row * cols..(row + 1) * cols])
    }

    /// Kick off kernel readahead for every tensor whose name starts with
    /// `prefix` (see [`Mmap::advise_willneed`]); returns the stored bytes
    /// advised.  The layerwise prefetcher calls this before decoding a
    /// block so the disk streams the block's tensors ahead of the typed
    /// copies instead of faulting tensor by tensor.
    pub fn advise_prefix(&self, prefix: &str) -> u64 {
        self.advise_prefix_where(prefix, |_| true)
    }

    /// [`RkvFile::advise_prefix`] restricted to tensors `keep` accepts.
    /// Readahead must match what the caller will actually decode: the
    /// layerwise prefetcher skips the sparse-managed FFN matrices (their
    /// rows stream individually per §3.2) and the resident predictor
    /// tensors, otherwise MADV_WILLNEED would drag the block's largest
    /// tensors off disk for nothing.
    pub fn advise_prefix_where<F: Fn(&str) -> bool>(&self, prefix: &str, keep: F) -> u64 {
        let mut advised = 0u64;
        for (name, e) in self.index.range(prefix.to_string()..) {
            if !name.starts_with(prefix) {
                break;
            }
            if !keep(name) {
                continue;
            }
            self.map
                .advise_willneed(self.data_offset + e.offset as usize, e.nbytes as usize);
            advised += e.nbytes;
        }
        advised
    }

    /// Total stored bytes across all tensors (checkpoint "Params" size).
    pub fn total_bytes(&self) -> u64 {
        self.index.values().map(|e| e.nbytes).sum()
    }

    /// Sum of stored bytes for tensors whose name passes `pred`.
    pub fn bytes_where<F: Fn(&str) -> bool>(&self, pred: F) -> u64 {
        self.index
            .values()
            .filter(|e| pred(&e.name))
            .map(|e| e.nbytes)
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Writer (test fixtures / tooling — export.py remains the production path)
// ---------------------------------------------------------------------------

/// An owned tensor staged for [`write_rkv`]: raw little-endian payload.
pub struct RkvTensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl RkvTensor {
    pub fn f32(name: &str, shape: Vec<usize>, v: &[f32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(4 * v.len());
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::F32, shape, data }
    }

    pub fn f16_from_f32(name: &str, shape: Vec<usize>, v: &[f32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(2 * v.len());
        for x in v {
            data.extend_from_slice(&crate::util::f32_to_f16(*x).to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::F16, shape, data }
    }

    pub fn i32(name: &str, shape: Vec<usize>, v: &[i32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(4 * v.len());
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::I32, shape, data }
    }

    pub fn u8(name: &str, shape: Vec<usize>, v: Vec<u8>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        Self { name: name.to_string(), dtype: DType::U8, shape, data: v }
    }
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::I8 => 2,
        DType::U8 => 3,
        DType::I32 => 4,
    }
}

const ALIGN: u64 = 64;

fn align_up(n: u64) -> u64 {
    n.div_ceil(ALIGN) * ALIGN
}

/// Write an `.rkv` checkpoint in the exact layout [`RkvFile::open`] reads
/// (64-byte-aligned payloads, version 1).  Used by the synthetic-model
/// test fixtures so the engine paths are exercised without `make
/// artifacts`.
pub fn write_rkv(path: &Path, tensors: &[RkvTensor]) -> Result<()> {
    // index size first: entries are variable-length (name + dims)
    let mut index_size = 0u64;
    for t in tensors {
        index_size += 2 + t.name.len() as u64 + 2 + 4 * t.shape.len() as u64 + 16;
    }
    let data_offset = align_up(20 + index_size);
    // relative, aligned payload offsets
    let mut offsets = Vec::with_capacity(tensors.len());
    let mut cursor = 0u64;
    for t in tensors {
        cursor = align_up(cursor);
        offsets.push(cursor);
        cursor += t.data.len() as u64;
    }
    let mut out: Vec<u8> = Vec::with_capacity((data_offset + cursor) as usize);
    out.extend_from_slice(b"RKV1");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    out.extend_from_slice(&data_offset.to_le_bytes());
    for (t, &off) in tensors.iter().zip(&offsets) {
        out.extend_from_slice(&(t.name.len() as u16).to_le_bytes());
        out.extend_from_slice(t.name.as_bytes());
        out.push(dtype_code(t.dtype));
        out.push(t.shape.len() as u8);
        for &dim in &t.shape {
            out.extend_from_slice(&(dim as u32).to_le_bytes());
        }
        out.extend_from_slice(&off.to_le_bytes());
        out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    }
    out.resize(data_offset as usize, 0);
    for (t, &off) in tensors.iter().zip(&offsets) {
        out.resize((data_offset + off) as usize, 0);
        out.extend_from_slice(&t.data);
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, &out)
        .with_context(|| format!("writing rkv to {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_then_read_round_trips() {
        let dir = std::env::temp_dir().join(format!("rkv-rt-{}", std::process::id()));
        let path = dir.join("t.rkv");
        let tensors = vec![
            RkvTensor::f32("a.mat", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            RkvTensor::f16_from_f32("b.vec", vec![4], &[0.5, -1.0, 2.0, 8.0]),
            RkvTensor::i32("c.assign", vec![3], &[0, 2, 1]),
            RkvTensor::u8("d.sign", vec![1, 2], vec![0xAB, 0x01]),
        ];
        write_rkv(&path, &tensors).unwrap();
        let f = RkvFile::open(&path).unwrap();
        assert_eq!(f.entry("a.mat").unwrap().shape, vec![2, 3]);
        let m = f.mat("a.mat").unwrap();
        assert_eq!(m.to_f32_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = f.vec_f32("b.vec").unwrap();
        assert_eq!(v, vec![0.5, -1.0, 2.0, 8.0]);
        assert_eq!(f.vec_i32("c.assign").unwrap(), vec![0, 2, 1]);
        assert_eq!(f.raw("d.sign").unwrap(), &[0xAB, 0x01]);
        assert_eq!(f.entry("a.mat").unwrap().nbytes, 24);
        // readahead hint walks exactly the prefix's tensors (a no-op for
        // correctness; the byte count is the observable contract)
        assert_eq!(f.advise_prefix("a."), 24);
        assert_eq!(f.advise_prefix(""), f.total_bytes());
        assert_eq!(f.advise_prefix("zzz"), 0);
        assert_eq!(
            f.advise_prefix_where("", |n| n != "a.mat"),
            f.total_bytes() - 24,
            "filtered readahead skips excluded tensors"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
