//! `.rkv` checkpoint reader — mirrors python/compile/export.py exactly.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"RKV1"
//! u32    version (=1)
//! u32    n_tensors
//! u64    data_offset (absolute)
//! index  n_tensors x { u16 name_len, name, u8 dtype, u8 ndim,
//!                      u32 dims[ndim], u64 offset(rel), u64 nbytes }
//! data   64-byte-aligned tensor payloads
//! ```
//!
//! The checkpoint is *untrusted input* (fuzzed in `tests/fuzz_smoke.rs`):
//! every header field is cursor-checked, every entry's payload range is
//! overflow-checked against the file, and the shape-derived element count
//! must equal the stored byte count — so a malformed file is an `Err`
//! from [`RkvFile::open`], never a panic and never an out-of-bounds view
//! in a later accessor.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::io::Mmap;
use crate::tensor::q4::{q4_groups, quantize_q4, quantize_q4_1};
use crate::tensor::{DType, Mat};
use crate::util::cast::{cast_slice, Pod};
use crate::util::f16::f16_to_f32;

#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub offset: u64, // relative to data section
    pub nbytes: u64,
}

impl TensorEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Bounds-checked little-endian reader over the header bytes: truncated
/// or oversized fields surface as `Err`, never slice panics.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow!("rkv index truncated at byte {} (want {n} more)", self.pos))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }
}

/// Dimensions beyond this are corruption, not tensors (export writes ≤2).
const MAX_NDIM: usize = 8;

pub struct RkvFile {
    map: Arc<Mmap>,
    data_offset: usize,
    index: BTreeMap<String, TensorEntry>,
}

impl RkvFile {
    pub fn open(path: &Path) -> Result<Self> {
        let map = Arc::new(Mmap::open(path)?);
        Self::parse(map).with_context(|| format!("parsing rkv {}", path.display()))
    }

    /// Parse an in-memory checkpoint image (fuzzers, tests, future
    /// network transports) through the identical validation path.
    pub fn open_bytes(data: &[u8]) -> Result<Self> {
        Self::parse(Arc::new(Mmap::from_bytes(data)))
    }

    fn parse(map: Arc<Mmap>) -> Result<Self> {
        let b = map.bytes();
        if b.len() < 20 || &b[0..4] != b"RKV1" {
            bail!("not an RKV1 file");
        }
        let mut cur = Cursor { b, pos: 4 };
        let version = cur.u32()?;
        if version != 1 {
            bail!("unsupported rkv version {version}");
        }
        let n = cur.u32()? as usize;
        let data_offset64 = cur.u64()?;
        if data_offset64 > b.len() as u64 {
            bail!("data offset {data_offset64} exceeds file size {}", b.len());
        }
        let data_offset = data_offset64 as usize;
        let mut index = BTreeMap::new();
        for i in 0..n {
            let nl = cur.u16()? as usize;
            let name = std::str::from_utf8(cur.take(nl)?)
                .with_context(|| format!("tensor {i}: name is not UTF-8"))?
                .to_string();
            let dtype = DType::from_code(cur.u8()?)?;
            let ndim = cur.u8()? as usize;
            if ndim > MAX_NDIM {
                bail!("tensor '{name}': implausible rank {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(cur.u32()? as usize);
            }
            let offset = cur.u64()?;
            let nbytes = cur.u64()?;
            // payload window must sit inside the file — checked without
            // u64 wrap-around
            let end = data_offset64
                .checked_add(offset)
                .and_then(|v| v.checked_add(nbytes))
                .ok_or_else(|| anyhow!("tensor '{name}': offset arithmetic overflows"))?;
            if end > b.len() as u64 {
                bail!("tensor '{name}' exceeds file bounds");
            }
            // the shape must account for every stored byte: this is what
            // lets typed views be length-checked instead of trusted
            shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| anyhow!("tensor '{name}': element count overflows"))?;
            // sub-byte dtypes have a packed byte count (and a required
            // rank); `bytes_for` owns that mapping for every dtype
            let expect_bytes = dtype.bytes_for(&shape).ok_or_else(|| {
                anyhow!("tensor '{name}': shape {shape:?} invalid for dtype {dtype:?}")
            })?;
            if expect_bytes != nbytes {
                bail!(
                    "tensor '{name}': shape {shape:?} x {dtype:?} wants {expect_bytes} bytes, \
                     header says {nbytes}"
                );
            }
            index.insert(
                name.clone(),
                TensorEntry { name, dtype, shape, offset, nbytes },
            );
        }
        Ok(Self { map, data_offset, index })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.index.keys().map(|s| s.as_str())
    }

    pub fn entry(&self, name: &str) -> Result<&TensorEntry> {
        self.index
            .get(name)
            .with_context(|| format!("tensor '{name}' not in checkpoint"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Raw bytes of a tensor (zero-copy view into the map).
    pub fn raw(&self, name: &str) -> Result<&[u8]> {
        let e = self.entry(name)?;
        let start = self.data_offset + e.offset as usize;
        // the range was validated against the file at parse time; `get`
        // keeps even a logic error here an Err, not a panic
        self.map
            .bytes()
            .get(start..start + e.nbytes as usize)
            .ok_or_else(|| anyhow!("tensor '{name}': payload range invalid"))
    }

    /// Typed zero-copy view.  Length is derived from (and checked
    /// against) the stored bytes via `util::cast`, so a shape/payload
    /// mismatch can never produce an oversized slice.
    pub fn typed<T: Pod>(&self, name: &str) -> Result<&[T]> {
        cast_slice::<T>(self.raw(name)?).with_context(|| format!("tensor '{name}'"))
    }

    /// Load a 1-D f32 vector (copies; counted by the caller's tracker).
    pub fn vec_f32(&self, name: &str) -> Result<Vec<f32>> {
        let e = self.entry(name)?;
        match e.dtype {
            DType::F32 => Ok(self.typed::<f32>(name)?.to_vec()),
            DType::F16 => Ok(self
                .typed::<u16>(name)?
                .iter()
                .map(|&h| f16_to_f32(h))
                .collect()),
            _ => bail!("tensor '{name}' is not float"),
        }
    }

    pub fn vec_i32(&self, name: &str) -> Result<Vec<i32>> {
        let e = self.entry(name)?;
        match e.dtype {
            DType::I32 => Ok(self.typed::<i32>(name)?.to_vec()),
            _ => bail!("tensor '{name}' is not i32"),
        }
    }

    /// Load a 2-D matrix in its storage precision.  For `I8` tensors the
    /// sibling `<name>.scale` vector is loaded alongside; for `Q4`/`Q4_1`
    /// the per-group f16 siblings `<name>.scale` (and `<name>.min`) are
    /// loaded and shape-validated against the group count.
    pub fn mat(&self, name: &str) -> Result<Mat> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            bail!("tensor '{name}' is {}-D, want 2-D", e.shape.len());
        }
        let (rows, cols) = (e.shape[0], e.shape[1]);
        Ok(match e.dtype {
            DType::F32 => Mat::F32 { rows, cols, data: self.typed::<f32>(name)?.to_vec() },
            DType::F16 => Mat::F16 { rows, cols, data: self.typed::<u16>(name)?.to_vec() },
            DType::I8 => {
                let scale = self.vec_f32(&format!("{name}.scale"))?;
                Mat::I8 { rows, cols, data: self.typed::<i8>(name)?.to_vec(), scale }
            }
            DType::Q4 => {
                let scale = self.q4_param(name, "scale", rows, cols)?;
                Mat::Q4 { rows, cols, data: self.raw(name)?.to_vec(), scale }
            }
            DType::Q41 => {
                let scale = self.q4_param(name, "scale", rows, cols)?;
                let min = self.q4_param(name, "min", rows, cols)?;
                Mat::Q41 { rows, cols, data: self.raw(name)?.to_vec(), scale, min }
            }
            other => bail!("tensor '{name}': dtype {:?} is not a matrix type", other),
        })
    }

    /// Load a per-group quantization parameter sibling (`<base>.<suffix>`)
    /// of a Q4/Q4_1 matrix: must be f16 with shape `[rows, groups(cols)]`
    /// so the fused kernels can index it without bounds hazards.
    fn q4_param(&self, base: &str, suffix: &str, rows: usize, cols: usize) -> Result<Vec<u16>> {
        let name = format!("{base}.{suffix}");
        let e = self.entry(&name)?;
        let ng = q4_groups(cols);
        if e.dtype != DType::F16 || e.shape != [rows, ng] {
            bail!(
                "tensor '{name}': quantized sibling must be f16 [{rows}, {ng}], \
                 got {:?} {:?}",
                e.dtype,
                e.shape
            );
        }
        Ok(self.typed::<u16>(&name)?.to_vec())
    }

    /// Zero-copy row view of an f16 matrix (embedding cache fast path).
    pub fn row_f16(&self, name: &str, row: usize) -> Result<&[u16]> {
        let e = self.entry(name)?;
        let cols = *e.shape.last().unwrap_or(&0);
        if cols == 0 {
            bail!("tensor '{name}': zero-width rows");
        }
        let all = self.typed::<u16>(name)?;
        let rows = all.len() / cols;
        if row >= rows {
            bail!("tensor '{name}': row {row} out of range (rows = {rows})");
        }
        Ok(&all[row * cols..(row + 1) * cols])
    }

    /// Kick off kernel readahead for every tensor whose name starts with
    /// `prefix` (see [`Mmap::advise_willneed`]); returns the stored bytes
    /// advised.  The layerwise prefetcher calls this before decoding a
    /// block so the disk streams the block's tensors ahead of the typed
    /// copies instead of faulting tensor by tensor.
    pub fn advise_prefix(&self, prefix: &str) -> u64 {
        self.advise_prefix_where(prefix, |_| true)
    }

    /// [`RkvFile::advise_prefix`] restricted to tensors `keep` accepts.
    /// Readahead must match what the caller will actually decode: the
    /// layerwise prefetcher skips the sparse-managed FFN matrices (their
    /// rows stream individually per §3.2) and the resident predictor
    /// tensors, otherwise MADV_WILLNEED would drag the block's largest
    /// tensors off disk for nothing.
    pub fn advise_prefix_where<F: Fn(&str) -> bool>(&self, prefix: &str, keep: F) -> u64 {
        let mut advised = 0u64;
        for (name, e) in self.index.range(prefix.to_string()..) {
            if !name.starts_with(prefix) {
                break;
            }
            if !keep(name) {
                continue;
            }
            self.map
                .advise_willneed(self.data_offset + e.offset as usize, e.nbytes as usize);
            advised += e.nbytes;
        }
        advised
    }

    /// Total stored bytes across all tensors (checkpoint "Params" size).
    pub fn total_bytes(&self) -> u64 {
        self.index.values().map(|e| e.nbytes).sum()
    }

    /// Sum of stored bytes for tensors whose name passes `pred`.
    pub fn bytes_where<F: Fn(&str) -> bool>(&self, pred: F) -> u64 {
        self.index
            .values()
            .filter(|e| pred(&e.name))
            .map(|e| e.nbytes)
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Writer (test fixtures / tooling — export.py remains the production path)
// ---------------------------------------------------------------------------

/// An owned tensor staged for [`write_rkv`]: raw little-endian payload.
pub struct RkvTensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl RkvTensor {
    pub fn f32(name: &str, shape: Vec<usize>, v: &[f32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(4 * v.len());
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::F32, shape, data }
    }

    pub fn f16_from_f32(name: &str, shape: Vec<usize>, v: &[f32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(2 * v.len());
        for x in v {
            data.extend_from_slice(&crate::util::f32_to_f16(*x).to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::F16, shape, data }
    }

    pub fn i32(name: &str, shape: Vec<usize>, v: &[i32]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(4 * v.len());
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::I32, shape, data }
    }

    pub fn u8(name: &str, shape: Vec<usize>, v: Vec<u8>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), v.len());
        Self { name: name.to_string(), dtype: DType::U8, shape, data: v }
    }

    /// Stage raw f16 *bits* (already-rounded quantization parameters —
    /// re-rounding through f32 would not be a bit-level no-op for NaN
    /// payloads, so siblings are written verbatim).
    pub fn f16_bits(name: &str, shape: Vec<usize>, bits: &[u16]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), bits.len());
        let mut data = Vec::with_capacity(2 * bits.len());
        for b in bits {
            data.extend_from_slice(&b.to_le_bytes());
        }
        Self { name: name.to_string(), dtype: DType::F16, shape, data }
    }

    /// Quantize a row-major f32 matrix to Q4 and stage the packed tensor
    /// plus its `.scale` sibling (append both to the tensor list).
    pub fn q4_from_f32(name: &str, rows: usize, cols: usize, v: &[f32]) -> Vec<Self> {
        let (packed, scale) = quantize_q4(rows, cols, v);
        vec![
            Self {
                name: name.to_string(),
                dtype: DType::Q4,
                shape: vec![rows, cols],
                data: packed,
            },
            Self::f16_bits(&format!("{name}.scale"), vec![rows, q4_groups(cols)], &scale),
        ]
    }

    /// Quantize a row-major f32 matrix to Q4_1 and stage the packed
    /// tensor plus its `.scale` and `.min` siblings.
    pub fn q4_1_from_f32(name: &str, rows: usize, cols: usize, v: &[f32]) -> Vec<Self> {
        let (packed, scale, min) = quantize_q4_1(rows, cols, v);
        let ng = q4_groups(cols);
        vec![
            Self {
                name: name.to_string(),
                dtype: DType::Q41,
                shape: vec![rows, cols],
                data: packed,
            },
            Self::f16_bits(&format!("{name}.scale"), vec![rows, ng], &scale),
            Self::f16_bits(&format!("{name}.min"), vec![rows, ng], &min),
        ]
    }

    /// Stage an arbitrary pre-packed payload under an explicit dtype —
    /// the malformed-image tests use this to write images the validated
    /// constructors refuse to produce.
    pub fn raw(name: &str, dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Self {
        Self { name: name.to_string(), dtype, shape, data }
    }
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::I8 => 2,
        DType::U8 => 3,
        DType::I32 => 4,
        DType::Q4 => 5,
        DType::Q41 => 6,
    }
}

const ALIGN: u64 = 64;

fn align_up(n: u64) -> u64 {
    n.div_ceil(ALIGN) * ALIGN
}

/// Serialize tensors to the exact `.rkv` image [`RkvFile::open`] reads
/// (64-byte-aligned payloads, version 1).  Split from [`write_rkv`] so
/// the fuzz seeds and in-memory round-trip tests share the writer.
pub fn rkv_bytes(tensors: &[RkvTensor]) -> Vec<u8> {
    // index size first: entries are variable-length (name + dims)
    let mut index_size = 0u64;
    for t in tensors {
        assert!(t.name.len() <= u16::MAX as usize, "tensor name too long");
        index_size += 2 + t.name.len() as u64 + 2 + 4 * t.shape.len() as u64 + 16;
    }
    let data_offset = align_up(20 + index_size);
    // relative, aligned payload offsets
    let mut offsets = Vec::with_capacity(tensors.len());
    let mut cursor = 0u64;
    for t in tensors {
        cursor = align_up(cursor);
        offsets.push(cursor);
        cursor += t.data.len() as u64;
    }
    let mut out: Vec<u8> = Vec::with_capacity((data_offset + cursor) as usize);
    out.extend_from_slice(b"RKV1");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    out.extend_from_slice(&data_offset.to_le_bytes());
    for (t, &off) in tensors.iter().zip(&offsets) {
        out.extend_from_slice(&(t.name.len() as u16).to_le_bytes());
        out.extend_from_slice(t.name.as_bytes());
        out.push(dtype_code(t.dtype));
        out.push(t.shape.len() as u8);
        for &dim in &t.shape {
            out.extend_from_slice(&(dim as u32).to_le_bytes());
        }
        out.extend_from_slice(&off.to_le_bytes());
        out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    }
    out.resize(data_offset as usize, 0);
    for (t, &off) in tensors.iter().zip(&offsets) {
        out.resize((data_offset + off) as usize, 0);
        out.extend_from_slice(&t.data);
    }
    out
}

/// Write an `.rkv` checkpoint in the exact layout [`RkvFile::open`] reads.
/// Used by the synthetic-model test fixtures so the engine paths are
/// exercised without `make artifacts`.
pub fn write_rkv(path: &Path, tensors: &[RkvTensor]) -> Result<()> {
    let out = rkv_bytes(tensors);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, &out)
        .with_context(|| format!("writing rkv to {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tensors() -> Vec<RkvTensor> {
        vec![
            RkvTensor::f32("a.mat", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            RkvTensor::f16_from_f32("b.vec", vec![4], &[0.5, -1.0, 2.0, 8.0]),
            RkvTensor::i32("c.assign", vec![3], &[0, 2, 1]),
            RkvTensor::u8("d.sign", vec![1, 2], vec![0xAB, 0x01]),
        ]
    }

    #[test]
    fn write_then_read_round_trips() {
        let dir = std::env::temp_dir().join(format!("rkv-rt-{}", std::process::id()));
        let path = dir.join("t.rkv");
        let tensors = sample_tensors();
        write_rkv(&path, &tensors).unwrap();
        let f = RkvFile::open(&path).unwrap();
        assert_eq!(f.entry("a.mat").unwrap().shape, vec![2, 3]);
        let m = f.mat("a.mat").unwrap();
        assert_eq!(m.to_f32_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = f.vec_f32("b.vec").unwrap();
        assert_eq!(v, vec![0.5, -1.0, 2.0, 8.0]);
        assert_eq!(f.vec_i32("c.assign").unwrap(), vec![0, 2, 1]);
        assert_eq!(f.raw("d.sign").unwrap(), &[0xAB, 0x01]);
        assert_eq!(f.entry("a.mat").unwrap().nbytes, 24);
        // readahead hint walks exactly the prefix's tensors (a no-op for
        // correctness; the byte count is the observable contract)
        assert_eq!(f.advise_prefix("a."), 24);
        assert_eq!(f.advise_prefix(""), f.total_bytes());
        assert_eq!(f.advise_prefix("zzz"), 0);
        assert_eq!(
            f.advise_prefix_where("", |n| n != "a.mat"),
            f.total_bytes() - 24,
            "filtered readahead skips excluded tensors"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_bytes_matches_open() {
        let bytes = rkv_bytes(&sample_tensors());
        let f = RkvFile::open_bytes(&bytes).unwrap();
        assert_eq!(f.mat("a.mat").unwrap().to_f32_vec(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(f.names().count(), 4);
    }

    #[test]
    fn row_f16_bounds_checked() {
        let bytes = rkv_bytes(&[RkvTensor::f16_from_f32(
            "emb",
            vec![2, 3],
            &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
        )]);
        let f = RkvFile::open_bytes(&bytes).unwrap();
        assert_eq!(f.row_f16("emb", 1).unwrap().len(), 3);
        assert!(f.row_f16("emb", 2).is_err(), "row past the end must Err");
    }

    #[test]
    fn q4_write_then_read_round_trips_bitwise() {
        // odd cols (17) exercises both a ragged group and a pad nibble
        for (rows, cols) in [(3usize, 32usize), (2, 17), (4, 40)] {
            let data: Vec<f32> = (0..rows * cols)
                .map(|i| ((i * 37 + 11) % 23) as f32 * 0.17 - 1.9)
                .collect();
            let mut tensors = RkvTensor::q4_from_f32("w", rows, cols, &data);
            tensors.extend(RkvTensor::q4_1_from_f32("v", rows, cols, &data));
            let f = RkvFile::open_bytes(&rkv_bytes(&tensors)).unwrap();
            assert_eq!(f.entry("w").unwrap().dtype, DType::Q4);
            assert_eq!(f.entry("w").unwrap().shape, vec![rows, cols]);
            // reader reconstructs exactly what the in-memory quantizer
            // produces — payload, scale bits, and decoded values
            let m = f.mat("w").unwrap();
            assert_eq!(m, Mat::quantize_q4_mat(rows, cols, &data));
            let m1 = f.mat("v").unwrap();
            assert_eq!(m1, Mat::quantize_q4_1_mat(rows, cols, &data));
        }
    }

    #[test]
    fn q4_payload_size_mismatch_rejected_at_open() {
        // a [2, 5] Q4 tensor packs to 2 * ceil(5/2) = 6 bytes; claiming
        // 5 (as numel/2 truncation would) must fail at open
        let t = RkvTensor::raw("w", DType::Q4, vec![2, 5], vec![0u8; 5]);
        assert!(RkvFile::open_bytes(&rkv_bytes(&[t])).is_err());
    }

    #[test]
    fn q4_non_matrix_rank_rejected_at_open() {
        // sub-byte packing is only defined for rank 2 — a 1-D Q4 tensor
        // has no well-defined packed size and must be rejected outright
        let t = RkvTensor::raw("w", DType::Q4, vec![6], vec![0u8; 3]);
        assert!(RkvFile::open_bytes(&rkv_bytes(&[t])).is_err());
    }

    #[test]
    fn q4_bad_sibling_rejected_by_mat() {
        let data = vec![0.5f32; 2 * 32];
        // missing .scale sibling
        let main = RkvTensor::q4_from_f32("w", 2, 32, &data).remove(0);
        let f = RkvFile::open_bytes(&rkv_bytes(&[main])).unwrap();
        assert!(f.mat("w").is_err(), "missing .scale must Err, not panic");
        // .scale present but wrong shape (one group short)
        let wide = vec![0.5f32; 2 * 64];
        let mut tensors = RkvTensor::q4_from_f32("w", 2, 64, &wide);
        tensors[1] = RkvTensor::f16_bits("w.scale", vec![2, 1], &[0x3C00, 0x3C00]);
        let f = RkvFile::open_bytes(&rkv_bytes(&tensors)).unwrap();
        assert!(f.mat("w").is_err(), "short .scale must Err, not over-read");
        // .scale present but wrong dtype
        let mut tensors = RkvTensor::q4_from_f32("w", 2, 32, &data);
        tensors[1] = RkvTensor::f32("w.scale", vec![2, 1], &[1.0, 1.0]);
        let f = RkvFile::open_bytes(&rkv_bytes(&tensors)).unwrap();
        assert!(f.mat("w").is_err(), "f32 .scale must be rejected");
    }

    #[test]
    fn shape_payload_mismatch_rejected_at_open() {
        // shape says 2x3 f32 (24 bytes) but the header claims only 12
        // stored bytes: accepted by the old parser, the root of the
        // RowView out-of-bounds hazard — must now fail at open.
        let mut bytes = rkv_bytes(&[RkvTensor::f32("m", vec![2, 3], &[0.0; 6])]);
        // entry layout after 20-byte header: name_len(2) + "m"(1) +
        // dtype(1) + ndim(1) + dims(8) + offset(8) -> nbytes at +21
        let nbytes_pos = 20 + 2 + 1 + 1 + 1 + 8 + 8;
        bytes[nbytes_pos..nbytes_pos + 8].copy_from_slice(&12u64.to_le_bytes());
        assert!(RkvFile::open_bytes(&bytes).is_err());
    }
}
