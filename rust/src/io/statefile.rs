//! Binary save/load for `RwkvState` snapshots (the prefix-state cache's
//! persistence format).
//!
//! Because RWKV's recurrent state is O(1) in sequence length, a fully
//! processed prompt prefix persists as one fixed-size snapshot — a few
//! MB regardless of how long the prefix was.  A statefile holds any
//! number of `(token-prefix, state)` entries so `engine::state_cache`
//! can survive process restarts (`--state-file`).
//!
//! Layout (little-endian):
//! ```text
//! magic  b"RWST"
//! u32    version (=2)
//! u16    tag_len, tag bytes   (model fingerprint, writer-chosen)
//! u32    n_entries
//! entry  n_entries x {
//!          u32 prefix_len, u32 prefix[prefix_len],
//!          u32 layers, u32 dim, u32 heads, u32 head_size,
//!          per layer: f32 att_x[dim], f32 wkv[heads*head_size^2],
//!                     f32 ffn_x[dim]
//!        }
//! u32    FNV-1a checksum over every preceding byte
//! ```
//!
//! The trailing checksum (version 2) catches SILENT damage: a statefile
//! with a flipped payload byte would otherwise load cleanly and plant a
//! corrupted state on a live prefix, breaking warm==cold bit-identity in
//! a way no shape check can see.  A mismatch fails the load; the cache
//! then just cold-starts (losing warmth, never correctness).
//!
//! The tag exists because shape alone cannot tell two checkpoints apart:
//! a fine-tuned model has identical dims but different weights, and its
//! states are NOT interchangeable.  The writer stamps whatever identity
//! it has (the coordinator uses model name + checkpoint size + mtime);
//! the reader returns it for the caller to compare.
//!
//! The payload is f32 (`RwkvState::ELEM_BYTES` — the element width is
//! defined once, in `engine::state`), so a save/load round trip is
//! bit-exact: a restored snapshot decodes the same stream a live one
//! would (`tests/state_cache_equivalence.rs`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::engine::state::RwkvState;

pub const STATEFILE_MAGIC: &[u8; 4] = b"RWST";
pub const STATEFILE_VERSION: u32 = 2;

/// FNV-1a over the statefile body — the trailing integrity word.  Any
/// single-byte change alters the digest (the XOR step injects a distinct
/// value and every later step is a bijection), so bit-flip corruption is
/// always detected; this is an integrity check, not an authenticity one.
pub fn statefile_checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize `(token-prefix, state)` entries to the checksummed
/// statefile image under a writer-chosen model `tag`.  Split from
/// [`write_statefile`] so fuzz seeds and in-memory round trips share the
/// writer.
pub fn statefile_bytes(tag: &str, entries: &[(&[u32], &RwkvState)]) -> Result<Vec<u8>> {
    bail_on_long_tag(tag)?;
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(STATEFILE_MAGIC);
    put_u32(&mut out, STATEFILE_VERSION);
    out.extend_from_slice(&(tag.len() as u16).to_le_bytes());
    out.extend_from_slice(tag.as_bytes());
    put_u32(&mut out, entries.len() as u32);
    for (prefix, st) in entries {
        put_u32(&mut out, prefix.len() as u32);
        for &t in *prefix {
            put_u32(&mut out, t);
        }
        put_u32(&mut out, st.layers() as u32);
        put_u32(&mut out, st.dim as u32);
        put_u32(&mut out, st.heads as u32);
        put_u32(&mut out, st.head_size as u32);
        for l in 0..st.layers() {
            put_f32s(&mut out, &st.att_x[l]);
            put_f32s(&mut out, &st.wkv[l]);
            put_f32s(&mut out, &st.ffn_x[l]);
        }
    }
    let digest = statefile_checksum(&out);
    put_u32(&mut out, digest);
    Ok(out)
}

/// Write `(token-prefix, state)` entries to `path` under a writer-chosen
/// model `tag` (atomic enough for the cache's shutdown save: written as
/// one buffer, one `fs::write`).
pub fn write_statefile(path: &Path, tag: &str, entries: &[(&[u32], &RwkvState)]) -> Result<()> {
    let out = statefile_bytes(tag, entries)?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, &out).with_context(|| format!("writing statefile {}", path.display()))
}

fn bail_on_long_tag(tag: &str) -> Result<()> {
    if tag.len() > u16::MAX as usize {
        bail!("statefile tag too long ({} bytes)", tag.len());
    }
    Ok(())
}

/// Bounds-checked little-endian cursor over the statefile bytes.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.b.len().saturating_sub(self.pos)
    }

    fn u16(&mut self) -> Result<u16> {
        if self.pos + 2 > self.b.len() {
            bail!("statefile truncated at byte {}", self.pos);
        }
        let v = u16::from_le_bytes(self.b[self.pos..self.pos + 2].try_into().unwrap());
        self.pos += 2;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32> {
        if self.pos + 4 > self.b.len() {
            bail!("statefile truncated at byte {}", self.pos);
        }
        let v = u32::from_le_bytes(self.b[self.pos..self.pos + 4].try_into().unwrap());
        self.pos += 4;
        Ok(v)
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        // `n` is derived from attacker-controlled shape fields: both the
        // byte count and the end position are overflow-checked
        let end = n
            .checked_mul(RwkvState::ELEM_BYTES)
            .and_then(|bytes| self.pos.checked_add(bytes));
        let Some(end) = end.filter(|&e| e <= self.b.len()) else {
            bail!("statefile truncated at byte {}", self.pos);
        };
        let bytes = end - self.pos;
        let out = self.b[self.pos..self.pos + bytes]
            .chunks_exact(RwkvState::ELEM_BYTES)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.pos += bytes;
        Ok(out)
    }
}

/// Read a statefile: the writer's model tag plus every
/// `(token-prefix, state)` entry, in file order.
pub fn read_statefile(path: &Path) -> Result<(String, Vec<(Vec<u32>, RwkvState)>)> {
    let all = std::fs::read(path).with_context(|| format!("reading statefile {}", path.display()))?;
    read_statefile_bytes(&all, &path.display().to_string())
}

/// Parse an in-memory statefile image (`origin` labels errors).  The
/// fuzzers drive this directly; [`read_statefile`] is a thin file
/// wrapper.
pub fn read_statefile_bytes(
    all: &[u8],
    origin: &str,
) -> Result<(String, Vec<(Vec<u32>, RwkvState)>)> {
    if all.len() < 12 || &all[0..4] != STATEFILE_MAGIC {
        bail!("{origin}: not a statefile (bad magic)");
    }
    // integrity first: the trailing word must match a digest of the body,
    // so truncation and silent bit-flips are rejected before any parsing
    let (bytes, tail) = all.split_at(all.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().unwrap());
    let computed = statefile_checksum(bytes);
    if stored != computed {
        bail!(
            "{origin}: statefile checksum mismatch (stored {stored:#010x}, computed \
             {computed:#010x}) — truncated or corrupt"
        );
    }
    let mut cur = Cursor { b: bytes, pos: 4 };
    let version = cur.u32()?;
    if version != STATEFILE_VERSION {
        bail!("{origin}: unsupported statefile version {version}");
    }
    let tag_len = cur.u16()? as usize;
    if tag_len > cur.remaining() {
        bail!("statefile tag exceeds file size");
    }
    let tag = std::str::from_utf8(&bytes[cur.pos..cur.pos + tag_len])
        .context("statefile tag is not UTF-8")?
        .to_string();
    cur.pos += tag_len;
    let n = cur.u32()? as usize;
    // every count below is attacker-controlled (a corrupt/truncated file):
    // bound allocations by the bytes actually present, so a bad header
    // returns Err instead of aborting on a multi-GB reservation
    let mut out = Vec::new();
    for i in 0..n {
        let plen = cur.u32()? as usize;
        if plen > cur.remaining() / 4 {
            bail!("statefile entry {i}: prefix length {plen} exceeds file size");
        }
        let mut prefix = Vec::with_capacity(plen);
        for _ in 0..plen {
            prefix.push(cur.u32()?);
        }
        let layers = cur.u32()? as usize;
        let dim = cur.u32()? as usize;
        let heads = cur.u32()? as usize;
        let head_size = cur.u32()? as usize;
        // u128 compare: a crafted heads/head_size pair could overflow the
        // usize product before the payload bound gets a chance to reject
        if heads as u128 * head_size as u128 != dim as u128 || dim == 0 || layers == 0 {
            bail!(
                "statefile entry {i}: inconsistent shape ({layers}L, dim {dim}, {heads}x{head_size})"
            );
        }
        // u128: dims are u32-sized, so per-layer element math cannot be
        // trusted to fit u64 before validation
        let per_layer = dim as u128 * 2 + heads as u128 * head_size as u128 * head_size as u128;
        let payload = per_layer * layers as u128 * RwkvState::ELEM_BYTES as u128;
        if payload > cur.remaining() as u128 {
            bail!("statefile entry {i}: payload exceeds file size");
        }
        let mut st = RwkvState::zero(layers, dim, heads, head_size);
        for l in 0..layers {
            st.att_x[l] = cur.f32s(dim)?;
            st.wkv[l] = cur.f32s(heads * head_size * head_size)?;
            st.ffn_x[l] = cur.f32s(dim)?;
        }
        out.push((prefix, st));
    }
    Ok((tag, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_state(seed: f32) -> RwkvState {
        let mut st = RwkvState::zero(2, 8, 2, 4);
        let vecs = st.att_x.iter_mut().chain(st.wkv.iter_mut()).chain(st.ffn_x.iter_mut());
        for (i, v) in vecs.enumerate() {
            for (j, x) in v.iter_mut().enumerate() {
                *x = seed + i as f32 * 0.25 + j as f32 * 0.0625;
            }
        }
        st
    }

    #[test]
    fn round_trips_bit_exact_with_tag() {
        let dir = std::env::temp_dir().join(format!("rwst-rt-{}", std::process::id()));
        let path = dir.join("cache.rwst");
        let a = filled_state(1.0);
        let b = filled_state(-3.5);
        write_statefile(&path, "model-x:1234:99", &[(&[2, 5, 9], &a), (&[2, 7], &b)]).unwrap();
        let (tag, back) = read_statefile(&path).unwrap();
        assert_eq!(tag, "model-x:1234:99");
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, vec![2, 5, 9]);
        assert_eq!(back[1].0, vec![2, 7]);
        assert!(back[0].1.bitwise_eq(&a));
        assert!(back[1].1.bitwise_eq(&b));
        // an empty tag is legal (unfingerprinted writers)
        write_statefile(&path, "", &[(&[4], &a)]).unwrap();
        let (tag, back) = read_statefile(&path).unwrap();
        assert_eq!(tag, "");
        assert_eq!(back.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join(format!("rwst-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.rwst");
        std::fs::write(&bad, b"NOPE....").unwrap();
        assert!(read_statefile(&bad).is_err());
        // valid header, truncated payload
        let path = dir.join("trunc.rwst");
        let st = filled_state(0.5);
        write_statefile(&path, "t", &[(&[2, 3], &st)]).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(read_statefile(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Corrupt counts must produce an `Err`, never a huge allocation: the
    /// reader bounds every count by the bytes actually in the file.
    /// Every crafted file carries a VALID checksum so the count-bounding
    /// logic itself is what rejects it, not the integrity word.
    #[test]
    fn rejects_oversized_counts_without_allocating() {
        let dir = std::env::temp_dir().join(format!("rwst-huge-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sealed = |mut b: Vec<u8>| {
            let digest = statefile_checksum(&b);
            b.extend_from_slice(&digest.to_le_bytes());
            b
        };
        let mut header = Vec::new();
        header.extend_from_slice(STATEFILE_MAGIC);
        header.extend_from_slice(&STATEFILE_VERSION.to_le_bytes());
        header.extend_from_slice(&0u16.to_le_bytes()); // empty tag
        // n_entries = u32::MAX with no entry bytes behind it
        let p1 = dir.join("entries.rwst");
        let mut b = header.clone();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p1, sealed(b)).unwrap();
        assert!(read_statefile(&p1).is_err());
        // one entry claiming a u32::MAX-token prefix
        let p2 = dir.join("prefix.rwst");
        let mut b = header.clone();
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&p2, sealed(b)).unwrap();
        assert!(read_statefile(&p2).is_err());
        // one entry whose shape implies a payload far beyond the file
        let p3 = dir.join("payload.rwst");
        let mut b = header;
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes()); // empty prefix
        for v in [1u32, 1 << 30, 1 << 15, 1 << 15] {
            // layers, dim, heads, head_size (heads*head_size == dim)
            b.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&p3, sealed(b)).unwrap();
        assert!(read_statefile(&p3).is_err());
        // a tag length pointing past the end of the file
        let p4 = dir.join("tag.rwst");
        let mut b = Vec::new();
        b.extend_from_slice(STATEFILE_MAGIC);
        b.extend_from_slice(&STATEFILE_VERSION.to_le_bytes());
        b.extend_from_slice(&u16::MAX.to_le_bytes());
        std::fs::write(&p4, sealed(b)).unwrap();
        assert!(read_statefile(&p4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Version 2's trailing FNV word: any single flipped byte anywhere in
    /// the file fails the load — silent corruption cannot plant states.
    #[test]
    fn checksum_rejects_any_single_byte_flip() {
        let dir = std::env::temp_dir().join(format!("rwst-sum-{}", std::process::id()));
        let path = dir.join("cache.rwst");
        let st = filled_state(2.0);
        write_statefile(&path, "m:1:2", &[(&[2, 5], &st)]).unwrap();
        let clean = std::fs::read(&path).unwrap();
        assert!(read_statefile(&path).is_ok());
        // probe a spread of offsets: header, tag, counts, payload, digest
        for off in [0usize, 5, 9, 14, clean.len() / 2, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[off] = !bad[off];
            std::fs::write(&path, &bad).unwrap();
            let err = read_statefile(&path);
            assert!(err.is_err(), "flip at byte {off} must fail the load");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
