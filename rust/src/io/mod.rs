//! Checkpoint + manifest I/O (substrate S14).

pub mod manifest;
pub mod mmap;
pub mod rkv;

pub use manifest::Manifest;
pub use mmap::Mmap;
pub use rkv::{write_rkv, RkvFile, RkvTensor, TensorEntry};
