//! Checkpoint + manifest I/O (substrate S14).

pub mod manifest;
pub mod mmap;
pub mod rkv;
pub mod statefile;

pub use manifest::Manifest;
pub use mmap::Mmap;
pub use rkv::{write_rkv, RkvFile, RkvTensor, TensorEntry};
pub use statefile::{read_statefile, write_statefile};
