//! Checkpoint + manifest I/O (substrate S14).

pub mod manifest;
pub mod mmap;
pub mod rkv;
pub mod statefile;

pub use manifest::Manifest;
pub use mmap::Mmap;
pub use rkv::{rkv_bytes, write_rkv, RkvFile, RkvTensor, TensorEntry};
pub use statefile::{read_statefile, read_statefile_bytes, statefile_bytes, write_statefile};
