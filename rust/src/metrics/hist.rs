//! Lock-free log-bucketed latency histograms (HDR-style).
//!
//! [`Registry::observe`](super::Registry::observe) used to push every
//! sample into an unbounded `Vec<f64>` — a memory leak on a long-running
//! server and no quantiles without sorting.  A [`Histogram`] replaces
//! that with a FIXED array of atomic bucket counters over a logarithmic
//! value grid: recording is a handful of relaxed atomic increments (no
//! lock, no allocation — safe on the round hot path), and p50/p90/p99/max
//! read back from the bucket counts at scrape time.
//!
//! Bucket layout (values are integer nanoseconds): the first
//! `2^SUB_BITS` buckets are exact (one value each); past that, each
//! power-of-two octave is split into `2^SUB_BITS` equal sub-buckets, so
//! every bucket's width is at most `2^-SUB_BITS` (~3.1%) of its value.
//! Quantile estimates therefore sit within ONE bucket width of the exact
//! sorted-sample answer (`tests/observability.rs` checks this against
//! [`crate::util::percentile`]).  Values past the top octave saturate
//! into the last bucket instead of indexing out of bounds.
//!
//! Merging two histograms is per-bucket addition, which makes it
//! associative and commutative — shard-local histograms can be combined
//! in any order.

use crate::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per power-of-two octave (as a bit count): 32 sub-buckets,
/// so bucket width <= 1/32 (~3.1%) of the bucket's lower bound.
const SUB_BITS: u32 = 5;
const SUB: u64 = 1 << SUB_BITS;
/// Octaves above the exact linear range: nanosecond values up to
/// 2^63.. (~292 years) land in a real bucket; beyond saturates.
const OCTAVES: usize = (64 - SUB_BITS) as usize;
/// Total bucket count: the exact linear range plus every octave.
pub const NUM_BUCKETS: usize = SUB as usize + OCTAVES * SUB as usize;

/// Bucket index for a nanosecond value (total order, saturating at the
/// top bucket).
#[inline]
fn bucket_index(nanos: u64) -> usize {
    if nanos < SUB {
        return nanos as usize;
    }
    let msb = 63 - nanos.leading_zeros();
    let k = (msb - SUB_BITS) as u64;
    let sub = (nanos >> k) - SUB;
    ((SUB + k * SUB + sub) as usize).min(NUM_BUCKETS - 1)
}

/// Exclusive upper bound of bucket `i`, in nanoseconds (as f64: the top
/// octaves exceed u64).
fn bucket_upper_nanos(i: usize) -> f64 {
    if i < SUB as usize {
        return (i + 1) as f64;
    }
    let k = (i - SUB as usize) / SUB as usize;
    let sub = ((i - SUB as usize) % SUB as usize) as u64;
    // k <= OCTAVES - 1 = 58, so the shift is exact in u64 and f64
    (SUB + sub + 1) as f64 * (1u64 << k) as f64
}

/// Width of bucket `i` in nanoseconds.
fn bucket_width_nanos(i: usize) -> f64 {
    if i < SUB as usize {
        1.0
    } else {
        let k = (i - SUB as usize) / SUB as usize;
        (1u64 << k) as f64
    }
}

fn secs_to_nanos(seconds: f64) -> u64 {
    if !seconds.is_finite() || seconds <= 0.0 {
        return 0;
    }
    let n = seconds * 1e9;
    if n >= u64::MAX as f64 {
        u64::MAX
    } else {
        n as u64
    }
}

/// `fetch_max` spelled as a CAS loop so the loom shim can model it.
fn atomic_max(cell: &AtomicU64, value: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while value > cur {
        match cell.compare_exchange_weak(cur, value, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Lock-free log-bucketed histogram of second-valued observations.
/// Recording costs three relaxed atomic RMW ops and never allocates;
/// the memory footprint is fixed (`NUM_BUCKETS` + 2 counters) however
/// many samples arrive — the long-running-server fix for the old
/// unbounded sample vectors.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    sum_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

// manual (not derived) so the loom shim's `AtomicU64`, which has no
// `Default`, still compiles
impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_nanos: AtomicU64::new(0),
            max_nanos: AtomicU64::new(0),
        }
    }

    /// Record one observation in seconds (negative/NaN clamp to 0).
    pub fn record(&self, seconds: f64) {
        self.record_nanos(secs_to_nanos(seconds));
    }

    pub fn record_nanos(&self, nanos: u64) {
        self.buckets[bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        atomic_max(&self.max_nanos, nanos);
    }

    /// Fold `other`'s counts into `self` (per-bucket addition, so merging
    /// is associative and commutative across any shard order).
    pub fn merge_from(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = src.load(Ordering::Relaxed);
            if n > 0 {
                dst.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.sum_nanos.fetch_add(other.sum_nanos.load(Ordering::Relaxed), Ordering::Relaxed);
        atomic_max(&self.max_nanos, other.max_nanos.load(Ordering::Relaxed));
    }

    /// Consistent-enough point-in-time copy for quantiles and export
    /// (bucket loads are relaxed; concurrent writers may land between
    /// loads, which only skews a live scrape by in-flight samples).
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        HistSnapshot {
            counts,
            count,
            sum_secs: self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            max_secs: self.max_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    /// `[lower, upper)` bounds in seconds of the bucket `seconds` lands
    /// in — the quantile error bound the tests assert against.
    pub fn bucket_bounds_secs(seconds: f64) -> (f64, f64) {
        let i = bucket_index(secs_to_nanos(seconds));
        let hi = bucket_upper_nanos(i);
        ((hi - bucket_width_nanos(i)) / 1e9, hi / 1e9)
    }
}

/// Point-in-time bucket counts plus derived statistics.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Exact sum of all observations in seconds (summed as integer
    /// nanoseconds, so the mean is not bucket-quantized).
    pub sum_secs: f64,
    /// Largest single observation in seconds (exact, not bucketized).
    pub max_secs: f64,
}

impl HistSnapshot {
    /// Mean in seconds (0 when empty).
    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_secs / self.count as f64
        }
    }

    /// Percentile estimate in seconds, `p` in [0, 100].  Uses the same
    /// nearest-rank convention as [`crate::util::percentile`] and returns
    /// the containing bucket's upper bound, so the estimate is within one
    /// bucket width above the exact sorted-sample value.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_upper_nanos(i) / 1e9;
            }
        }
        self.max_secs
    }

    /// Non-empty buckets as `(upper_bound_secs, cumulative_count)` in
    /// increasing bound order — the Prometheus `_bucket{le=...}` series.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((bucket_upper_nanos(i) / 1e9, cum));
            }
        }
        out
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn index_is_monotonic_and_in_bounds() {
        let mut prev = 0usize;
        let mut v = 0u64;
        while v < u64::MAX / 2 {
            let i = bucket_index(v);
            assert!(i < NUM_BUCKETS, "index {i} out of bounds for {v}");
            assert!(i >= prev, "index must be monotonic in the value");
            prev = i;
            v = v.saturating_mul(2).saturating_add(1);
        }
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1, "top value saturates");
    }

    #[test]
    fn bounds_bracket_the_value() {
        for &v in &[0u64, 1, 31, 32, 33, 100, 1_000, 999_999, 1 << 40, u64::MAX / 3] {
            let i = bucket_index(v);
            let hi = bucket_upper_nanos(i);
            let lo = hi - bucket_width_nanos(i);
            assert!((v as f64) < hi, "{v} must sit below its bucket's upper bound {hi}");
            assert!((v as f64) >= lo - 0.5, "{v} must sit at/above its bucket's lower bound {lo}");
        }
    }

    #[test]
    fn relative_bucket_width_is_bounded() {
        // past the exact range, width / lower-bound <= 2^-SUB_BITS
        for &v in &[50u64, 1_000, 123_456, 10_000_000, 5_000_000_000] {
            let i = bucket_index(v);
            let w = bucket_width_nanos(i);
            let lo = bucket_upper_nanos(i) - w;
            assert!(
                w / lo <= 1.0 / SUB as f64 + 1e-12,
                "bucket at {v}: width {w} vs lower bound {lo}"
            );
        }
    }

    #[test]
    fn record_and_quantiles() {
        let h = Histogram::new();
        for i in 1..=100u64 {
            h.record(i as f64 * 1e-3); // 1ms..100ms
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.mean_secs() - 0.0505).abs() < 1e-9, "nanosecond sums stay exact");
        let p50 = s.quantile(50.0);
        assert!((0.049..0.053).contains(&p50), "p50 ~ 50ms, got {p50}");
        let p99 = s.quantile(99.0);
        assert!((0.098..0.104).contains(&p99), "p99 ~ 100ms, got {p99}");
        assert!((s.max_secs - 0.1).abs() < 1e-9, "max is exact");
    }

    #[test]
    fn top_bucket_saturates() {
        let h = Histogram::new();
        h.record(f64::MAX); // absurd value: clamps into the top bucket
        h.record_nanos(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.count, 2, "saturating values still count");
        assert!(s.quantile(100.0) > 1e9, "saturated samples report the top bucket");
    }

    #[test]
    fn negative_and_nan_clamp_to_zero() {
        let h = Histogram::new();
        h.record(-1.0);
        h.record(f64::NAN);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.quantile(100.0), bucket_upper_nanos(0) / 1e9);
    }

    #[test]
    fn merge_is_associative() {
        let mk = |seed: u64| {
            let h = Histogram::new();
            let mut rng = crate::util::XorShift::new(seed);
            for _ in 0..500 {
                h.record(rng.next_f64() * 0.25);
            }
            h
        };
        let (a, b, c) = (mk(1), mk(2), mk(3));
        // left fold: (a + b) + c
        let left = Histogram::new();
        left.merge_from(&a);
        left.merge_from(&b);
        left.merge_from(&c);
        // right fold: a + (b + c)
        let bc = Histogram::new();
        bc.merge_from(&b);
        bc.merge_from(&c);
        let right = Histogram::new();
        right.merge_from(&a);
        right.merge_from(&bc);
        let (l, r) = (left.snapshot(), right.snapshot());
        assert_eq!(l.counts, r.counts, "merge must be associative per bucket");
        assert_eq!(l.count, r.count);
        assert!((l.sum_secs - r.sum_secs).abs() < 1e-12);
        assert_eq!(l.max_secs, r.max_secs);
    }

    #[test]
    fn cumulative_buckets_end_at_count() {
        let h = Histogram::new();
        for i in 0..50u64 {
            h.record(1e-4 * (1 + i % 7) as f64);
        }
        let s = h.snapshot();
        let cum = s.cumulative_buckets();
        assert!(!cum.is_empty());
        assert!(cum.windows(2).all(|w| w[0].0 < w[1].0), "bounds strictly increase");
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1), "counts are cumulative");
        assert_eq!(cum.last().unwrap().1, s.count);
    }
}
