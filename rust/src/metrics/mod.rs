//! Metrics registry + weight-residency accounting (substrate S25).
//!
//! The paper reports a model's memory footprint as its peak weight
//! residency under a loading strategy (§5.1).  `MemTracker` is the single
//! source of truth: every byte of weights copied into RAM is registered
//! under a component group (emb / timemix / chanmix / head / predictor /
//! hh / other), transient sparse loads included, and the peak of the
//! running total is what `exp fig5/fig6/table7` report.

use std::collections::BTreeMap;

use crate::sync::Mutex;

/// Component groups used by the Figure 6 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Group {
    Emb,
    TimeMix,
    ChanMix,
    Head,
    Predictor,
    HierHead,
    State,
    Other,
}

impl Group {
    pub fn name(self) -> &'static str {
        match self {
            Group::Emb => "embedding",
            Group::TimeMix => "time-mix",
            Group::ChanMix => "channel-mix",
            Group::Head => "head",
            Group::Predictor => "predictor",
            Group::HierHead => "hier-head",
            Group::State => "state",
            Group::Other => "other",
        }
    }
}

#[derive(Default, Debug)]
struct MemInner {
    current: u64,
    peak: u64,
    by_group: BTreeMap<Group, u64>,
    peak_by_group: BTreeMap<Group, u64>,
    load_events: u64,
    bytes_loaded_total: u64,
}

/// Thread-safe residency tracker.
#[derive(Debug)]
pub struct MemTracker {
    inner: Mutex<MemInner>,
}

// manual (not derived) so the shim's loom `Mutex`, which has no
// `Default`, still compiles
impl Default for MemTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl MemTracker {
    pub fn new() -> Self {
        Self { inner: Mutex::new(MemInner::default()) }
    }

    pub fn load(&self, group: Group, bytes: u64) {
        let mut m = self.inner.lock().unwrap();
        m.current += bytes;
        *m.by_group.entry(group).or_default() += bytes;
        let cur = m.current;
        m.peak = m.peak.max(cur);
        let g = *m.by_group.get(&group).unwrap();
        let e = m.peak_by_group.entry(group).or_default();
        *e = (*e).max(g);
        m.load_events += 1;
        m.bytes_loaded_total += bytes;
    }

    pub fn unload(&self, group: Group, bytes: u64) {
        let mut m = self.inner.lock().unwrap();
        m.current = m.current.saturating_sub(bytes);
        let e = m.by_group.entry(group).or_default();
        *e = e.saturating_sub(bytes);
    }

    pub fn current(&self) -> u64 {
        self.inner.lock().unwrap().current
    }

    pub fn peak(&self) -> u64 {
        self.inner.lock().unwrap().peak
    }

    pub fn peak_by_group(&self) -> BTreeMap<Group, u64> {
        self.inner.lock().unwrap().peak_by_group.clone()
    }

    pub fn current_by_group(&self) -> BTreeMap<Group, u64> {
        self.inner.lock().unwrap().by_group.clone()
    }

    pub fn bytes_loaded_total(&self) -> u64 {
        self.inner.lock().unwrap().bytes_loaded_total
    }

    /// Reset peak to the current level (start of a measured phase).
    pub fn reset_peak(&self) {
        let mut m = self.inner.lock().unwrap();
        m.peak = m.current;
        m.peak_by_group = m.by_group.clone();
    }
}

/// Simple named counters/timers for the serving stack.
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    timings: Mutex<BTreeMap<String, Vec<f64>>>,
}

// manual for the same loom-compatibility reason as `MemTracker`
impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            counters: Mutex::new(BTreeMap::new()),
            timings: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_default() += by;
    }

    /// Overwrite a counter with an absolute value (gauge semantics —
    /// used for levels that can fall as well as rise, e.g. the
    /// prefix-state cache's resident `cache_bytes`).
    pub fn set(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn observe(&self, name: &str, seconds: f64) {
        self.timings
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn timing_mean(&self, name: &str) -> Option<f64> {
        let t = self.timings.lock().unwrap();
        let v = t.get(name)?;
        if v.is_empty() {
            return None;
        }
        Some(v.iter().sum::<f64>() / v.len() as f64)
    }

    pub fn timings(&self, name: &str) -> Vec<f64> {
        self.timings
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in self.timings.lock().unwrap().iter() {
            if !v.is_empty() {
                let mean = v.iter().sum::<f64>() / v.len() as f64;
                out.push_str(&format!("{k}: n={} mean={:.3}ms\n", v.len(), mean * 1e3));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let t = MemTracker::new();
        t.load(Group::Emb, 100);
        t.load(Group::Head, 200);
        t.unload(Group::Head, 200);
        t.load(Group::Emb, 50);
        assert_eq!(t.current(), 150);
        assert_eq!(t.peak(), 300);
        assert_eq!(t.peak_by_group()[&Group::Head], 200);
    }

    #[test]
    fn reset_peak_starts_phase() {
        let t = MemTracker::new();
        t.load(Group::Emb, 100);
        t.unload(Group::Emb, 100);
        t.reset_peak();
        assert_eq!(t.peak(), 0);
        t.load(Group::State, 10);
        assert_eq!(t.peak(), 10);
    }

    #[test]
    fn registry_counts() {
        let r = Registry::new();
        r.inc("tokens", 3);
        r.inc("tokens", 2);
        r.observe("step", 0.5);
        assert_eq!(r.counter("tokens"), 5);
        assert_eq!(r.timing_mean("step"), Some(0.5));
    }

    #[test]
    fn set_overwrites_gauge() {
        let r = Registry::new();
        r.set("cache_bytes", 100);
        r.set("cache_bytes", 40); // gauges can fall
        assert_eq!(r.counter("cache_bytes"), 40);
    }
}
