//! Metrics registry + weight-residency accounting (substrate S25).
//!
//! The paper reports a model's memory footprint as its peak weight
//! residency under a loading strategy (§5.1).  `MemTracker` is the single
//! source of truth: every byte of weights copied into RAM is registered
//! under a component group (emb / timemix / chanmix / head / predictor /
//! hh / other), transient sparse loads included, and the peak of the
//! running total is what `exp fig5/fig6/table7` report.

pub mod hist;
pub mod trace;

use std::collections::{BTreeMap, BTreeSet};

use crate::json::{self, Value};
use crate::sync::{Arc, Mutex};

use hist::{HistSnapshot, Histogram};

/// Component groups used by the Figure 6 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Group {
    Emb,
    TimeMix,
    ChanMix,
    Head,
    Predictor,
    HierHead,
    State,
    Other,
}

impl Group {
    pub fn name(self) -> &'static str {
        match self {
            Group::Emb => "embedding",
            Group::TimeMix => "time-mix",
            Group::ChanMix => "channel-mix",
            Group::Head => "head",
            Group::Predictor => "predictor",
            Group::HierHead => "hier-head",
            Group::State => "state",
            Group::Other => "other",
        }
    }
}

#[derive(Default, Debug)]
struct MemInner {
    current: u64,
    peak: u64,
    by_group: BTreeMap<Group, u64>,
    peak_by_group: BTreeMap<Group, u64>,
    load_events: u64,
    bytes_loaded_total: u64,
}

/// Thread-safe residency tracker.
#[derive(Debug)]
pub struct MemTracker {
    inner: Mutex<MemInner>,
}

// manual (not derived) so the shim's loom `Mutex`, which has no
// `Default`, still compiles
impl Default for MemTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl MemTracker {
    pub fn new() -> Self {
        Self { inner: Mutex::new(MemInner::default()) }
    }

    pub fn load(&self, group: Group, bytes: u64) {
        let mut m = self.inner.lock().unwrap();
        m.current += bytes;
        *m.by_group.entry(group).or_default() += bytes;
        let cur = m.current;
        m.peak = m.peak.max(cur);
        let g = *m.by_group.get(&group).unwrap();
        let e = m.peak_by_group.entry(group).or_default();
        *e = (*e).max(g);
        m.load_events += 1;
        m.bytes_loaded_total += bytes;
    }

    pub fn unload(&self, group: Group, bytes: u64) {
        let mut m = self.inner.lock().unwrap();
        m.current = m.current.saturating_sub(bytes);
        let e = m.by_group.entry(group).or_default();
        *e = e.saturating_sub(bytes);
    }

    pub fn current(&self) -> u64 {
        self.inner.lock().unwrap().current
    }

    pub fn peak(&self) -> u64 {
        self.inner.lock().unwrap().peak
    }

    pub fn peak_by_group(&self) -> BTreeMap<Group, u64> {
        self.inner.lock().unwrap().peak_by_group.clone()
    }

    pub fn current_by_group(&self) -> BTreeMap<Group, u64> {
        self.inner.lock().unwrap().by_group.clone()
    }

    pub fn bytes_loaded_total(&self) -> u64 {
        self.inner.lock().unwrap().bytes_loaded_total
    }

    /// Reset peak to the current level (start of a measured phase).
    pub fn reset_peak(&self) {
        let mut m = self.inner.lock().unwrap();
        m.peak = m.current;
        m.peak_by_group = m.by_group.clone();
    }
}

/// Named counters, gauges, and latency histograms for the serving stack.
///
/// Timings (`observe`) land in fixed-size [`Histogram`]s — a bounded
/// footprint however long the server runs, with p50/p90/p99/max readable
/// at any time — instead of the old per-name unbounded `Vec<f64>`.
/// [`Registry::render_prometheus`] / [`Registry::stats_json`] are the
/// scrape surfaces the server's `GET /metrics` / `GET /stats` endpoints
/// expose.
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    /// Names written through `set()` — exported with gauge semantics.
    gauges: Mutex<BTreeSet<String>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

// manual for the same loom-compatibility reason as `MemTracker`
impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeSet::new()),
            hists: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_default() += by;
    }

    /// Overwrite a counter with an absolute value (gauge semantics —
    /// used for levels that can fall as well as rise, e.g. the
    /// prefix-state cache's resident `cache_bytes`).
    pub fn set(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
        self.gauges.lock().unwrap().insert(name.to_string());
    }

    /// Record one timing sample into the named histogram.  The map lock
    /// only guards the name lookup; the record itself is lock-free
    /// atomic increments into a fixed bucket array (no allocation after
    /// the first observation of a name).
    pub fn observe(&self, name: &str, seconds: f64) {
        let h = Arc::clone(
            self.hists
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        );
        h.record(seconds);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// The named histogram, if any sample was ever observed under it.
    /// Hot loops can hold the `Arc` and `record()` directly, skipping the
    /// name lookup entirely.
    pub fn hist(&self, name: &str) -> Option<Arc<Histogram>> {
        self.hists.lock().unwrap().get(name).cloned()
    }

    /// Point-in-time statistics for the named histogram.
    pub fn hist_snapshot(&self, name: &str) -> Option<HistSnapshot> {
        self.hist(name).map(|h| h.snapshot())
    }

    pub fn hist_names(&self) -> Vec<String> {
        self.hists.lock().unwrap().keys().cloned().collect()
    }

    pub fn timing_mean(&self, name: &str) -> Option<f64> {
        let s = self.hist_snapshot(name)?;
        (s.count > 0).then(|| s.mean_secs())
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        let hists: Vec<(String, Arc<Histogram>)> = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), Arc::clone(h)))
            .collect();
        for (k, h) in hists {
            let s = h.snapshot();
            if s.count > 0 {
                out.push_str(&format!(
                    "{k}: n={} mean={:.3}ms\n",
                    s.count,
                    s.mean_secs() * 1e3
                ));
            }
        }
        out
    }

    /// Prometheus text exposition (version 0.0.4) of every counter,
    /// gauge, and histogram.  Counters/gauges render under one map lock,
    /// so relations between them (the admission accounting invariant)
    /// hold WITHIN a single scrape, not just eventually.  Histogram
    /// families emit only their non-empty `_bucket` lines (cumulative, in
    /// increasing `le` order) plus `+Inf`, `_sum`, `_count`.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        {
            let counters = self.counters.lock().unwrap();
            let gauges = self.gauges.lock().unwrap();
            let mut finished_header = false;
            for (k, v) in counters.iter() {
                // `finish_reason_<r>` counters fold into ONE labeled
                // family so dashboards can group by reason (BTreeMap
                // order keeps the family contiguous; the TYPE header
                // must appear exactly once)
                if let Some(reason) = k.strip_prefix("finish_reason_") {
                    if !finished_header {
                        out.push_str("# TYPE rwkv_requests_finished_total counter\n");
                        finished_header = true;
                    }
                    out.push_str(&format!(
                        "rwkv_requests_finished_total{{reason=\"{reason}\"}} {v}\n"
                    ));
                    continue;
                }
                let name = prom_name(k);
                let kind = if gauges.contains(k) { "gauge" } else { "counter" };
                out.push_str(&format!("# TYPE {name} {kind}\n{name} {v}\n"));
            }
        }
        let hists: Vec<(String, Arc<Histogram>)> = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), Arc::clone(h)))
            .collect();
        for (k, h) in hists {
            let s = h.snapshot();
            let name = prom_hist_name(&k);
            out.push_str(&format!("# TYPE {name} histogram\n"));
            for (le, cum) in s.cumulative_buckets() {
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", s.count));
            out.push_str(&format!("{name}_sum {}\n", s.sum_secs));
            out.push_str(&format!("{name}_count {}\n", s.count));
        }
        out
    }

    /// JSON snapshot (the `GET /stats` body): counters + gauges verbatim,
    /// histograms as count/mean/p50/p90/p99/max summaries.
    pub fn stats_json(&self) -> Value {
        let mut counters = BTreeMap::new();
        let mut gauge_obj = BTreeMap::new();
        {
            let cs = self.counters.lock().unwrap();
            let gs = self.gauges.lock().unwrap();
            for (k, v) in cs.iter() {
                if gs.contains(k) {
                    gauge_obj.insert(k.clone(), json::num(*v as f64));
                } else {
                    counters.insert(k.clone(), json::num(*v as f64));
                }
            }
        }
        let hists: Vec<(String, Arc<Histogram>)> = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), Arc::clone(h)))
            .collect();
        let mut hist_obj = BTreeMap::new();
        for (k, h) in hists {
            let s = h.snapshot();
            hist_obj.insert(
                k,
                json::obj(vec![
                    ("count", json::num(s.count as f64)),
                    ("sum_secs", json::num(s.sum_secs)),
                    ("mean_secs", json::num(s.mean_secs())),
                    ("p50_secs", json::num(s.quantile(50.0))),
                    ("p90_secs", json::num(s.quantile(90.0))),
                    ("p99_secs", json::num(s.quantile(99.0))),
                    ("max_secs", json::num(s.max_secs)),
                ]),
            );
        }
        Value::Obj(BTreeMap::from([
            ("counters".to_string(), Value::Obj(counters)),
            ("gauges".to_string(), Value::Obj(gauge_obj)),
            ("histograms".to_string(), Value::Obj(hist_obj)),
        ]))
    }
}

/// Prometheus metric name for an internal counter/gauge key: `rwkv_`
/// prefix, invalid characters mapped to `_`.
fn prom_name(key: &str) -> String {
    let mut s = String::with_capacity(key.len() + 5);
    s.push_str("rwkv_");
    for c in key.chars() {
        s.push(if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' });
    }
    s
}

/// Histogram family name: internal `_secs` suffixes become the
/// conventional Prometheus `_seconds` unit suffix.
fn prom_hist_name(key: &str) -> String {
    match key.strip_suffix("_secs") {
        Some(base) => prom_name(&format!("{base}_seconds")),
        None => prom_name(key),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let t = MemTracker::new();
        t.load(Group::Emb, 100);
        t.load(Group::Head, 200);
        t.unload(Group::Head, 200);
        t.load(Group::Emb, 50);
        assert_eq!(t.current(), 150);
        assert_eq!(t.peak(), 300);
        assert_eq!(t.peak_by_group()[&Group::Head], 200);
    }

    #[test]
    fn reset_peak_starts_phase() {
        let t = MemTracker::new();
        t.load(Group::Emb, 100);
        t.unload(Group::Emb, 100);
        t.reset_peak();
        assert_eq!(t.peak(), 0);
        t.load(Group::State, 10);
        assert_eq!(t.peak(), 10);
    }

    #[test]
    fn registry_counts() {
        let r = Registry::new();
        r.inc("tokens", 3);
        r.inc("tokens", 2);
        r.observe("step", 0.5);
        assert_eq!(r.counter("tokens"), 5);
        assert_eq!(r.timing_mean("step"), Some(0.5));
    }

    #[test]
    fn set_overwrites_gauge() {
        let r = Registry::new();
        r.set("cache_bytes", 100);
        r.set("cache_bytes", 40); // gauges can fall
        assert_eq!(r.counter("cache_bytes"), 40);
    }

    #[test]
    fn observe_is_bounded_and_quantiled() {
        // the long-running-server fix: 100k samples stay a fixed-size
        // histogram, and the registry answers quantiles directly
        let r = Registry::new();
        for i in 0..100_000u64 {
            r.observe("round_seconds", 1e-4 + (i % 100) as f64 * 1e-5);
        }
        let s = r.hist_snapshot("round_seconds").expect("hist exists");
        assert_eq!(s.count, 100_000);
        let p50 = s.quantile(50.0);
        assert!((5e-4..7e-4).contains(&p50), "p50 ~ 0.6ms, got {p50}");
        assert!(r.hist_snapshot("nope").is_none());
    }

    #[test]
    fn report_format_is_stable() {
        let r = Registry::new();
        r.inc("rounds", 2);
        r.observe("step", 0.5);
        let report = r.report();
        assert!(report.contains("rounds: 2\n"));
        assert!(report.contains("step: n=1 mean=500.000ms\n"));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.inc("rounds", 3);
        r.set("queue_depth", 2);
        r.inc("finish_reason_length", 5);
        r.observe("queue_wait_secs", 0.001);
        r.observe("queue_wait_secs", 0.004);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE rwkv_rounds counter\nrwkv_rounds 3\n"));
        assert!(text.contains("# TYPE rwkv_queue_depth gauge\nrwkv_queue_depth 2\n"));
        assert!(text.contains("rwkv_requests_finished_total{reason=\"length\"} 5\n"));
        // the `_secs` key exports under the conventional `_seconds` unit
        assert!(text.contains("# TYPE rwkv_queue_wait_seconds histogram\n"));
        assert!(text.contains("rwkv_queue_wait_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("rwkv_queue_wait_seconds_count 2\n"));
        let sum: f64 = text
            .lines()
            .find_map(|l| l.strip_prefix("rwkv_queue_wait_seconds_sum "))
            .expect("sum line")
            .parse()
            .unwrap();
        assert!((sum - 0.005).abs() < 1e-9, "sum is exact, got {sum}");
        // every line is a comment or `name[{labels}] value` with a
        // parseable numeric value — the exposition grammar the scrape
        // smoke also enforces
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (_, val) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(
                val == "+Inf" || val.parse::<f64>().is_ok(),
                "unparseable value in line: {line}"
            );
        }
    }

    #[test]
    fn stats_json_summarizes_histograms() {
        let r = Registry::new();
        r.inc("rounds", 7);
        r.set("cache_bytes", 11);
        for i in 1..=10 {
            r.observe("ttft_secs", i as f64 * 0.01);
        }
        let v = r.stats_json();
        assert_eq!(v.f64_at(&["counters", "rounds"]), Some(7.0));
        assert_eq!(v.f64_at(&["gauges", "cache_bytes"]), Some(11.0));
        assert_eq!(v.f64_at(&["histograms", "ttft_secs", "count"]), Some(10.0));
        let p99 = v.f64_at(&["histograms", "ttft_secs", "p99_secs"]).unwrap();
        assert!((0.09..0.12).contains(&p99), "p99 ~ 100ms, got {p99}");
        // the JSON text round-trips through the crate parser
        let text = v.to_string();
        assert!(crate::json::parse(&text).is_ok());
    }
}
