//! Bounded per-round trace ring buffer with JSONL export.
//!
//! Latency histograms answer "how bad is the tail"; the trace answers
//! "what did round N actually do".  The coordinator (the ONLY writer —
//! it owns the engine, so pushes are single-threaded and the mutex is
//! uncontended on the hot path) records one [`RoundTrace`] per
//! scheduling round: batch composition, the prefill-chunk choice the
//! degradation policy made, phase timings, queue depth, shed/deadline
//! events, and prefetch waits.  The ring is BOUNDED — past `capacity`
//! the oldest round is dropped and `dropped()` counts it — so a
//! long-running server holds a fixed-size flight recorder, never an
//! unbounded log.
//!
//! `--trace-out <path>` exports the ring as JSON Lines (one round per
//! line) when the coordinator shuts down, for offline timeline analysis;
//! the open-loop bench (`benches/serving_throughput -- --arrival-rate`)
//! writes the same format.

use std::collections::VecDeque;
use std::path::Path;

use crate::json::{self, Value};
use crate::sync::Mutex;

/// Ring capacity used when a trace sink is requested without an explicit
/// capacity (about a megabyte of rounds).
pub const DEFAULT_CAPACITY: usize = 4096;

/// One scheduling round, as the coordinator saw it.
#[derive(Clone, Debug, Default)]
pub struct RoundTrace {
    /// Round ordinal (1-based, matches the coordinator's fault hooks).
    pub round: u64,
    /// Seconds since the coordinator loop started.
    pub at_secs: f64,
    /// Sessions in flight when the engine round ran.
    pub sessions: usize,
    /// Prompt tokens advanced this round (0 = pure decode round).
    pub prefill_tokens: usize,
    /// Decode rows advanced this round.
    pub decode_tokens: usize,
    /// The prefill chunk the round ran with — under queue pressure the
    /// degradation policy shrinks it below the configured base.
    pub chunk: usize,
    /// Admission queue depth at the round boundary.
    pub queue_depth: usize,
    /// Wall time of the engine round.
    pub round_secs: f64,
    /// Weight bytes streamed by the fused pass.
    pub weight_bytes: u64,
    /// Tokens emitted to streams this round.
    pub emitted: usize,
    /// Sessions retired normally this round (length/stop).
    pub completed: usize,
    /// Sessions retired by cancellation this round.
    pub cancelled: usize,
    /// Sessions retired by deadline expiry this round.
    pub deadline_expired: usize,
    /// Submissions shed at this round boundary (drain races).
    pub shed: usize,
    /// Engine phase split (seconds): WKV recurrence, weight-streaming
    /// matmuls, head.
    pub wkv_secs: f64,
    pub matmul_secs: f64,
    pub head_secs: f64,
    /// Layerwise streaming: exposed block acquisition stall and the part
    /// spent waiting on an in-flight prefetch (0 under full loading).
    pub block_load_secs: f64,
    pub prefetch_wait_secs: f64,
    /// The engine round returned an error (every in-flight stream was
    /// cancelled; the composition fields describe the attempt).
    pub round_error: bool,
}

impl RoundTrace {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("round", json::num(self.round as f64)),
            ("at_secs", json::num(self.at_secs)),
            ("sessions", json::num(self.sessions as f64)),
            ("prefill_tokens", json::num(self.prefill_tokens as f64)),
            ("decode_tokens", json::num(self.decode_tokens as f64)),
            ("chunk", json::num(self.chunk as f64)),
            ("queue_depth", json::num(self.queue_depth as f64)),
            ("round_secs", json::num(self.round_secs)),
            ("weight_bytes", json::num(self.weight_bytes as f64)),
            ("emitted", json::num(self.emitted as f64)),
            ("completed", json::num(self.completed as f64)),
            ("cancelled", json::num(self.cancelled as f64)),
            ("deadline_expired", json::num(self.deadline_expired as f64)),
            ("shed", json::num(self.shed as f64)),
            ("wkv_secs", json::num(self.wkv_secs)),
            ("matmul_secs", json::num(self.matmul_secs)),
            ("head_secs", json::num(self.head_secs)),
            ("block_load_secs", json::num(self.block_load_secs)),
            ("prefetch_wait_secs", json::num(self.prefetch_wait_secs)),
            ("round_error", Value::Bool(self.round_error)),
        ])
    }
}

struct RingInner {
    rounds: VecDeque<RoundTrace>,
    dropped: u64,
}

/// Bounded flight recorder of recent rounds.
pub struct TraceRing {
    capacity: usize,
    inner: Mutex<RingInner>,
}

impl TraceRing {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            capacity,
            inner: Mutex::new(RingInner { rounds: VecDeque::new(), dropped: 0 }),
        }
    }

    /// Append one round; past capacity the OLDEST round is evicted (and
    /// counted) so the ring always holds the most recent window.
    pub fn push(&self, t: RoundTrace) {
        let mut g = self.inner.lock().unwrap();
        if g.rounds.len() == self.capacity {
            g.rounds.pop_front();
            g.dropped += 1;
        }
        g.rounds.push_back(t);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().rounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rounds evicted past the capacity bound (a non-zero value tells an
    /// offline consumer the JSONL is a suffix, not the full history).
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Copy of the retained rounds, oldest first.
    pub fn snapshot(&self) -> Vec<RoundTrace> {
        self.inner.lock().unwrap().rounds.iter().cloned().collect()
    }

    /// JSON Lines rendering (one round object per line, oldest first).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for t in self.snapshot() {
            out.push_str(&t.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL export (the `--trace-out` sink).
    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn mk(round: u64) -> RoundTrace {
        RoundTrace { round, round_secs: 0.001 * round as f64, ..RoundTrace::default() }
    }

    #[test]
    fn ring_is_bounded_and_keeps_the_newest() {
        let ring = TraceRing::new(3);
        for r in 1..=5 {
            ring.push(mk(r));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.dropped(), 2);
        let rounds: Vec<u64> = ring.snapshot().iter().map(|t| t.round).collect();
        assert_eq!(rounds, vec![3, 4, 5], "oldest rounds are evicted first");
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let ring = TraceRing::new(8);
        let mut t = mk(7);
        t.sessions = 3;
        t.chunk = 4;
        t.queue_depth = 2;
        t.round_error = true;
        ring.push(t);
        ring.push(mk(8));
        let text = ring.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = crate::json::parse(lines[0]).expect("trace line is valid JSON");
        assert_eq!(v.f64_at(&["round"]), Some(7.0));
        assert_eq!(v.f64_at(&["sessions"]), Some(3.0));
        assert_eq!(v.f64_at(&["chunk"]), Some(4.0));
        assert_eq!(v.get("round_error").and_then(|b| b.as_bool()), Some(true));
        let v = crate::json::parse(lines[1]).expect("trace line is valid JSON");
        assert_eq!(v.f64_at(&["round"]), Some(8.0));
    }

    #[test]
    fn write_jsonl_round_trips_through_a_file() {
        let ring = TraceRing::new(4);
        ring.push(mk(1));
        let path = std::env::temp_dir().join(format!("rwkv-trace-test-{}.jsonl", std::process::id()));
        ring.write_jsonl(&path).expect("write trace");
        let text = std::fs::read_to_string(&path).expect("read trace back");
        assert_eq!(text.lines().count(), 1);
        assert!(crate::json::parse(text.lines().next().unwrap()).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
