//! Submit-side admission gate shared between client threads and the
//! round loop.
//!
//! This is the only coordinator state touched from OUTSIDE the
//! coordinator thread (every client thread calling
//! [`Coordinator::submit`](super::Coordinator::submit) races through
//! it), so it is kept small, lock-free, and — since PR 7 — built on
//! [`crate::sync`] atomics so the loom model tests below can exhaustively
//! check the reserve/release protocol under every interleaving.
//!
//! Protocol:
//! - `try_reserve` claims a queue slot before the submission is sent
//!   down the mpsc channel.  With a bound, the claim is a CAS loop so a
//!   burst of concurrent submitters can never overshoot `max_queue`
//!   (checked by `loom_gate_reserve_never_overshoots`).
//! - `release` returns the slot once the round loop admits (or sheds)
//!   the submission, or when the send itself fails.
//! - `begin_drain` / `is_draining` is a Release/Acquire flag pair: the
//!   shutdown path flips it, submitters observe it before reserving.
//! - `note_round_nanos` / `round_nanos` is a monotonic-ish EWMA of round
//!   wall time feeding the `retry_after_ms` backoff hint; Relaxed is
//!   enough because the value is advisory (a hint, never a correctness
//!   input).

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Submit-side state shared between client threads and the round loop.
pub(crate) struct Gate {
    /// Submissions sent but not yet admitted into sessions.
    queued: AtomicUsize,
    /// Shutdown flag: reject new work, drain in-flight.
    draining: AtomicBool,
    /// EWMA of recent round wall time (nanos) — the `retry_after_ms`
    /// estimate (`0` until the first round completes).
    round_nanos: AtomicU64,
}

impl Gate {
    // `new` rather than `Default`/const-init: loom atomics have neither
    // a const constructor nor `Default`.
    pub(crate) fn new() -> Self {
        Self {
            queued: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            round_nanos: AtomicU64::new(0),
        }
    }

    /// Claim a queue slot.  `max_queue == 0` means unbounded: always
    /// succeeds.  Otherwise a CAS loop enforces the bound exactly —
    /// concurrent claimers cannot overshoot it.
    pub(crate) fn try_reserve(&self, max_queue: usize) -> bool {
        if max_queue == 0 {
            self.queued.fetch_add(1, Ordering::AcqRel);
            return true;
        }
        let mut depth = self.queued.load(Ordering::Relaxed);
        loop {
            if depth >= max_queue {
                return false;
            }
            match self.queued.compare_exchange_weak(
                depth,
                depth + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(d) => depth = d,
            }
        }
    }

    /// Return a slot claimed by [`Gate::try_reserve`].  Callers uphold
    /// the pairing (exactly one release per successful reserve); an
    /// unpaired release would underflow and wrap the depth gauge.
    pub(crate) fn release(&self) {
        self.queued.fetch_sub(1, Ordering::AcqRel);
    }

    /// Current queue depth (advisory: a racing reserve/release may move
    /// it immediately — used for the gauge metric and the backoff hint).
    pub(crate) fn depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    pub(crate) fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub(crate) fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Fold one round's wall time (nanos) into the EWMA
    /// (`next = (3*prev + sample) / 4`; the first sample seeds it).
    /// Only the round loop calls this, so load-then-store is not a race.
    pub(crate) fn note_round_nanos(&self, sample: u64) {
        let prev = self.round_nanos.load(Ordering::Relaxed);
        let next = if prev == 0 { sample } else { (3 * prev + sample) / 4 };
        // `.max(1)` so a sub-nanosecond round cannot reset the
        // "no history yet" sentinel
        self.round_nanos.store(next.max(1), Ordering::Relaxed);
    }

    /// EWMA round wall time in nanos (`0` = no round has completed).
    pub(crate) fn round_nanos(&self) -> u64 {
        self.round_nanos.load(Ordering::Relaxed)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::Gate;

    #[test]
    fn unbounded_reserve_always_succeeds() {
        let g = Gate::new();
        for i in 0..100 {
            assert!(g.try_reserve(0));
            assert_eq!(g.depth(), i + 1);
        }
    }

    #[test]
    fn bounded_reserve_sheds_at_limit() {
        let g = Gate::new();
        assert!(g.try_reserve(2));
        assert!(g.try_reserve(2));
        assert!(!g.try_reserve(2));
        assert_eq!(g.depth(), 2);
        g.release();
        assert!(g.try_reserve(2));
        assert!(!g.try_reserve(2));
    }

    #[test]
    fn drain_flag_round_trips() {
        let g = Gate::new();
        assert!(!g.is_draining());
        g.begin_drain();
        assert!(g.is_draining());
    }

    #[test]
    fn round_ewma_seeds_then_smooths() {
        let g = Gate::new();
        assert_eq!(g.round_nanos(), 0);
        g.note_round_nanos(1000);
        assert_eq!(g.round_nanos(), 1000);
        g.note_round_nanos(2000);
        assert_eq!(g.round_nanos(), (3 * 1000 + 2000) / 4);
        // a zero sample cannot re-arm the "no history" sentinel
        let g2 = Gate::new();
        g2.note_round_nanos(0);
        assert_eq!(g2.round_nanos(), 1);
    }
}

// Loom model tests (run by the CI `loom` job with
// `RUSTFLAGS="--cfg loom" cargo test --lib --release loom_`): exhaustive
// interleaving checks of the reserve/release CAS protocol.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::Gate;
    use crate::sync::Arc;

    /// Two threads race `try_reserve(1)`: exactly one may win, and the
    /// depth must equal the number of winners (never overshooting the
    /// bound, never losing a claim).
    #[test]
    fn loom_gate_reserve_never_overshoots() {
        loom::model(|| {
            let gate = Arc::new(Gate::new());
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let g = Arc::clone(&gate);
                    loom::thread::spawn(move || g.try_reserve(1))
                })
                .collect();
            let wins = handles.into_iter().filter(|h| h.join().unwrap()).count();
            assert_eq!(wins, 1, "exactly one of two racers may claim the single slot");
            assert_eq!(gate.depth(), 1);
        });
    }

    /// A release concurrent with a racing reserve: the racer either sees
    /// the slot free (claims it) or full (sheds) — but the final depth
    /// is always consistent with the set of successful claims.
    #[test]
    fn loom_gate_release_frees_slot_for_racer() {
        loom::model(|| {
            let gate = Arc::new(Gate::new());
            assert!(gate.try_reserve(1));
            let g = Arc::clone(&gate);
            let racer = loom::thread::spawn(move || g.try_reserve(1));
            gate.release();
            let won = racer.join().unwrap();
            let expect = if won { 1 } else { 0 };
            assert_eq!(gate.depth(), expect);
            if !won {
                // the slot is free after both threads are done
                assert!(gate.try_reserve(1));
            }
        });
    }
}
