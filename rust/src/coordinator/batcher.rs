//! Dynamic batching policy: collect requests up to `max_batch` within
//! `window_ms` before a decode round; when the engine is busy, admit
//! without waiting (continuous batching — new requests join mid-flight,
//! vLLM-style, scaled to a single-device edge serving loop).

use crate::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use super::Submission;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub window_ms: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 8, window_ms: 2 }
    }
}

pub(crate) enum Admit {
    Requests(Vec<Submission>),
    None,
    Closed,
}

pub struct DynamicBatcher {
    policy: BatchPolicy,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy }
    }

    /// Pull work from the queue.  With `in_flight == 0` this waits up to
    /// `idle_tick` for a request (a bounded wait, so the round loop can
    /// observe shutdown/drain flags between ticks); otherwise it drains
    /// whatever is pending without stalling the decode loop.  `max_live`
    /// caps total in-flight sessions (the `--max-concurrency` knob; the
    /// batch policy's `max_batch` still bounds admissions per call).
    pub(crate) fn admit(
        &mut self,
        rx: &Receiver<Submission>,
        in_flight: usize,
        max_live: usize,
        idle_tick: Duration,
    ) -> Admit {
        let mut out = Vec::new();
        let capacity = self
            .policy
            .max_batch
            .min(max_live.saturating_sub(in_flight));
        if capacity == 0 {
            return Admit::None;
        }
        if in_flight == 0 {
            // idle: wait (bounded) for the first request
            match rx.recv_timeout(idle_tick) {
                Ok(s) => out.push(s),
                Err(RecvTimeoutError::Timeout) => return Admit::None,
                Err(RecvTimeoutError::Disconnected) => return Admit::Closed,
            }
            // then batch within the window
            let deadline = Duration::from_millis(self.policy.window_ms);
            while out.len() < capacity {
                match rx.recv_timeout(deadline) {
                    Ok(s) => out.push(s),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        } else {
            // busy: opportunistic drain
            while out.len() < capacity {
                match rx.try_recv() {
                    Ok(s) => out.push(s),
                    Err(_) => break,
                }
            }
        }
        if out.is_empty() {
            Admit::None
        } else {
            Admit::Requests(out)
        }
    }
}
