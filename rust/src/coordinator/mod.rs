//! Request coordinator (S21): router + dynamic batcher + round scheduler.
//!
//! Edge-serving shape: one engine (one device) advances a set of
//! concurrent [`Session`]s round-robin (continuous batching: new requests
//! join mid-flight).  The whole loop is one call per round —
//! `RwkvEngine::step_round` — which fuses prompt-phase sessions (chunked
//! `(B', T)` prefill) and decode-phase sessions into a SINGLE pass over
//! the weights: every projection, FFN matrix and the head stream from
//! storage once per round and serve every row while hot, so dense-layer
//! bytes-per-round are constant in the number of sessions and aggregate
//! tok/s scales with the batch.  The §3.2 sparse FFN unions predicted
//! rows across all prompt and decode rows of the round (each row masked
//! to its own set — bit-identical to the per-slot path).  Sampling and
//! stop-token checking happen inside the round; this loop only routes the
//! emitted tokens to their streams.
//!
//! Overload resilience ([`AdmissionPolicy`], knobs surfaced as
//! `EngineConfig`/CLI fields): admission is BOUNDED — at most `max_queue`
//! submissions wait for a slot and at most `max_concurrency` sessions are
//! in flight; a submission past the bound is shed immediately with
//! [`Event::Rejected`] (429 semantics, `retry_after_ms` hint) instead of
//! queueing forever.  Prompts over `max_prompt_tokens` are refused the
//! same way.  Each request can carry a deadline; expired sessions retire
//! at the next round boundary with [`FinishReason::DeadlineExceeded`]
//! (partial tokens were already streamed).  Under sustained pressure the
//! loop degrades gracefully: with requests waiting behind a full slot
//! set, prefill chunks shrink so decode sessions get their next token
//! sooner — chunking never changes the math, so admitted streams stay
//! bit-identical.
//!
//! Graceful shutdown: [`Coordinator::begin_shutdown`] (the serve path's
//! SIGINT/SIGTERM handler) flips a drain flag — new submissions are
//! rejected, in-flight sessions keep stepping for up to the drain budget,
//! stragglers are then cancelled (every admitted request still gets a
//! terminal [`Event::Done`]), and the prefix-state cache saves its
//! statefile before the thread exits.
//!
//! Lifecycle: [`Coordinator::submit`] returns a [`RequestHandle`] whose
//! `cancel()` retires the session at the next round boundary; a client
//! that drops its handle mid-stream is detected via `Event` send failure
//! and retired the same way ([`FinishReason::Cancelled`]).
//!
//! Telemetry: the coordinator registry is THE registry — the loop hands
//! it to the engine ([`RwkvEngine::adopt_metrics`]) so one scrape covers
//! both sides.  Counters/gauges: `rounds`, `round_weight_bytes`,
//! `prefill_tokens`, `decode_tokens`, `requests_admitted` /
//! `requests_completed` / `requests_cancelled` / `requests_rejected` /
//! `requests_deadline_exceeded`, `finish_reason_*`, `tokens_out`, the
//! `queue_depth` gauge, plus the engine's own series (`simd_backend_id`,
//! `session_rounds`, `blocks_prefetched`, ...).  Latency histograms
//! ([`crate::metrics::hist`], bounded, lock-free): `queue_wait_secs`,
//! `ttft_secs` (split `ttft_warm_secs`/`ttft_cold_secs` by prefix-cache
//! hit), `itl_secs` (inter-token latency), `request_total_secs`,
//! `coord_round_secs` and the engine's `round_*_secs` phase splits.  Spans
//! are recorded at round boundaries only — the hot path never allocates
//! for telemetry.  Accounting invariant (asserted by `tests/overload.rs`
//! and `tests/faults.rs`): every submission is rejected or admitted, and
//! every admitted request terminates exactly once — `requests_admitted
//! == requests_completed + requests_cancelled +
//! requests_deadline_exceeded`.  With a prefix-state cache
//! ([`Coordinator::spawn_with_cache`]): `cache_hits` / `cache_misses` /
//! `cache_hit_tokens` / `cache_insertions` / `cache_evictions` plus the
//! `cache_bytes` residency gauge.
//!
//! Round traces: with [`CoordinatorConfig::trace_capacity`] or
//! `trace_out` set, every scheduling round appends one structured
//! [`RoundTrace`] to a bounded ring ([`crate::metrics::trace`]) — batch
//! composition, the prefill-chunk the degradation policy chose, phase
//! timings, shed/deadline events, prefetch waits — exported as JSONL at
//! shutdown when `trace_out` names a path.
//!
//! Topology: N client threads -> mpsc -> coordinator thread (owns the
//! engine) -> per-request streaming channels.  Intra-round compute
//! parallelism lives BELOW this loop: the engine factory is handed a
//! [`crate::pool::ThreadPool`] handle (`RwkvEngine::load_with_pool`, the
//! `--threads` knob) and every `step_round` fans its kernels, per-slot
//! WKV recurrence and predictor out over those workers — the coordinator
//! thread stays the only place sessions are mutated between rounds.

pub mod batcher;
mod gate;

use std::path::PathBuf;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::EngineConfig;
use crate::engine::sampler::Sampler;
use crate::engine::session::Session;
use crate::engine::state_cache::StateCache;
use crate::engine::RwkvEngine;
use crate::metrics::trace::{RoundTrace, TraceRing};
use crate::metrics::Registry;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::Arc;
use crate::testutil::faults::FaultPlan;
use batcher::{BatchPolicy, DynamicBatcher};
use gate::Gate;

pub use crate::engine::session::FinishReason;

/// How long the round loop waits for work when idle before re-checking
/// the shutdown/drain flags (purely an internal wake-up cadence).
const IDLE_TICK: Duration = Duration::from_millis(50);

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// Extra stop token ids (EOS always stops; the stop token is emitted).
    pub stop_tokens: Vec<u32>,
    /// Multi-token stop sequences (suffix match over emitted tokens; the
    /// matching tokens are emitted, then the stream ends with
    /// `reason: "stop"`).
    pub stop_sequences: Vec<Vec<u32>>,
    /// Explicit sampler seed; `None` falls back to the request id.
    pub seed: Option<u64>,
    /// Participate in the coordinator's prefix-state cache (fork from the
    /// longest cached prompt prefix AND contribute snapshots).  Ignored
    /// when the coordinator has no cache.  Default `true`.
    pub cache: bool,
    /// Per-request deadline in milliseconds, measured from submission.
    /// `None` falls back to [`AdmissionPolicy::default_deadline_ms`]
    /// (`0` there = no deadline).  An expired session retires at the next
    /// round boundary with `reason: "deadline"`, keeping the tokens it
    /// already streamed.
    pub deadline_ms: Option<u64>,
}

impl Default for Request {
    fn default() -> Self {
        Self {
            id: 0,
            prompt: Vec::new(),
            max_tokens: 32,
            temperature: 0.0,
            top_p: 1.0,
            stop_tokens: Vec::new(),
            stop_sequences: Vec::new(),
            seed: None,
            cache: true,
            deadline_ms: None,
        }
    }
}

/// Why a submission was refused before any session was created (no
/// engine work was done; `requests_admitted` does NOT count it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue is full (`max_queue`); retry after the hint.
    Overloaded,
    /// The prompt exceeds `max_prompt_tokens`.
    PromptTooLong { tokens: usize, limit: usize },
    /// The coordinator is draining for shutdown (or already stopped).
    ShuttingDown,
}

impl RejectReason {
    /// Stable wire name (the server's structured `error` field).
    pub fn wire_name(&self) -> &'static str {
        match self {
            RejectReason::Overloaded => "overloaded",
            RejectReason::PromptTooLong { .. } => "prompt_too_long",
            RejectReason::ShuttingDown => "shutting_down",
        }
    }
}

/// Streamed events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    Token { token: u32 },
    /// Terminal per-request summary: token count, service seconds
    /// (admission -> retirement), finish reason, prompt tokens served
    /// from the prefix-state cache, queue wait seconds, and time to
    /// first token (`None` when the request retired before emitting).
    Done {
        tokens: usize,
        seconds: f64,
        reason: FinishReason,
        cached_tokens: usize,
        queue_secs: f64,
        ttft_secs: Option<f64>,
    },
    Error { message: String },
    /// Shed at admission (load, prompt limit, or shutdown) — terminal;
    /// no session existed, so no `Done` follows.  `retry_after_ms` is a
    /// backoff hint from recent round latency and queue depth.
    Rejected { reason: RejectReason, retry_after_ms: u64 },
}

pub(crate) struct Submission {
    pub(crate) req: Request,
    pub(crate) tx: Sender<Event>,
    pub(crate) cancel: Arc<AtomicBool>,
    /// Started at submission — queue wait telemetry.
    pub(crate) queued: crate::util::Stopwatch,
    /// Absolute deadline resolved at submission time.
    pub(crate) deadline: Option<Instant>,
}

/// Client side of a submitted request: the event stream plus a cancel
/// switch.  Dropping the handle (or its iterator) also cancels — the
/// coordinator notices the dead stream on the next emitted token.
pub struct RequestHandle {
    pub id: u64,
    rx: Receiver<Event>,
    cancel: Arc<AtomicBool>,
}

impl RequestHandle {
    /// Ask the coordinator to retire this request at the next round
    /// boundary; the stream then ends with `Done { reason: Cancelled }`.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Next event, or `None` once the stream is closed.
    pub fn recv(&self) -> Option<Event> {
        self.rx.recv().ok()
    }

    /// Borrowing event iterator (keeps the handle, so `cancel()` stays
    /// available mid-stream).
    pub fn iter(&self) -> std::sync::mpsc::Iter<'_, Event> {
        self.rx.iter()
    }
}

impl IntoIterator for RequestHandle {
    type Item = Event;
    type IntoIter = std::sync::mpsc::IntoIter<Event>;

    fn into_iter(self) -> Self::IntoIter {
        self.rx.into_iter()
    }
}

impl<'a> IntoIterator for &'a RequestHandle {
    type Item = Event;
    type IntoIter = std::sync::mpsc::Iter<'a, Event>;

    fn into_iter(self) -> Self::IntoIter {
        self.rx.iter()
    }
}

/// Bounded-admission / deadline / drain knobs.  The default is fully
/// permissive (legacy behaviour: unbounded queue, no deadline) so
/// library users and benches opt in explicitly; the serve path builds
/// one from `EngineConfig` where bounded admission is the default.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Max submissions waiting for a session slot (`0` = unbounded).
    pub max_queue: usize,
    /// Max sessions in flight (`0` = the batch policy's `max_batch`).
    pub max_concurrency: usize,
    /// Reject prompts longer than this many tokens (`0` = unlimited).
    pub max_prompt_tokens: usize,
    /// Deadline applied to requests that don't carry their own (`0` =
    /// none).
    pub default_deadline_ms: u64,
    /// Shutdown drain budget: how long in-flight sessions may keep
    /// stepping after [`Coordinator::begin_shutdown`].
    pub drain_ms: u64,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self {
            max_queue: 0,
            max_concurrency: 0,
            max_prompt_tokens: 0,
            default_deadline_ms: 0,
            drain_ms: 5000,
        }
    }
}

impl AdmissionPolicy {
    /// The serve path's policy: every knob comes from the engine config
    /// (CLI flags / config JSON), where `max_queue` defaults to 64.
    pub fn from_config(cfg: &EngineConfig) -> Self {
        Self {
            max_queue: cfg.max_queue,
            max_concurrency: cfg.max_concurrency,
            max_prompt_tokens: cfg.max_prompt_tokens,
            default_deadline_ms: cfg.deadline_ms,
            drain_ms: cfg.drain_ms,
        }
    }
}

/// Everything [`Coordinator::spawn_cfg`] needs beyond the engine factory.
pub struct CoordinatorConfig {
    pub policy: BatchPolicy,
    pub admission: AdmissionPolicy,
    /// Prefix-state cache the coordinator thread owns across requests.
    pub cache: Option<StateCache>,
    /// Statefile for the cache (load at startup, save at shutdown).
    pub state_file: Option<PathBuf>,
    /// Test-only fault-injection plan ([`crate::testutil::faults`]):
    /// deterministic engine-round errors and artificially slow rounds.
    /// Production callers leave this `None`.
    pub faults: Option<FaultPlan>,
    /// Round-trace ring capacity (`0` = no ring, unless `trace_out`
    /// forces one at [`crate::metrics::trace::DEFAULT_CAPACITY`]).
    pub trace_capacity: usize,
    /// Write the trace ring as JSONL to this path at shutdown.
    pub trace_out: Option<PathBuf>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            policy: BatchPolicy::default(),
            admission: AdmissionPolicy::default(),
            cache: None,
            state_file: None,
            faults: None,
            trace_capacity: 0,
            trace_out: None,
        }
    }
}

pub struct Coordinator {
    tx: Sender<Submission>,
    handle: Option<JoinHandle<()>>,
    pub metrics: Arc<Registry>,
    /// Bounded per-round flight recorder (`None` unless tracing was
    /// requested via [`CoordinatorConfig`]).
    pub trace: Option<Arc<TraceRing>>,
    admission: AdmissionPolicy,
    gate: Arc<Gate>,
}

impl Coordinator {
    /// Spawn the coordinator thread; the engine is CONSTRUCTED on that
    /// thread (PJRT handles are not `Send`, so an engine cannot cross
    /// threads — the factory pattern keeps both backends usable).
    pub fn spawn<F>(factory: F, policy: BatchPolicy) -> Self
    where
        F: FnOnce() -> Result<RwkvEngine> + Send + 'static,
    {
        Self::spawn_cfg(factory, CoordinatorConfig { policy, ..CoordinatorConfig::default() })
    }

    /// [`Coordinator::spawn`] with a prefix-state cache: the coordinator
    /// thread owns ONE cache shared across all requests — lookups fork
    /// new sessions off cached prompt prefixes, and prefill chunk
    /// boundaries insert snapshots.  Because the cache lives behind the
    /// existing single-round-thread model, the hot path pays no extra
    /// locking.  With `state_file`, snapshots load from that path at
    /// startup and save back when the coordinator shuts down.
    pub fn spawn_with_cache<F>(
        factory: F,
        policy: BatchPolicy,
        cache: Option<StateCache>,
        state_file: Option<PathBuf>,
    ) -> Self
    where
        F: FnOnce() -> Result<RwkvEngine> + Send + 'static,
    {
        Self::spawn_cfg(
            factory,
            CoordinatorConfig { policy, cache, state_file, ..CoordinatorConfig::default() },
        )
    }

    /// Fully-configured spawn: batching + admission bounds + cache +
    /// statefile + (tests only) fault injection.
    pub fn spawn_cfg<F>(factory: F, cfg: CoordinatorConfig) -> Self
    where
        F: FnOnce() -> Result<RwkvEngine> + Send + 'static,
    {
        let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
        let metrics = Arc::new(Registry::new());
        let m2 = Arc::clone(&metrics);
        let gate = Arc::new(Gate::new());
        let g2 = Arc::clone(&gate);
        let admission = cfg.admission;
        let trace = (cfg.trace_capacity > 0 || cfg.trace_out.is_some()).then(|| {
            let cap = if cfg.trace_capacity > 0 {
                cfg.trace_capacity
            } else {
                crate::metrics::trace::DEFAULT_CAPACITY
            };
            Arc::new(TraceRing::new(cap))
        });
        let t2 = trace.clone();
        let handle = std::thread::Builder::new()
            .name("rwkv-coordinator".into())
            .spawn(move || match factory() {
                Ok(mut engine) => run_loop(&mut engine, rx, cfg, &m2, &g2, t2),
                Err(e) => {
                    // refuse all submissions with the load error
                    let msg = format!("engine load failed: {e:#}");
                    while let Ok(sub) = rx.recv() {
                        let _ = sub.tx.send(Event::Error { message: msg.clone() });
                    }
                }
            })
            .expect("spawn coordinator");
        Self { tx, handle: Some(handle), metrics, trace, admission, gate }
    }

    /// Submit a request; returns a cancellable handle over the stream.
    /// Admission is bounded: past `max_queue` (or over the prompt limit,
    /// or during shutdown) the stream carries a single terminal
    /// [`Event::Rejected`] and no engine work happens.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let id = req.id;
        if let Err(reason) = self.try_enqueue(req, tx.clone(), Arc::clone(&cancel)) {
            self.metrics.inc("requests_rejected", 1);
            let retry_after_ms = match reason {
                RejectReason::Overloaded => self.retry_after_ms(),
                _ => 0,
            };
            let _ = tx.send(Event::Rejected { reason, retry_after_ms });
        }
        RequestHandle { id, rx, cancel }
    }

    /// The bounded-admission gate.  `Err` = shed (nothing was enqueued).
    fn try_enqueue(
        &self,
        req: Request,
        tx: Sender<Event>,
        cancel: Arc<AtomicBool>,
    ) -> std::result::Result<(), RejectReason> {
        if self.gate.is_draining() {
            return Err(RejectReason::ShuttingDown);
        }
        let limit = self.admission.max_prompt_tokens;
        if limit > 0 && req.prompt.len() > limit {
            return Err(RejectReason::PromptTooLong { tokens: req.prompt.len(), limit });
        }
        // reserve a queue slot (a CAS loop inside the gate, so a burst
        // cannot overshoot the bound — loom-checked in `gate.rs`)
        if !self.gate.try_reserve(self.admission.max_queue) {
            return Err(RejectReason::Overloaded);
        }
        self.metrics.set("queue_depth", self.gate.depth() as u64);
        let ms = req.deadline_ms.unwrap_or(self.admission.default_deadline_ms);
        let deadline = (ms > 0).then(|| Instant::now() + Duration::from_millis(ms));
        let sub = Submission { req, tx, cancel, queued: crate::util::Stopwatch::start(), deadline };
        if self.tx.send(sub).is_err() {
            // coordinator thread exited: release the slot, surface it
            self.gate.release();
            return Err(RejectReason::ShuttingDown);
        }
        Ok(())
    }

    /// Backoff hint for shed requests: queue depth × recent round time
    /// (a fresh coordinator with no round history suggests 50 ms).
    fn retry_after_ms(&self) -> u64 {
        let ns = self.gate.round_nanos();
        if ns == 0 {
            return 50;
        }
        let round_ms = (ns / 1_000_000).max(1);
        let depth = self.gate.depth() as u64;
        (round_ms * (depth + 1)).clamp(1, 60_000)
    }

    /// Begin graceful shutdown (the SIGINT/SIGTERM path): new
    /// submissions are rejected with `shutting_down`, in-flight sessions
    /// keep stepping for up to the drain budget (each still ends with a
    /// terminal `Done`), then the statefile is saved.  Non-blocking; use
    /// [`Coordinator::shutdown`] to also wait for the drain.
    pub fn begin_shutdown(&self) {
        self.gate.begin_drain();
    }

    /// [`Coordinator::begin_shutdown`] + wait for the coordinator thread
    /// to finish draining and persist its statefile.
    pub fn shutdown(&mut self) {
        self.begin_shutdown();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Convenience: run one request to completion.
    pub fn generate_blocking(&self, req: Request) -> Result<Vec<u32>> {
        let rx = self.submit(req);
        let mut out = Vec::new();
        for ev in rx {
            match ev {
                Event::Token { token } => out.push(token),
                Event::Done { .. } => break,
                Event::Error { message } => anyhow::bail!("generation failed: {message}"),
                Event::Rejected { reason, retry_after_ms } => anyhow::bail!(
                    "request rejected: {} (retry_after_ms={retry_after_ms})",
                    reason.wire_name()
                ),
            }
        }
        Ok(out)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // closing the channel ends the loop once queues drain
        let (dummy_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-session plumbing the engine does not need to know about.
struct Conn {
    tx: Sender<Event>,
    cancel: Arc<AtomicBool>,
    started: crate::util::Stopwatch,
    /// Feed tokens served from the prefix-state cache at admission.
    cached_tokens: usize,
    /// Absolute request deadline (checked at round boundaries).
    deadline: Option<Instant>,
    /// Queue wait measured at admission (span telemetry + `Done`).
    queue_secs: f64,
    /// Time to first token, set once at the first emission (`None` =
    /// nothing emitted yet).
    ttft_secs: Option<f64>,
    /// Service-clock time of the most recent emission — the per-token
    /// ITL is the delta between consecutive emissions.
    last_token_secs: f64,
}

/// Fingerprint for the prefix-state cache's statefile: model name plus
/// checkpoint size + mtime.  Shape checks alone cannot distinguish a
/// fine-tuned checkpoint (identical dims, different weights) whose cached
/// states would silently break warm==cold bit-identity; re-exporting the
/// `.rkv` changes the mtime and invalidates the file.
fn model_tag(engine: &RwkvEngine) -> String {
    let rkv = engine.store.manifest.rkv_path();
    let (len, mtime) = std::fs::metadata(&rkv)
        .map(|m| {
            let secs = m
                .modified()
                .ok()
                .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                .map(|d| d.as_secs())
                .unwrap_or(0);
            (m.len(), secs)
        })
        .unwrap_or((0, 0));
    format!("{}:{len}:{mtime}", engine.cfg.model)
}

/// Mirror the cache's counters into the coordinator registry
/// (`cache_bytes` is a gauge — current residency; the rest are
/// monotonic).
fn sync_cache_metrics(cache: &StateCache, metrics: &Registry) {
    let st = cache.stats();
    metrics.set("cache_hits", st.hits);
    metrics.set("cache_misses", st.misses);
    metrics.set("cache_hit_tokens", st.hit_tokens);
    metrics.set("cache_insertions", st.insertions);
    metrics.set("cache_evictions", st.evictions);
    metrics.set("cache_bytes", cache.bytes());
}

/// Overload degradation: with `queued` requests waiting behind a FULL
/// slot set, prefill chunks shrink (halving per waiting request, floor
/// 1) so decode sessions reach their next token sooner — round latency
/// is roughly linear in planned rows.  Chunking never changes the math
/// (`tests/prefill_equivalence.rs`), so admitted streams stay
/// bit-identical; an un-pressured loop always uses the full chunk.
fn degraded_chunk(base: usize, queued: usize, in_flight: usize, max_live: usize) -> usize {
    if queued == 0 || in_flight < max_live {
        return base;
    }
    (base >> queued.min(8)).max(1)
}

fn run_loop(
    engine: &mut RwkvEngine,
    rx: Receiver<Submission>,
    cfg: CoordinatorConfig,
    metrics: &Arc<Registry>,
    gate: &Gate,
    trace: Option<Arc<TraceRing>>,
) {
    let CoordinatorConfig { policy, admission, mut cache, state_file, faults, trace_out, .. } = cfg;
    // one registry for both sides: engine-side series (simd_backend_id,
    // round_*_secs phase splits, prefetch counters) land where the
    // server's /metrics scrape can see them
    engine.adopt_metrics(Arc::clone(metrics));
    // warm the cache from a previous run's snapshots — fingerprint- and
    // shape-filtered, so a state file written by a different model (even a
    // same-shape fine-tune) cannot plant stale snapshots on live prefixes
    // (missing file = cold start; a mismatched or corrupt file is
    // reported and ignored, never fatal)
    let tag = cache.as_ref().map(|_| model_tag(engine)).unwrap_or_default();
    if let (Some(c), Some(path)) = (cache.as_mut(), state_file.as_ref()) {
        match c.load_matching(path, &tag, &engine.new_state()) {
            Ok(n) if n > 0 => {
                eprintln!("[coordinator] loaded {n} state snapshots from {}", path.display())
            }
            Ok(_) => {}
            Err(e) => eprintln!("[coordinator] state file {} ignored: {e:#}", path.display()),
        }
    }
    let max_live = if admission.max_concurrency > 0 {
        admission.max_concurrency
    } else {
        policy.max_batch
    };
    let base_chunk = engine.cfg.prefill_chunk.max(1);
    let mut batcher = DynamicBatcher::new(policy);
    let mut sessions: Vec<Session> = Vec::new();
    let mut conns: Vec<Conn> = Vec::new();
    let mut round_index: u64 = 0;
    let mut drain_deadline: Option<Instant> = None;
    // loop-relative clock for trace timestamps
    let loop_clock = crate::util::Stopwatch::start();
    loop {
        let draining = gate.is_draining();
        if draining && drain_deadline.is_none() {
            drain_deadline = Some(Instant::now() + Duration::from_millis(admission.drain_ms));
        }
        // submissions shed at THIS round boundary (drain races) — trace
        let mut shed_now = 0usize;
        // admit new work (bounded idle wait so drain flags stay observable)
        match batcher.admit(&rx, sessions.len(), max_live, IDLE_TICK) {
            batcher::Admit::Closed if sessions.is_empty() => break,
            batcher::Admit::Requests(subs) => {
                for s in subs {
                    gate.release();
                    metrics.set("queue_depth", gate.depth() as u64);
                    let queue_secs = s.queued.elapsed_secs();
                    metrics.observe("queue_wait_secs", queue_secs);
                    if draining {
                        // raced the shutdown flag into the queue: shed,
                        // never started
                        metrics.inc("requests_rejected", 1);
                        shed_now += 1;
                        let _ = s.tx.send(Event::Rejected {
                            reason: RejectReason::ShuttingDown,
                            retry_after_ms: 0,
                        });
                        continue;
                    }
                    metrics.inc("requests_admitted", 1);
                    if s.deadline.map(|d| Instant::now() >= d).unwrap_or(false) {
                        // expired while queued: admitted, retired before
                        // any engine work (still a terminal Done, so the
                        // accounting invariant holds)
                        metrics.inc("requests_deadline_exceeded", 1);
                        metrics.inc("finish_reason_deadline", 1);
                        metrics.observe("request_total_secs", queue_secs);
                        let _ = s.tx.send(Event::Done {
                            tokens: 0,
                            seconds: queue_secs,
                            reason: FinishReason::DeadlineExceeded,
                            cached_tokens: 0,
                            queue_secs,
                            ttft_secs: None,
                        });
                        continue;
                    }
                    let mut stop = s.req.stop_tokens.clone();
                    if !stop.contains(&crate::text::EOS) {
                        stop.push(crate::text::EOS);
                    }
                    // prefix-state cache lookup: fork off the longest
                    // cached prefix instead of prefilling from scratch
                    let (mut sess, cached_tokens) = match cache.as_mut() {
                        Some(c) if s.req.cache => {
                            Session::new_with_cache(engine, s.req.id, &s.req.prompt, c)
                        }
                        _ => (Session::new(engine, s.req.id, &s.req.prompt), 0),
                    };
                    sess.max_tokens = s.req.max_tokens;
                    sess.stop_tokens = stop;
                    sess.stop_seqs = s.req.stop_sequences.clone();
                    sess.use_cache = s.req.cache;
                    sess.sampler = Sampler::new(
                        s.req.temperature,
                        s.req.top_p,
                        s.req.seed.unwrap_or(s.req.id),
                    );
                    sessions.push(sess);
                    conns.push(Conn {
                        tx: s.tx,
                        cancel: s.cancel,
                        started: crate::util::Stopwatch::start(),
                        cached_tokens,
                        deadline: s.deadline,
                        queue_secs,
                        ttft_secs: None,
                        last_token_secs: 0.0,
                    });
                }
                if let Some(c) = cache.as_ref() {
                    sync_cache_metrics(c, metrics);
                }
            }
            _ => {}
        }
        if sessions.is_empty() {
            if draining {
                // drained: shed whatever is still queued, then exit
                while let Ok(s) = rx.try_recv() {
                    gate.release();
                    metrics.inc("requests_rejected", 1);
                    let _ = s.tx.send(Event::Rejected {
                        reason: RejectReason::ShuttingDown,
                        retry_after_ms: 0,
                    });
                }
                break;
            }
            continue;
        }
        // round-boundary retirement checks: client cancellations, the
        // drain budget, per-request deadlines
        let now = Instant::now();
        let drain_expired = drain_deadline.map(|d| now >= d).unwrap_or(false);
        for (sess, conn) in sessions.iter_mut().zip(&conns) {
            if conn.cancel.load(Ordering::Relaxed) {
                sess.cancel();
            } else if drain_expired {
                // drain budget exhausted: hard-stop the stragglers (each
                // still gets a terminal Done below)
                sess.cancel();
            } else if conn.deadline.map(|d| now >= d).unwrap_or(false) {
                sess.finish(FinishReason::DeadlineExceeded);
            }
        }
        // SLO degradation: decode-priority under queue pressure
        let queued_now = gate.depth();
        engine.cfg.prefill_chunk = degraded_chunk(base_chunk, queued_now, sessions.len(), max_live);
        // test-only fault hook: deterministic slow rounds / round errors
        let injected = match faults.as_ref() {
            Some(plan) => {
                if let Some(pause) = plan.slow_round_delay(round_index) {
                    std::thread::sleep(pause);
                }
                plan.round_error(round_index)
            }
            None => None,
        };
        round_index += 1;
        // ONE engine call per scheduling round: chunked prefill + batched
        // decode + sampling + stop checks all happen inside step_round
        let round = crate::util::Stopwatch::start();
        let result = match injected {
            Some(e) => Err(e),
            None => engine.step_round_cached(&mut sessions, cache.as_mut()),
        };
        let report = match result {
            Ok(r) => r,
            Err(e) => {
                // a round error is engine-global (the fused pass serves
                // every session): every in-flight stream gets the error,
                // then terminates with a Cancelled Done so per-request
                // accounting (admitted = completed + cancelled +
                // deadline_exceeded) stays consistent
                let cancelled_now = sessions.len();
                for (sess, conn) in sessions.iter().zip(&conns) {
                    let _ = conn.tx.send(Event::Error { message: e.to_string() });
                    let service_secs = conn.started.elapsed_secs();
                    metrics.inc("requests_cancelled", 1);
                    metrics.inc("finish_reason_cancelled", 1);
                    metrics.inc("tokens_out", sess.tokens_produced() as u64);
                    metrics.observe("request_total_secs", conn.queue_secs + service_secs);
                    let _ = conn.tx.send(Event::Done {
                        tokens: sess.tokens_produced(),
                        seconds: service_secs,
                        reason: FinishReason::Cancelled,
                        cached_tokens: conn.cached_tokens,
                        queue_secs: conn.queue_secs,
                        ttft_secs: conn.ttft_secs,
                    });
                }
                if let Some(ring) = trace.as_ref() {
                    ring.push(RoundTrace {
                        round: round_index,
                        at_secs: loop_clock.elapsed_secs(),
                        sessions: cancelled_now,
                        chunk: engine.cfg.prefill_chunk,
                        queue_depth: queued_now,
                        round_secs: round.elapsed_secs(),
                        cancelled: cancelled_now,
                        shed: shed_now,
                        round_error: true,
                        ..RoundTrace::default()
                    });
                }
                sessions.clear();
                conns.clear();
                continue;
            }
        };
        let round_secs = round.elapsed_secs();
        // EWMA round time feeds the submit-side retry_after_ms hint
        gate.note_round_nanos((round_secs * 1e9) as u64);
        metrics.inc("rounds", 1);
        // distinct from the engine's own `round_secs` (pure engine time):
        // this one includes scheduling overhead and injected fault delay
        metrics.observe("coord_round_secs", round_secs);
        metrics.inc("round_weight_bytes", report.round_weight_bytes);
        metrics.inc("prefill_tokens", report.prefill_tokens as u64);
        metrics.inc("decode_tokens", report.decode_tokens as u64);
        if let Some(c) = cache.as_ref() {
            sync_cache_metrics(c, metrics);
        }
        let in_flight = sessions.len();
        for em in &report.emitted {
            let conn = &mut conns[em.session];
            // per-request span points, measured at the round boundary:
            // first emission fixes TTFT (split by prefix-cache warmth so
            // the state cache's win shows up as a latency delta), later
            // emissions record the inter-token gap
            let at = conn.started.elapsed_secs();
            match conn.ttft_secs {
                None => {
                    conn.ttft_secs = Some(at);
                    metrics.observe("ttft_secs", at);
                    if conn.cached_tokens > 0 {
                        metrics.observe("ttft_warm_secs", at);
                    } else {
                        metrics.observe("ttft_cold_secs", at);
                    }
                }
                Some(_) => metrics.observe("itl_secs", at - conn.last_token_secs),
            }
            conn.last_token_secs = at;
            if conn.tx.send(Event::Token { token: em.token }).is_err() {
                // the client went away: stop paying weight passes for it
                sessions[em.session].cancel();
            }
        }
        // retire finished sessions (round stops + cancellations +
        // deadline expiries)
        let (mut completed_now, mut cancelled_now, mut deadline_now) = (0usize, 0usize, 0usize);
        for i in (0..sessions.len()).rev() {
            let reason = match sessions[i].finish_reason() {
                Some(r) => r,
                None => continue,
            };
            let sess = sessions.remove(i);
            let conn = conns.remove(i);
            match reason {
                FinishReason::Cancelled => {
                    metrics.inc("requests_cancelled", 1);
                    cancelled_now += 1;
                }
                FinishReason::DeadlineExceeded => {
                    metrics.inc("requests_deadline_exceeded", 1);
                    deadline_now += 1;
                }
                _ => {
                    metrics.inc("requests_completed", 1);
                    completed_now += 1;
                }
            }
            metrics.inc(&format!("finish_reason_{}", reason.name()), 1);
            metrics.inc("tokens_out", sess.tokens_produced() as u64);
            let service_secs = conn.started.elapsed_secs();
            metrics.observe("request_total_secs", conn.queue_secs + service_secs);
            let _ = conn.tx.send(Event::Done {
                tokens: sess.tokens_produced(),
                seconds: service_secs,
                reason,
                cached_tokens: conn.cached_tokens,
                queue_secs: conn.queue_secs,
                ttft_secs: conn.ttft_secs,
            });
        }
        if let Some(ring) = trace.as_ref() {
            ring.push(RoundTrace {
                round: round_index,
                at_secs: loop_clock.elapsed_secs(),
                sessions: in_flight,
                prefill_tokens: report.prefill_tokens,
                decode_tokens: report.decode_tokens,
                chunk: engine.cfg.prefill_chunk,
                queue_depth: queued_now,
                round_secs,
                weight_bytes: report.round_weight_bytes,
                emitted: report.emitted.len(),
                completed: completed_now,
                cancelled: cancelled_now,
                deadline_expired: deadline_now,
                shed: shed_now,
                wkv_secs: engine.last_stats.wkv_secs,
                matmul_secs: engine.last_stats.matmul_secs,
                head_secs: engine.last_stats.head_secs,
                block_load_secs: engine.last_stats.block_load_secs,
                prefetch_wait_secs: engine.last_stats.prefetch_wait_secs,
                round_error: false,
            });
        }
    }
    // restore the configured chunk (the loop may exit mid-degradation)
    engine.cfg.prefill_chunk = base_chunk;
    // export the flight recorder for offline timeline analysis
    // (best-effort, like the statefile save below)
    if let (Some(ring), Some(path)) = (trace.as_ref(), trace_out.as_ref()) {
        match ring.write_jsonl(path) {
            Ok(()) => eprintln!(
                "[coordinator] wrote {} round traces to {} ({} dropped past capacity)",
                ring.len(),
                path.display(),
                ring.dropped()
            ),
            Err(e) => eprintln!("[coordinator] trace export failed: {e:#}"),
        }
    }
    // persist the warm cache for the next process (best-effort: a failed
    // save only loses warmth, never correctness)
    if let (Some(c), Some(path)) = (cache.as_ref(), state_file.as_ref()) {
        match c.save(path, &tag) {
            Ok(n) => eprintln!("[coordinator] saved {n} state snapshots to {}", path.display()),
            Err(e) => eprintln!("[coordinator] state file save failed: {e:#}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degraded_chunk_shrinks_only_under_pressure() {
        // no queue -> full chunk, whatever the occupancy
        assert_eq!(degraded_chunk(8, 0, 4, 4), 8);
        // queue but free slots -> still full chunk
        assert_eq!(degraded_chunk(8, 3, 2, 4), 8);
        // full slots + queue -> halve per waiting request, floor 1
        assert_eq!(degraded_chunk(8, 1, 4, 4), 4);
        assert_eq!(degraded_chunk(8, 2, 4, 4), 2);
        assert_eq!(degraded_chunk(8, 3, 4, 4), 1);
        assert_eq!(degraded_chunk(8, 100, 4, 4), 1);
        assert_eq!(degraded_chunk(1, 5, 4, 4), 1);
    }

    #[test]
    fn reject_reason_wire_names() {
        assert_eq!(RejectReason::Overloaded.wire_name(), "overloaded");
        assert_eq!(
            RejectReason::PromptTooLong { tokens: 10, limit: 4 }.wire_name(),
            "prompt_too_long"
        );
        assert_eq!(RejectReason::ShuttingDown.wire_name(), "shutting_down");
    }

    #[test]
    fn admission_policy_from_config() {
        let cfg = EngineConfig {
            max_queue: 3,
            max_concurrency: 2,
            max_prompt_tokens: 100,
            deadline_ms: 750,
            drain_ms: 1234,
            ..EngineConfig::default()
        };
        let p = AdmissionPolicy::from_config(&cfg);
        assert_eq!(p.max_queue, 3);
        assert_eq!(p.max_concurrency, 2);
        assert_eq!(p.max_prompt_tokens, 100);
        assert_eq!(p.default_deadline_ms, 750);
        assert_eq!(p.drain_ms, 1234);
    }
}
