//! Request coordinator (S21): router + dynamic batcher + round scheduler.
//!
//! Edge-serving shape: one engine (one device) advances a set of
//! concurrent [`Session`]s round-robin (continuous batching: new requests
//! join mid-flight).  The whole loop is one call per round —
//! `RwkvEngine::step_round` — which fuses prompt-phase sessions (chunked
//! `(B', T)` prefill) and decode-phase sessions into a SINGLE pass over
//! the weights: every projection, FFN matrix and the head stream from
//! storage once per round and serve every row while hot, so dense-layer
//! bytes-per-round are constant in the number of sessions and aggregate
//! tok/s scales with the batch.  The §3.2 sparse FFN unions predicted
//! rows across all prompt and decode rows of the round (each row masked
//! to its own set — bit-identical to the per-slot path).  Sampling and
//! stop-token checking happen inside the round; this loop only routes the
//! emitted tokens to their streams.
//!
//! Lifecycle: [`Coordinator::submit`] returns a [`RequestHandle`] whose
//! `cancel()` retires the session at the next round boundary; a client
//! that drops its handle mid-stream is detected via `Event` send failure
//! and retired the same way ([`FinishReason::Cancelled`]).
//!
//! Per-round telemetry in the coordinator registry: `rounds`,
//! `round_seconds`, `round_weight_bytes`, `prefill_tokens`,
//! `decode_tokens`, `requests_admitted` / `requests_completed` /
//! `requests_cancelled`, `tokens_out`.  With a prefix-state cache
//! ([`Coordinator::spawn_with_cache`]): `cache_hits` / `cache_misses` /
//! `cache_hit_tokens` / `cache_insertions` / `cache_evictions` plus the
//! `cache_bytes` residency gauge.
//!
//! Topology: N client threads -> mpsc -> coordinator thread (owns the
//! engine) -> per-request streaming channels.  Intra-round compute
//! parallelism lives BELOW this loop: the engine factory is handed a
//! [`crate::pool::ThreadPool`] handle (`RwkvEngine::load_with_pool`, the
//! `--threads` knob) and every `step_round` fans its kernels, per-slot
//! WKV recurrence and predictor out over those workers — the coordinator
//! thread stays the only place sessions are mutated between rounds.

pub mod batcher;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::sampler::Sampler;
use crate::engine::session::Session;
use crate::engine::state_cache::StateCache;
use crate::engine::RwkvEngine;
use crate::metrics::Registry;
use batcher::{BatchPolicy, DynamicBatcher};

pub use crate::engine::session::FinishReason;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// Extra stop token ids (EOS always stops; the stop token is emitted).
    pub stop_tokens: Vec<u32>,
    /// Multi-token stop sequences (suffix match over emitted tokens; the
    /// matching tokens are emitted, then the stream ends with
    /// `reason: "stop"`).
    pub stop_sequences: Vec<Vec<u32>>,
    /// Explicit sampler seed; `None` falls back to the request id.
    pub seed: Option<u64>,
    /// Participate in the coordinator's prefix-state cache (fork from the
    /// longest cached prompt prefix AND contribute snapshots).  Ignored
    /// when the coordinator has no cache.  Default `true`.
    pub cache: bool,
}

impl Default for Request {
    fn default() -> Self {
        Self {
            id: 0,
            prompt: Vec::new(),
            max_tokens: 32,
            temperature: 0.0,
            top_p: 1.0,
            stop_tokens: Vec::new(),
            stop_sequences: Vec::new(),
            seed: None,
            cache: true,
        }
    }
}

/// Streamed events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    Token { token: u32 },
    Done { tokens: usize, seconds: f64, reason: FinishReason, cached_tokens: usize },
    Error { message: String },
}

pub(crate) struct Submission {
    pub(crate) req: Request,
    pub(crate) tx: Sender<Event>,
    pub(crate) cancel: Arc<AtomicBool>,
}

/// Client side of a submitted request: the event stream plus a cancel
/// switch.  Dropping the handle (or its iterator) also cancels — the
/// coordinator notices the dead stream on the next emitted token.
pub struct RequestHandle {
    pub id: u64,
    rx: Receiver<Event>,
    cancel: Arc<AtomicBool>,
}

impl RequestHandle {
    /// Ask the coordinator to retire this request at the next round
    /// boundary; the stream then ends with `Done { reason: Cancelled }`.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// Next event, or `None` once the stream is closed.
    pub fn recv(&self) -> Option<Event> {
        self.rx.recv().ok()
    }

    /// Borrowing event iterator (keeps the handle, so `cancel()` stays
    /// available mid-stream).
    pub fn iter(&self) -> std::sync::mpsc::Iter<'_, Event> {
        self.rx.iter()
    }
}

impl IntoIterator for RequestHandle {
    type Item = Event;
    type IntoIter = std::sync::mpsc::IntoIter<Event>;

    fn into_iter(self) -> Self::IntoIter {
        self.rx.into_iter()
    }
}

impl<'a> IntoIterator for &'a RequestHandle {
    type Item = Event;
    type IntoIter = std::sync::mpsc::Iter<'a, Event>;

    fn into_iter(self) -> Self::IntoIter {
        self.rx.iter()
    }
}

pub struct Coordinator {
    tx: Sender<Submission>,
    handle: Option<JoinHandle<()>>,
    pub metrics: Arc<Registry>,
}

impl Coordinator {
    /// Spawn the coordinator thread; the engine is CONSTRUCTED on that
    /// thread (PJRT handles are not `Send`, so an engine cannot cross
    /// threads — the factory pattern keeps both backends usable).
    pub fn spawn<F>(factory: F, policy: BatchPolicy) -> Self
    where
        F: FnOnce() -> Result<RwkvEngine> + Send + 'static,
    {
        Self::spawn_with_cache(factory, policy, None, None)
    }

    /// [`Coordinator::spawn`] with a prefix-state cache: the coordinator
    /// thread owns ONE cache shared across all requests — lookups fork
    /// new sessions off cached prompt prefixes, and prefill chunk
    /// boundaries insert snapshots.  Because the cache lives behind the
    /// existing single-round-thread model, the hot path pays no extra
    /// locking.  With `state_file`, snapshots load from that path at
    /// startup and save back when the coordinator shuts down.
    pub fn spawn_with_cache<F>(
        factory: F,
        policy: BatchPolicy,
        cache: Option<StateCache>,
        state_file: Option<PathBuf>,
    ) -> Self
    where
        F: FnOnce() -> Result<RwkvEngine> + Send + 'static,
    {
        let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
        let metrics = Arc::new(Registry::new());
        let m2 = Arc::clone(&metrics);
        let handle = std::thread::Builder::new()
            .name("rwkv-coordinator".into())
            .spawn(move || match factory() {
                Ok(mut engine) => run_loop(&mut engine, rx, policy, &m2, cache, state_file),
                Err(e) => {
                    // refuse all submissions with the load error
                    let msg = format!("engine load failed: {e:#}");
                    while let Ok(sub) = rx.recv() {
                        let _ = sub.tx.send(Event::Error { message: msg.clone() });
                    }
                }
            })
            .expect("spawn coordinator");
        Self { tx, handle: Some(handle), metrics }
    }

    /// Submit a request; returns a cancellable handle over the stream.
    pub fn submit(&self, req: Request) -> RequestHandle {
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let id = req.id;
        // A send failure means the coordinator thread exited; surface it
        // on the stream instead of panicking.
        let sub = Submission { req, tx: tx.clone(), cancel: Arc::clone(&cancel) };
        if self.tx.send(sub).is_err() {
            let _ = tx.send(Event::Error { message: "coordinator stopped".into() });
        }
        RequestHandle { id, rx, cancel }
    }

    /// Convenience: run one request to completion.
    pub fn generate_blocking(&self, req: Request) -> Result<Vec<u32>> {
        let rx = self.submit(req);
        let mut out = Vec::new();
        for ev in rx {
            match ev {
                Event::Token { token } => out.push(token),
                Event::Done { .. } => break,
                Event::Error { message } => anyhow::bail!("generation failed: {message}"),
            }
        }
        Ok(out)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // closing the channel ends the loop once queues drain
        let (dummy_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Per-session plumbing the engine does not need to know about.
struct Conn {
    tx: Sender<Event>,
    cancel: Arc<AtomicBool>,
    started: crate::util::Stopwatch,
    /// Feed tokens served from the prefix-state cache at admission.
    cached_tokens: usize,
}

/// Fingerprint for the prefix-state cache's statefile: model name plus
/// checkpoint size + mtime.  Shape checks alone cannot distinguish a
/// fine-tuned checkpoint (identical dims, different weights) whose cached
/// states would silently break warm==cold bit-identity; re-exporting the
/// `.rkv` changes the mtime and invalidates the file.
fn model_tag(engine: &RwkvEngine) -> String {
    let rkv = engine.store.manifest.rkv_path();
    let (len, mtime) = std::fs::metadata(&rkv)
        .map(|m| {
            let secs = m
                .modified()
                .ok()
                .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
                .map(|d| d.as_secs())
                .unwrap_or(0);
            (m.len(), secs)
        })
        .unwrap_or((0, 0));
    format!("{}:{len}:{mtime}", engine.cfg.model)
}

/// Mirror the cache's counters into the coordinator registry
/// (`cache_bytes` is a gauge — current residency; the rest are
/// monotonic).
fn sync_cache_metrics(cache: &StateCache, metrics: &Registry) {
    let st = cache.stats();
    metrics.set("cache_hits", st.hits);
    metrics.set("cache_misses", st.misses);
    metrics.set("cache_hit_tokens", st.hit_tokens);
    metrics.set("cache_insertions", st.insertions);
    metrics.set("cache_evictions", st.evictions);
    metrics.set("cache_bytes", cache.bytes());
}

fn run_loop(
    engine: &mut RwkvEngine,
    rx: Receiver<Submission>,
    policy: BatchPolicy,
    metrics: &Registry,
    mut cache: Option<StateCache>,
    state_file: Option<PathBuf>,
) {
    // warm the cache from a previous run's snapshots — fingerprint- and
    // shape-filtered, so a state file written by a different model (even a
    // same-shape fine-tune) cannot plant stale snapshots on live prefixes
    // (missing file = cold start; a mismatched or corrupt file is
    // reported and ignored, never fatal)
    let tag = cache.as_ref().map(|_| model_tag(engine)).unwrap_or_default();
    if let (Some(c), Some(path)) = (cache.as_mut(), state_file.as_ref()) {
        match c.load_matching(path, &tag, &engine.new_state()) {
            Ok(n) if n > 0 => {
                eprintln!("[coordinator] loaded {n} state snapshots from {}", path.display())
            }
            Ok(_) => {}
            Err(e) => eprintln!("[coordinator] state file {} ignored: {e:#}", path.display()),
        }
    }
    let mut batcher = DynamicBatcher::new(policy);
    let mut sessions: Vec<Session> = Vec::new();
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        // admit new work (blocking when idle, draining when busy)
        match batcher.admit(&rx, sessions.len()) {
            batcher::Admit::Closed if sessions.is_empty() => break,
            batcher::Admit::Requests(subs) => {
                for s in subs {
                    metrics.inc("requests_admitted", 1);
                    let mut stop = s.req.stop_tokens.clone();
                    if !stop.contains(&crate::text::EOS) {
                        stop.push(crate::text::EOS);
                    }
                    // prefix-state cache lookup: fork off the longest
                    // cached prefix instead of prefilling from scratch
                    let (mut sess, cached_tokens) = match cache.as_mut() {
                        Some(c) if s.req.cache => {
                            Session::new_with_cache(engine, s.req.id, &s.req.prompt, c)
                        }
                        _ => (Session::new(engine, s.req.id, &s.req.prompt), 0),
                    };
                    sess.max_tokens = s.req.max_tokens;
                    sess.stop_tokens = stop;
                    sess.stop_seqs = s.req.stop_sequences.clone();
                    sess.use_cache = s.req.cache;
                    sess.sampler = Sampler::new(
                        s.req.temperature,
                        s.req.top_p,
                        s.req.seed.unwrap_or(s.req.id),
                    );
                    sessions.push(sess);
                    conns.push(Conn {
                        tx: s.tx,
                        cancel: s.cancel,
                        started: crate::util::Stopwatch::start(),
                        cached_tokens,
                    });
                }
                if let Some(c) = cache.as_ref() {
                    sync_cache_metrics(c, metrics);
                }
            }
            _ => {}
        }
        if sessions.is_empty() {
            continue;
        }
        // apply client-side cancellations before stepping
        for (sess, conn) in sessions.iter_mut().zip(&conns) {
            if conn.cancel.load(Ordering::Relaxed) {
                sess.cancel();
            }
        }
        // ONE engine call per scheduling round: chunked prefill + batched
        // decode + sampling + stop checks all happen inside step_round
        let round = crate::util::Stopwatch::start();
        let report = match engine.step_round_cached(&mut sessions, cache.as_mut()) {
            Ok(r) => r,
            Err(e) => {
                // a round error is engine-global (the fused pass serves
                // every session): every in-flight stream gets the error,
                // then terminates with a Cancelled Done so per-request
                // accounting (admitted = completed + cancelled) stays
                // consistent
                for (sess, conn) in sessions.iter().zip(&conns) {
                    let _ = conn.tx.send(Event::Error { message: e.to_string() });
                    let _ = conn.tx.send(Event::Done {
                        tokens: sess.tokens_produced(),
                        seconds: conn.started.elapsed_secs(),
                        reason: FinishReason::Cancelled,
                        cached_tokens: conn.cached_tokens,
                    });
                    metrics.inc("requests_cancelled", 1);
                    metrics.inc("tokens_out", sess.tokens_produced() as u64);
                }
                sessions.clear();
                conns.clear();
                continue;
            }
        };
        metrics.inc("rounds", 1);
        metrics.observe("round_seconds", round.elapsed_secs());
        metrics.inc("round_weight_bytes", report.round_weight_bytes);
        metrics.inc("prefill_tokens", report.prefill_tokens as u64);
        metrics.inc("decode_tokens", report.decode_tokens as u64);
        if let Some(c) = cache.as_ref() {
            sync_cache_metrics(c, metrics);
        }
        for em in &report.emitted {
            if conns[em.session].tx.send(Event::Token { token: em.token }).is_err() {
                // the client went away: stop paying weight passes for it
                sessions[em.session].cancel();
            }
        }
        // retire finished sessions (round stops + cancellations)
        for i in (0..sessions.len()).rev() {
            let reason = match sessions[i].finish_reason() {
                Some(r) => r,
                None => continue,
            };
            let sess = sessions.remove(i);
            let conn = conns.remove(i);
            if reason == FinishReason::Cancelled {
                metrics.inc("requests_cancelled", 1);
            } else {
                metrics.inc("requests_completed", 1);
            }
            metrics.inc("tokens_out", sess.tokens_produced() as u64);
            let _ = conn.tx.send(Event::Done {
                tokens: sess.tokens_produced(),
                seconds: conn.started.elapsed_secs(),
                reason,
                cached_tokens: conn.cached_tokens,
            });
        }
    }
    // persist the warm cache for the next process (best-effort: a failed
    // save only loses warmth, never correctness)
    if let (Some(c), Some(path)) = (cache.as_ref(), state_file.as_ref()) {
        match c.save(path, &tag) {
            Ok(n) => eprintln!("[coordinator] saved {n} state snapshots to {}", path.display()),
            Err(e) => eprintln!("[coordinator] state file save failed: {e:#}"),
        }
    }
}
