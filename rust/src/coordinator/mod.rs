//! Request coordinator (S21): router + dynamic batcher + decode scheduler.
//!
//! Edge-serving shape: one engine (one device) decodes a *batch* of
//! concurrent requests round-robin, one token each per scheduling round
//! (continuous batching: new requests join mid-flight).
//!
//! Batched decode design (one weight pass per round): decode-phase slots
//! advance through `RwkvEngine::forward_tokens_batch`, which keeps all B
//! activations in a `(B, D)` scratch and drives every projection, FFN
//! matrix and the head through the tensor::matmat multi-vector kernels —
//! each weight row streams from storage ONCE per round and serves every
//! slot while hot, so dense-layer bytes-per-round are constant in B and
//! aggregate tok/s scales with the batch.  The §3.2 sparse FFN is fused
//! across the round (the PowerInfer-style amortization): per-slot
//! predictor index sets are UNIONED, one pass over the union rows computes
//! every slot's activations (each slot masked to its own predicted set, so
//! results stay bit-identical to the per-slot path), and the union bytes
//! are what residency accounting charges.  Per-round telemetry
//! (`decode_rounds`, `decode_round_weight_bytes`, `decode_slot_tokens`)
//! lands in the coordinator registry for benches and dashboards.
//!
//! Topology: N client threads -> mpsc -> coordinator thread (owns the
//! engine) -> per-request streaming channels.

pub mod batcher;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::engine::sampler::Sampler;
use crate::engine::{state::RwkvState, RwkvEngine};
use crate::metrics::Registry;
use batcher::{BatchPolicy, DynamicBatcher};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_p: f32,
}

/// Streamed events for one request.
#[derive(Clone, Debug)]
pub enum Event {
    Token { token: u32 },
    Done { tokens: usize, seconds: f64 },
    Error { message: String },
}

pub(crate) struct Submission {
    pub(crate) req: Request,
    pub(crate) tx: Sender<Event>,
}

/// In-flight decode slot.
struct Slot {
    req: Request,
    tx: Sender<Event>,
    state: RwkvState,
    sampler: Sampler,
    last_token: u32,
    produced: usize,
    prompt_pos: usize,
    started: crate::util::Stopwatch,
}

pub struct Coordinator {
    tx: Sender<Submission>,
    handle: Option<JoinHandle<()>>,
    pub metrics: Arc<Registry>,
}

impl Coordinator {
    /// Spawn the coordinator thread; the engine is CONSTRUCTED on that
    /// thread (PJRT handles are not `Send`, so an engine cannot cross
    /// threads — the factory pattern keeps both backends usable).
    pub fn spawn<F>(factory: F, policy: BatchPolicy) -> Self
    where
        F: FnOnce() -> Result<RwkvEngine> + Send + 'static,
    {
        let (tx, rx): (Sender<Submission>, Receiver<Submission>) = channel();
        let metrics = Arc::new(Registry::new());
        let m2 = Arc::clone(&metrics);
        let handle = std::thread::Builder::new()
            .name("rwkv-coordinator".into())
            .spawn(move || match factory() {
                Ok(mut engine) => run_loop(&mut engine, rx, policy, &m2),
                Err(e) => {
                    // refuse all submissions with the load error
                    let msg = format!("engine load failed: {e:#}");
                    while let Ok(sub) = rx.recv() {
                        let _ = sub.tx.send(Event::Error { message: msg.clone() });
                    }
                }
            })
            .expect("spawn coordinator");
        Self { tx, handle: Some(handle), metrics }
    }

    /// Submit a request; returns the event stream receiver.
    pub fn submit(&self, req: Request) -> Receiver<Event> {
        let (tx, rx) = channel();
        // A send failure means the coordinator thread exited; surface it
        // on the stream instead of panicking.
        if self.tx.send(Submission { req, tx: tx.clone() }).is_err() {
            let _ = tx.send(Event::Error { message: "coordinator stopped".into() });
        }
        rx
    }

    /// Convenience: run one request to completion.
    pub fn generate_blocking(&self, req: Request) -> Result<Vec<u32>> {
        let rx = self.submit(req);
        let mut out = Vec::new();
        for ev in rx {
            match ev {
                Event::Token { token } => out.push(token),
                Event::Done { .. } => break,
                Event::Error { message } => anyhow::bail!("generation failed: {message}"),
            }
        }
        Ok(out)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // closing the channel ends the loop once queues drain
        let (dummy_tx, _) = channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_loop(
    engine: &mut RwkvEngine,
    rx: Receiver<Submission>,
    policy: BatchPolicy,
    metrics: &Registry,
) {
    let mut batcher = DynamicBatcher::new(policy);
    let mut slots: Vec<Slot> = Vec::new();
    loop {
        // admit new work (blocking when idle, draining when busy)
        let admitted = batcher.admit(&rx, slots.len());
        match admitted {
            batcher::Admit::Closed if slots.is_empty() => break,
            batcher::Admit::Requests(subs) => {
                for s in subs {
                    metrics.inc("requests_admitted", 1);
                    slots.push(Slot {
                        state: engine.new_state(),
                        sampler: Sampler::new(s.req.temperature, s.req.top_p, s.req.id),
                        last_token: crate::text::BOS,
                        produced: 0,
                        prompt_pos: 0,
                        started: crate::util::Stopwatch::start(),
                        req: s.req,
                        tx: s.tx,
                    });
                }
            }
            _ => {}
        }
        if slots.is_empty() {
            continue;
        }
        // one scheduling round: each slot advances one token.  Slots still
        // in prefill step individually; decode-phase slots advance as ONE
        // batched engine call (sparse-row unions amortize, see engine::
        // forward_tokens_batch).
        let round = crate::util::Stopwatch::start();
        let mut finished: Vec<usize> = Vec::new();
        let mut decode_idx: Vec<usize> = Vec::new();
        for (i, slot) in slots.iter_mut().enumerate() {
            if slot.prompt_pos < slot.req.prompt.len() {
                if let Err(e) = engine.forward_hidden(slot.last_token, &mut slot.state) {
                    let _ = slot.tx.send(Event::Error { message: e.to_string() });
                    finished.push(i);
                    continue;
                }
                slot.last_token = slot.req.prompt[slot.prompt_pos];
                slot.prompt_pos += 1;
            } else {
                decode_idx.push(i);
            }
        }
        if !decode_idx.is_empty() && engine.cfg.backend == crate::config::Backend::Xla {
            // XLA backend has no batched path: step slots individually
            for &i in &decode_idx {
                let slot = &mut slots[i];
                match engine.forward_token(slot.last_token, &mut slot.state) {
                    Ok(mut logits) => {
                        let tok = slot.sampler.sample(&mut logits);
                        slot.last_token = tok;
                        slot.produced += 1;
                        let _ = slot.tx.send(Event::Token { token: tok });
                        if slot.produced >= slot.req.max_tokens || tok == crate::text::EOS {
                            finished.push(i);
                        }
                    }
                    Err(e) => {
                        let _ = slot.tx.send(Event::Error { message: e.to_string() });
                        finished.push(i);
                    }
                }
            }
        } else if !decode_idx.is_empty() {
            // move states out so the batch call can borrow them together
            let tokens: Vec<u32> = decode_idx.iter().map(|&i| slots[i].last_token).collect();
            let mut states: Vec<RwkvState> = decode_idx
                .iter()
                .map(|&i| std::mem::replace(&mut slots[i].state, RwkvState::zero(0, 0, 1, 1)))
                .collect();
            match engine.forward_tokens_batch(&tokens, &mut states) {
                Ok(all_logits) => {
                    metrics.inc("decode_rounds", 1);
                    metrics.inc("decode_round_weight_bytes", engine.last_round_weight_bytes);
                    metrics.inc("decode_slot_tokens", tokens.len() as u64);
                    for ((&i, state), mut logits) in
                        decode_idx.iter().zip(states).zip(all_logits)
                    {
                        let slot = &mut slots[i];
                        slot.state = state;
                        let tok = slot.sampler.sample(&mut logits);
                        slot.last_token = tok;
                        slot.produced += 1;
                        let _ = slot.tx.send(Event::Token { token: tok });
                        if slot.produced >= slot.req.max_tokens || tok == crate::text::EOS {
                            finished.push(i);
                        }
                    }
                }
                Err(e) => {
                    for (&i, state) in decode_idx.iter().zip(states) {
                        let slot = &mut slots[i];
                        slot.state = state;
                        let _ = slot.tx.send(Event::Error { message: e.to_string() });
                        finished.push(i);
                    }
                }
            }
        }
        finished.sort_unstable();
        finished.dedup();
        metrics.observe("round_seconds", round.elapsed_secs());
        metrics.inc("rounds", 1);
        for &i in finished.iter().rev() {
            let slot = slots.remove(i);
            metrics.inc("requests_completed", 1);
            metrics.inc("tokens_out", slot.produced as u64);
            let _ = slot.tx.send(Event::Done {
                tokens: slot.produced,
                seconds: slot.started.elapsed_secs(),
            });
        }
    }
}
