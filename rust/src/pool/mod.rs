//! Fixed-size thread pool + scoped data-parallel sections (substrate S23
//! — no tokio, no rayon in this environment).
//!
//! Two kinds of work run here:
//!
//! * **Fire-and-forget / future-style jobs** — [`ThreadPool::spawn`] and
//!   [`ThreadPool::submit`], used for background work such as layerwise
//!   prefetch.  Jobs are `'static` boxed closures delivered over an mpsc
//!   channel that all workers drain.
//! * **Scoped data-parallel sections** — [`ThreadPool::parallel_for`],
//!   the intra-round compute path.  The closure may borrow stack data
//!   (weights, activation buffers): the call does not return until every
//!   chunk has finished, so the borrows stay valid without `Arc`/clone.
//!
//! # Scheduling
//!
//! `parallel_for(n, f)` splits `0..n` into `workers() + 1` contiguous
//! chunks by **deterministic static chunking**: chunk sizes depend only on
//! `n` and the pool size (`n / lanes` items each, the first `n % lanes`
//! chunks take one extra), never on runtime timing.  Chunk 0 runs inline
//! on the calling thread; the rest are dispatched to workers.  No closure
//! is boxed per call — a chunk descriptor is a small plain struct — so a
//! section adds no per-call heap allocation beyond the channel node.
//!
//! Work assignment is static, not work-stealing: for the engine's use
//! (equal-cost output rows / slots) this is both faster and — more
//! importantly — *reproducible*.  Numerical determinism, however, does not
//! depend on the chunking at all: callers only ever shard work whose
//! per-element reduction order is unchanged by the split (see
//! `tensor::matmat`), so results are bit-identical for every pool size,
//! including the inline `threads = 1` path.
//!
//! # Panic semantics
//!
//! A panicking job never takes a worker down (every job runs under
//! `catch_unwind`, so pool capacity is preserved) and never deadlocks the
//! caller:
//!
//! * [`Task::wait`] resumes the job's panic on the *submitting* thread
//!   instead of hanging on a channel whose sender died.
//! * [`ThreadPool::parallel_for`] waits for **all** chunks (borrowed data
//!   must outlive every worker's access), then resumes the first chunk
//!   panic on the caller.
//! * `Drop` sends every worker a shutdown message and joins the
//!   `JoinHandle`s — workers are never detached.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;
type Panic = Box<dyn Any + Send + 'static>;

/// One chunk of a scoped [`ThreadPool::parallel_for`] section.
///
/// Raw pointers erase the caller's stack lifetimes; this is sound because
/// `parallel_for` does not return until [`Latch`] has counted every chunk
/// done, so the pointees strictly outlive all worker access.
struct Chunk {
    /// The section body, shared by every chunk: `f(chunk, start, end)`.
    f: *const (dyn Fn(usize, usize, usize) + Sync),
    chunk: usize,
    start: usize,
    end: usize,
    latch: *const Latch,
}

// Safety: see `Chunk` — the caller blocks until the latch opens, so the
// borrowed closure/latch outlive the worker's use of these pointers.
unsafe impl Send for Chunk {}

enum Msg {
    Run(Job),
    Scoped(Chunk),
    Shutdown,
}

/// Completion latch for one `parallel_for` call: counts outstanding
/// chunks and records the first panic payload.
#[derive(Default)]
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

#[derive(Default)]
struct LatchState {
    remaining: usize,
    panic: Option<Panic>,
}

impl Latch {
    fn done(&self, panic: Option<Panic>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<Panic> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panic.take()
    }
}

/// Fixed pool of named worker threads; see the module docs for the
/// scheduling and panic contracts.
pub struct ThreadPool {
    /// Guarded so the pool is `Sync` and an `Arc<ThreadPool>` can be
    /// threaded through engine/coordinator construction; only the owning
    /// compute thread dispatches, so the lock is uncontended.
    tx: Mutex<Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool of `n` workers (clamped to at least 1) named
    /// `rwkv-pool-{i}`.
    pub fn new(n: usize) -> Self {
        Self::named(n, "rwkv-pool")
    }

    /// Spawn a pool of `n` workers (clamped to at least 1) with thread
    /// names `{name}-{i}` — dedicated pools (e.g. the layerwise
    /// prefetcher's I/O worker) stay tellable from the compute lanes in
    /// profilers and panic messages.
    pub fn named(n: usize, name: &str) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // a panicking job must not kill the worker
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Scoped(c)) => {
                                // Safety: pointees outlive this call (the
                                // submitter blocks on the latch).
                                let f = unsafe { &*c.f };
                                let latch = unsafe { &*c.latch };
                                let r = catch_unwind(AssertUnwindSafe(|| {
                                    f(c.chunk, c.start, c.end)
                                }));
                                latch.done(r.err());
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Mutex::new(tx), workers }
    }

    /// Number of worker threads (total parallelism of a scoped section is
    /// `workers() + 1`: the caller runs a chunk too).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    fn send(&self, msg: Msg) {
        self.tx.lock().unwrap().send(msg).expect("pool alive");
    }

    /// Run `f` asynchronously (fire-and-forget).  A panic inside `f` is
    /// swallowed (the worker survives); use [`ThreadPool::submit`] when
    /// the caller needs the result or the panic.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.send(Msg::Run(Box::new(f)));
    }

    /// Run `f` asynchronously, returning a handle to await its result.
    pub fn submit<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(&self, f: F) -> Task<T> {
        let (tx, rx) = channel();
        self.spawn(move || {
            let r = catch_unwind(AssertUnwindSafe(f));
            let _ = tx.send(r);
        });
        Task { rx }
    }

    /// Scoped data-parallel for: run `f(chunk, start, end)` over the
    /// deterministic static chunking of `0..n` (see module docs), using
    /// the calling thread plus every worker.  Returns when ALL chunks are
    /// done; `f` may therefore borrow local data.  If any chunk panics,
    /// the first panic resumes on the caller after the section completes.
    ///
    /// ```
    /// use std::sync::atomic::{AtomicU64, Ordering};
    /// use rwkv_lite::pool::ThreadPool;
    ///
    /// let pool = ThreadPool::new(3);
    /// let xs: Vec<u64> = (0..100).collect(); // borrowed, not moved
    /// let total = AtomicU64::new(0);
    /// pool.parallel_for(xs.len(), &|_chunk, start, end| {
    ///     let part: u64 = xs[start..end].iter().sum();
    ///     total.fetch_add(part, Ordering::Relaxed);
    /// });
    /// assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    /// ```
    pub fn parallel_for(&self, n: usize, f: &(dyn Fn(usize, usize, usize) + Sync)) {
        if n == 0 {
            return;
        }
        let lanes = self.workers.len() + 1;
        let latch = Latch::default();
        // non-empty chunk count is min(n, lanes); the count must be set
        // before any worker can decrement
        latch.state.lock().unwrap().remaining = n.min(lanes) - 1;
        let fp: *const (dyn Fn(usize, usize, usize) + Sync) = f;
        let lp: *const Latch = &latch;
        let mut bounds = chunk_bounds(n, lanes);
        let (c0, s0, e0) = bounds.next().expect("n > 0 has a first chunk");
        for (chunk, start, end) in bounds {
            self.send(Msg::Scoped(Chunk { f: fp, chunk, start, end, latch: lp }));
        }
        // chunk 0 runs inline on the caller; even if it panics we MUST
        // wait for the workers first (they borrow the caller's stack)
        let mine = catch_unwind(AssertUnwindSafe(|| f(c0, s0, e0)));
        let theirs = latch.wait();
        if let Err(p) = mine {
            resume_unwind(p);
        }
        if let Some(p) = theirs {
            resume_unwind(p);
        }
    }
}

/// Build the compute pool for a `threads` knob (config / `--threads`):
/// `0` = one lane per available core, `1` = no pool (run inline), `k` =
/// `k` lanes (`k - 1` workers plus the calling thread).
pub fn for_threads(threads: usize) -> Option<Arc<ThreadPool>> {
    let lanes = match threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        t => t,
    };
    (lanes > 1).then(|| Arc::new(ThreadPool::new(lanes - 1)))
}

/// The deterministic static chunking of `0..n` into at most `lanes`
/// non-empty `(chunk, start, end)` ranges: `n / lanes` items per chunk,
/// the first `n % lanes` chunks take one extra.
fn chunk_bounds(n: usize, lanes: usize) -> impl Iterator<Item = (usize, usize, usize)> {
    let base = n / lanes;
    let extra = n % lanes;
    let mut start = 0usize;
    (0..lanes).filter_map(move |c| {
        let len = base + usize::from(c < extra);
        let s = start;
        start += len;
        (len > 0).then_some((c, s, s + len))
    })
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.lock().unwrap().send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A pending result from [`ThreadPool::submit`].
pub struct Task<T> {
    rx: Receiver<std::thread::Result<T>>,
}

impl<T> Task<T> {
    /// Block for the result.  If the job panicked, the panic resumes HERE
    /// (on the submitter) instead of hanging on a dead channel.
    pub fn wait(self) -> T {
        match self.rx.recv() {
            Ok(Ok(v)) => v,
            Ok(Err(p)) => resume_unwind(p),
            Err(_) => panic!("pool shut down before task completed"),
        }
    }

    /// Non-blocking poll; `None` while still running.  Panics (resuming
    /// the job's panic) if the job panicked.
    pub fn try_wait(&self) -> Option<T> {
        match self.rx.try_recv() {
            Ok(Ok(v)) => Some(v),
            Ok(Err(p)) => resume_unwind(p),
            Err(_) => None,
        }
    }
}

/// Copyable parallelism handle passed down to the sharded kernels:
/// `Par::none()` (or a `threads = 1` engine) runs sections inline;
/// otherwise sections fan out over the pool.  Results are bit-identical
/// either way — the handle only chooses who computes which range.
#[derive(Clone, Copy, Default)]
pub struct Par<'a> {
    pool: Option<&'a ThreadPool>,
}

impl<'a> Par<'a> {
    /// Inline execution (the single-threaded reference path).
    pub fn none() -> Self {
        Self { pool: None }
    }

    /// Fan out over `pool` when `Some`, inline when `None`.
    pub fn new(pool: Option<&'a ThreadPool>) -> Self {
        Self { pool }
    }

    /// Number of concurrent lanes a section is split into (1 == inline).
    /// Per-lane scratch owners size their buffers with this.
    pub fn lanes(&self) -> usize {
        self.pool.map_or(1, |p| p.workers() + 1)
    }

    /// Run `f(chunk, start, end)` over the static chunking of `0..n`
    /// (inline as `f(0, 0, n)` without a pool).  See
    /// [`ThreadPool::parallel_for`].
    pub fn run(&self, n: usize, f: &(dyn Fn(usize, usize, usize) + Sync)) {
        match self.pool {
            Some(p) => p.parallel_for(n, f),
            None => {
                if n > 0 {
                    f(0, 0, n)
                }
            }
        }
    }
}

/// Shared-mutable slice view for handing ONE buffer to several chunks of a
/// scoped section that write **disjoint** element ranges (sharded kernel
/// outputs, per-lane scratch, per-session states).
///
/// Safety contract (callers): every element is accessed by at most one
/// chunk, and the underlying buffer outlives the section — guaranteed by
/// `parallel_for` blocking until all chunks finish.
pub(crate) struct SharedSliceMut<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SharedSliceMut<T> {}
unsafe impl<T: Send> Sync for SharedSliceMut<T> {}

impl<T> SharedSliceMut<T> {
    pub(crate) fn new(s: &mut [T]) -> Self {
        Self { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// Reconstruct the full slice inside a chunk.
    ///
    /// # Safety
    /// The chunk must only touch elements no other chunk touches, per the
    /// type-level contract above.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn get(&self) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<_> = (0..64)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for t in tasks {
            t.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn named_pool_names_workers() {
        let pool = ThreadPool::named(1, "rwkv-io");
        let name = pool
            .submit(|| std::thread::current().name().map(str::to_string))
            .wait();
        assert_eq!(name.as_deref(), Some("rwkv-io-0"));
    }

    #[test]
    fn submit_returns_value() {
        let pool = ThreadPool::new(2);
        let t = pool.submit(|| 6 * 7);
        assert_eq!(t.wait(), 42);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn task_wait_propagates_panic_instead_of_hanging() {
        let pool = ThreadPool::new(1);
        let t = pool.submit(|| -> u32 { panic!("job exploded") });
        let r = catch_unwind(AssertUnwindSafe(|| t.wait()));
        let p = r.expect_err("wait must propagate the job panic");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "job exploded");
        // the worker survived the panic and still runs jobs
        assert_eq!(pool.submit(|| 7).wait(), 7);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 2, 3, 4, 7, 100] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(n, &|_c, s, e| {
                for h in &hits[s..e] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n}: every index exactly once"
            );
        }
    }

    #[test]
    fn chunking_is_deterministic_and_static() {
        // depends only on (n, lanes): recomputing gives identical bounds
        let a: Vec<_> = chunk_bounds(13, 4).collect();
        let b: Vec<_> = chunk_bounds(13, 4).collect();
        assert_eq!(a, b);
        assert_eq!(a, vec![(0, 0, 4), (1, 4, 7), (2, 7, 10), (3, 10, 13)]);
        // n < lanes: only non-empty chunks materialize, indexes preserved
        let c: Vec<_> = chunk_bounds(2, 4).collect();
        assert_eq!(c, vec![(0, 0, 1), (1, 1, 2)]);
    }

    #[test]
    fn parallel_for_borrows_and_writes_disjoint_ranges() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 257];
        let view = SharedSliceMut::new(&mut out);
        pool.parallel_for(257, &|_c, s, e| {
            let out = unsafe { view.get() };
            for (i, o) in out[s..e].iter_mut().enumerate() {
                *o = s + i;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn parallel_for_propagates_chunk_panic_after_completion() {
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(30, &|c, s, e| {
                if c == 1 {
                    panic!("chunk down");
                }
                done.fetch_add(e - s, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err(), "worker-chunk panic must reach the caller");
        // pool still usable afterwards
        let total = AtomicUsize::new(0);
        pool.parallel_for(10, &|_c, s, e| {
            total.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10);
    }
}
