//! Fixed-size thread pool + scoped data-parallel sections (substrate S23
//! — no tokio, no rayon in this environment).
//!
//! Two kinds of work run here:
//!
//! * **Fire-and-forget / future-style jobs** — [`ThreadPool::spawn`] and
//!   [`ThreadPool::submit`], used for background work such as layerwise
//!   prefetch.  Jobs are `'static` boxed closures delivered over an mpsc
//!   channel that all workers drain.
//! * **Scoped data-parallel sections** — [`ThreadPool::parallel_for`],
//!   the intra-round compute path.  The closure may borrow stack data
//!   (weights, activation buffers): the call does not return until every
//!   chunk has finished, so the borrows stay valid without `Arc`/clone.
//!
//! # Scheduling
//!
//! `parallel_for(n, f)` splits `0..n` into `workers() + 1` contiguous
//! chunks by **deterministic static chunking**: chunk sizes depend only on
//! `n` and the pool size (`n / lanes` items each, the first `n % lanes`
//! chunks take one extra), never on runtime timing.  Chunk 0 runs inline
//! on the calling thread; the rest are dispatched to workers.  No closure
//! is boxed per call — a chunk descriptor is a small plain struct — so a
//! section adds no per-call heap allocation beyond the channel node.
//!
//! Work assignment is static, not work-stealing: for the engine's use
//! (equal-cost output rows / slots) this is both faster and — more
//! importantly — *reproducible*.  Numerical determinism, however, does not
//! depend on the chunking at all: callers only ever shard work whose
//! per-element reduction order is unchanged by the split (see
//! `tensor::matmat`), so results are bit-identical for every pool size,
//! including the inline `threads = 1` path.
//!
//! # Panic semantics
//!
//! A panicking job never takes a worker down (every job runs under
//! `catch_unwind`, so pool capacity is preserved) and never deadlocks the
//! caller:
//!
//! * [`Task::wait`] resumes the job's panic on the *submitting* thread
//!   instead of hanging on a channel whose sender died.
//! * [`ThreadPool::parallel_for`] waits for **all** chunks (borrowed data
//!   must outlive every worker's access), then resumes the first chunk
//!   panic on the caller.
//! * `Drop` sends every worker a shutdown message and joins the
//!   `JoinHandle`s — workers are never detached.
//!
//! # Verification
//!
//! The cross-thread protocols here ([`Latch`], [`TaskSlot`]) are built on
//! `crate::sync` so the CI loom job model-checks them exhaustively (the
//! `loom_*` tests below); the raw-pointer hand-off is additionally run
//! under Miri and ThreadSanitizer, and [`SharedSliceMut`] carries a
//! debug-build claims ledger that turns any violation of the
//! disjoint-range contract into a deterministic panic.  See
//! `docs/correctness.md` for the full matrix.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::thread::JoinHandle;

use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;
type Panic = Box<dyn Any + Send + 'static>;

/// One chunk of a scoped [`ThreadPool::parallel_for`] section.
///
/// Raw pointers erase the caller's stack lifetimes; this is sound because
/// `parallel_for` does not return until [`Latch`] has counted every chunk
/// done, so the pointees strictly outlive all worker access.
struct Chunk {
    /// The section body, shared by every chunk: `f(chunk, start, end)`.
    f: *const (dyn Fn(usize, usize, usize) + Sync),
    chunk: usize,
    start: usize,
    end: usize,
    latch: *const Latch,
}

// SAFETY: see `Chunk` — the caller blocks until the latch opens, so the
// borrowed closure/latch outlive the worker's use of these pointers; the
// pointees themselves are `Sync` (`f` by bound, `Latch` by construction),
// so dereferencing them from a worker thread is sound.
unsafe impl Send for Chunk {}

enum Msg {
    Run(Job),
    Scoped(Chunk),
    Shutdown,
}

/// Completion latch for one `parallel_for` call: counts outstanding
/// chunks and records the first panic payload.
///
/// # Lifetime audit (the `Chunk.latch` raw pointer)
///
/// The latch lives on the caller's stack and workers reach it through a
/// raw pointer, so the caller must not return while any worker can still
/// touch it.  [`Latch::wait`] only returns once `remaining == 0`, and a
/// worker's *last* access is dropping the mutex guard inside
/// [`Latch::done`] — which is also the release that lets the waiting
/// caller re-acquire the mutex and observe `remaining == 0`.  The
/// notification is sent while the lock is still held, so the waiter
/// cannot wake, return, and free the latch between the decrement and the
/// notify.  (Rust's `std` mutex explicitly supports being freed
/// immediately after the owner's unlock, the classic condvar-destruction
/// pattern.)  The `loom_latch_*` models below check exactly this
/// protocol, including that `done` publishes the worker's chunk writes to
/// the waiter.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Panic>,
}

impl Latch {
    /// A latch counting `remaining` outstanding chunks.  (Constructed
    /// explicitly rather than via `Default` + mutation so the count is
    /// set before the latch address can ever escape to a worker — and
    /// because loom's `Mutex` has no `Default`.)
    fn new(remaining: usize) -> Self {
        Self {
            state: Mutex::new(LatchState { remaining, panic: None }),
            cv: Condvar::new(),
        }
    }

    fn done(&self, panic: Option<Panic>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<Panic> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panic.take()
    }
}

/// Fixed pool of named worker threads; see the module docs for the
/// scheduling and panic contracts.
pub struct ThreadPool {
    /// Guarded so the pool is `Sync` and an `Arc<ThreadPool>` can be
    /// threaded through engine/coordinator construction; only the owning
    /// compute thread dispatches, so the lock is uncontended.
    tx: Mutex<Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool of `n` workers (clamped to at least 1) named
    /// `rwkv-pool-{i}`.
    pub fn new(n: usize) -> Self {
        Self::named(n, "rwkv-pool")
    }

    /// Spawn a pool of `n` workers (clamped to at least 1) with thread
    /// names `{name}-{i}` — dedicated pools (e.g. the layerwise
    /// prefetcher's I/O worker) stay tellable from the compute lanes in
    /// profilers and panic messages.
    pub fn named(n: usize, name: &str) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // a panicking job must not kill the worker
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Scoped(c)) => {
                                // SAFETY: the pointees outlive this call —
                                // the submitter blocks on the latch until
                                // `done` below has run (see `Latch` docs).
                                let f = unsafe { &*c.f };
                                // SAFETY: same lifetime argument as `c.f`.
                                let latch = unsafe { &*c.latch };
                                let r = catch_unwind(AssertUnwindSafe(|| {
                                    f(c.chunk, c.start, c.end)
                                }));
                                latch.done(r.err());
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Mutex::new(tx), workers }
    }

    /// Number of worker threads (total parallelism of a scoped section is
    /// `workers() + 1`: the caller runs a chunk too).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    fn send(&self, msg: Msg) {
        self.tx.lock().unwrap().send(msg).expect("pool alive");
    }

    /// Run `f` asynchronously (fire-and-forget).  A panic inside `f` is
    /// swallowed (the worker survives); use [`ThreadPool::submit`] when
    /// the caller needs the result or the panic.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.send(Msg::Run(Box::new(f)));
    }

    /// Run `f` asynchronously, returning a handle to await its result.
    ///
    /// The result travels through a [`TaskSlot`] (mutex + condvar, not a
    /// channel) so the completion hand-off is loom-modeled; a drop guard
    /// marks the slot orphaned if the job is destroyed unexecuted (pool
    /// shut down first), so [`Task::wait`] can never hang.
    pub fn submit<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(&self, f: F) -> Task<T> {
        let slot = Arc::new(TaskSlot::new());
        let guard = OrphanGuard { slot: Arc::clone(&slot) };
        self.spawn(move || {
            let r = catch_unwind(AssertUnwindSafe(f));
            guard.slot.complete(r);
            // `guard` drops here; `orphan` is a no-op once a result is in.
        });
        Task { slot }
    }

    /// Scoped data-parallel for: run `f(chunk, start, end)` over the
    /// deterministic static chunking of `0..n` (see module docs), using
    /// the calling thread plus every worker.  Returns when ALL chunks are
    /// done; `f` may therefore borrow local data.  If any chunk panics,
    /// the first panic resumes on the caller after the section completes.
    ///
    /// ```
    /// use rwkv_lite::pool::ThreadPool;
    /// use rwkv_lite::sync::atomic::{AtomicU64, Ordering};
    ///
    /// let pool = ThreadPool::new(3);
    /// let xs: Vec<u64> = (0..100).collect(); // borrowed, not moved
    /// let total = AtomicU64::new(0);
    /// pool.parallel_for(xs.len(), &|_chunk, start, end| {
    ///     let part: u64 = xs[start..end].iter().sum();
    ///     total.fetch_add(part, Ordering::Relaxed);
    /// });
    /// assert_eq!(total.load(Ordering::Relaxed), 99 * 100 / 2);
    /// ```
    pub fn parallel_for(&self, n: usize, f: &(dyn Fn(usize, usize, usize) + Sync)) {
        if n == 0 {
            return;
        }
        let lanes = self.workers.len() + 1;
        // non-empty chunk count is min(n, lanes); the count is fixed at
        // construction, before the latch address escapes to any worker
        let latch = Latch::new(n.min(lanes) - 1);
        let fp: *const (dyn Fn(usize, usize, usize) + Sync) = f;
        let lp: *const Latch = &latch;
        let mut bounds = chunk_bounds(n, lanes);
        let (c0, s0, e0) = bounds.next().expect("n > 0 has a first chunk");
        for (chunk, start, end) in bounds {
            self.send(Msg::Scoped(Chunk { f: fp, chunk, start, end, latch: lp }));
        }
        // chunk 0 runs inline on the caller; even if it panics we MUST
        // wait for the workers first (they borrow the caller's stack)
        let mine = catch_unwind(AssertUnwindSafe(|| f(c0, s0, e0)));
        let theirs = latch.wait();
        if let Err(p) = mine {
            resume_unwind(p);
        }
        if let Some(p) = theirs {
            resume_unwind(p);
        }
    }
}

/// Build the compute pool for a `threads` knob (config / `--threads`):
/// `0` = one lane per available core, `1` = no pool (run inline), `k` =
/// `k` lanes (`k - 1` workers plus the calling thread).
pub fn for_threads(threads: usize) -> Option<Arc<ThreadPool>> {
    let lanes = match threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        t => t,
    };
    (lanes > 1).then(|| Arc::new(ThreadPool::new(lanes - 1)))
}

/// The deterministic static chunking of `0..n` into at most `lanes`
/// non-empty `(chunk, start, end)` ranges: `n / lanes` items per chunk,
/// the first `n % lanes` chunks take one extra.
fn chunk_bounds(n: usize, lanes: usize) -> impl Iterator<Item = (usize, usize, usize)> {
    let base = n / lanes;
    let extra = n % lanes;
    let mut start = 0usize;
    (0..lanes).filter_map(move |c| {
        let len = base + usize::from(c < extra);
        let s = start;
        start += len;
        (len > 0).then_some((c, s, s + len))
    })
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.lock().unwrap().send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The completion slot a submitted job reports into: a mutex/condvar
/// cell instead of a one-shot channel, so loom can model the
/// complete/wait/orphan races (`loom_task_slot_*` below).
struct TaskSlot<T> {
    state: Mutex<SlotState<T>>,
    cv: Condvar,
}

struct SlotState<T> {
    result: Option<std::thread::Result<T>>,
    /// The job was destroyed without running (pool shut down while it sat
    /// in the queue), or the result was already taken: waiting is futile.
    orphaned: bool,
}

impl<T> TaskSlot<T> {
    fn new() -> Self {
        Self {
            state: Mutex::new(SlotState { result: None, orphaned: false }),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, r: std::thread::Result<T>) {
        let mut st = self.state.lock().unwrap();
        st.result = Some(r);
        self.cv.notify_all();
    }

    /// Mark the slot dead if (and only if) no result ever arrived.
    fn orphan(&self) {
        let mut st = self.state.lock().unwrap();
        if st.result.is_none() {
            st.orphaned = true;
            self.cv.notify_all();
        }
    }

    /// Block until a result or orphan marker; `None` means the job will
    /// never produce one.
    fn take_blocking(&self) -> Option<std::thread::Result<T>> {
        let mut st = self.state.lock().unwrap();
        while st.result.is_none() && !st.orphaned {
            st = self.cv.wait(st).unwrap();
        }
        let r = st.result.take();
        // a taken result must not be awaited twice
        st.orphaned = true;
        r
    }

    /// `Ok(Some)` result ready (taken), `Ok(None)` still running,
    /// `Err(())` orphaned.
    fn try_take(&self) -> Result<Option<std::thread::Result<T>>, ()> {
        let mut st = self.state.lock().unwrap();
        match st.result.take() {
            Some(r) => {
                st.orphaned = true;
                Ok(Some(r))
            }
            None if st.orphaned => Err(()),
            None => Ok(None),
        }
    }
}

/// Marks the slot orphaned when the job closure is dropped — whether
/// after running (no-op: a result is already in) or unexecuted because
/// the pool shut down with the job still queued.
struct OrphanGuard<T> {
    slot: Arc<TaskSlot<T>>,
}

impl<T> Drop for OrphanGuard<T> {
    fn drop(&mut self) {
        self.slot.orphan();
    }
}

/// A pending result from [`ThreadPool::submit`].
pub struct Task<T> {
    slot: Arc<TaskSlot<T>>,
}

impl<T> Task<T> {
    /// Block for the result.  If the job panicked, the panic resumes HERE
    /// (on the submitter) instead of hanging on a dead slot.
    pub fn wait(self) -> T {
        match self.slot.take_blocking() {
            Some(Ok(v)) => v,
            Some(Err(p)) => resume_unwind(p),
            None => panic!("pool shut down before task completed"),
        }
    }

    /// Non-blocking poll; `None` while still running (or if the slot was
    /// already consumed/orphaned).  Panics (resuming the job's panic) if
    /// the job panicked.
    pub fn try_wait(&self) -> Option<T> {
        match self.slot.try_take() {
            Ok(Some(Ok(v))) => Some(v),
            Ok(Some(Err(p))) => resume_unwind(p),
            Ok(None) | Err(()) => None,
        }
    }
}

/// Copyable parallelism handle passed down to the sharded kernels:
/// `Par::serial()` (or a `threads = 1` engine) runs sections inline;
/// otherwise sections fan out over the pool.  Results are bit-identical
/// either way — the handle only chooses who computes which range, which
/// is what lets each kernel expose ONE entry point instead of a
/// scalar/`_par` twin pair.
#[derive(Clone, Copy, Default)]
pub struct Par<'a> {
    pool: Option<&'a ThreadPool>,
}

impl<'a> Par<'a> {
    /// Inline execution (the single-threaded reference path).
    pub fn serial() -> Self {
        Self { pool: None }
    }

    /// Fan out over `pool` when `Some`, inline when `None`.
    pub fn new(pool: Option<&'a ThreadPool>) -> Self {
        Self { pool }
    }

    /// Number of concurrent lanes a section is split into (1 == inline).
    /// Per-lane scratch owners size their buffers with this.
    pub fn lanes(&self) -> usize {
        self.pool.map_or(1, |p| p.workers() + 1)
    }

    /// Run `f(chunk, start, end)` over the static chunking of `0..n`
    /// (inline as `f(0, 0, n)` without a pool).  See
    /// [`ThreadPool::parallel_for`].
    pub fn run(&self, n: usize, f: &(dyn Fn(usize, usize, usize) + Sync)) {
        match self.pool {
            Some(p) => p.parallel_for(n, f),
            None => {
                if n > 0 {
                    f(0, 0, n)
                }
            }
        }
    }
}

/// Shared-mutable slice view for handing ONE buffer to several chunks of a
/// scoped section that write **disjoint** element ranges (sharded kernel
/// outputs, per-lane scratch, per-session states).
///
/// Safety contract (callers): every element is accessed by at most one
/// chunk, and the underlying buffer outlives the section — guaranteed by
/// `parallel_for` blocking until all chunks finish.
///
/// In debug builds every chunk additionally registers the shard range it
/// claims via [`SharedSliceMut::debug_claim`]; overlapping claims panic
/// deterministically, turning a would-be data race into a test failure.
/// Claims are in the *shard-index space* the section chunks over (rows,
/// columns, spans, lanes — whatever `parallel_for(n, ..)`'s `n` counts),
/// which need not be element indices of the underlying buffer.
pub(crate) struct SharedSliceMut<T> {
    ptr: *mut T,
    len: usize,
    #[cfg(debug_assertions)]
    claims: Mutex<Vec<(usize, usize)>>,
}

// SAFETY: the view is only shared between the chunks of one scoped
// section; callers uphold disjoint element access (debug-asserted via the
// claims ledger), the buffer outlives the section, and the ledger itself
// is behind a `Mutex` — so sending the view to worker threads cannot
// introduce aliased mutation.
unsafe impl<T: Send> Send for SharedSliceMut<T> {}
// SAFETY: same argument as `Send`; `&SharedSliceMut` only exposes the
// raw parts and the internally-synchronized ledger.
unsafe impl<T: Send> Sync for SharedSliceMut<T> {}

impl<T> SharedSliceMut<T> {
    pub(crate) fn new(s: &mut [T]) -> Self {
        Self {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            #[cfg(debug_assertions)]
            claims: Mutex::new(Vec::new()),
        }
    }

    /// Reconstruct the full slice inside a chunk.
    ///
    /// # Safety
    /// The chunk must only touch elements no other chunk touches, per the
    /// type-level contract above.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn get(&self) -> &mut [T] {
        // SAFETY: ptr/len come from the live `&mut [T]` this view was
        // built from; the caller upholds the disjointness contract.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    /// Debug-assert that `[start, end)` (in the section's shard-index
    /// space) is claimed by exactly this one chunk.  Call once per chunk
    /// before writing; compiled out in release builds.
    #[cfg(debug_assertions)]
    pub(crate) fn debug_claim(&self, start: usize, end: usize) {
        let mut claims = self.claims.lock().unwrap();
        for &(s, e) in claims.iter() {
            assert!(
                end <= s || start >= e,
                "SharedSliceMut: overlapping shard claims [{start}, {end}) vs [{s}, {e})"
            );
        }
        claims.push((start, end));
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    pub(crate) fn debug_claim(&self, _start: usize, _end: usize) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<_> = (0..64)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for t in tasks {
            t.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn named_pool_names_workers() {
        let pool = ThreadPool::named(1, "rwkv-io");
        let name = pool
            .submit(|| std::thread::current().name().map(str::to_string))
            .wait();
        assert_eq!(name.as_deref(), Some("rwkv-io-0"));
    }

    #[test]
    fn submit_returns_value() {
        let pool = ThreadPool::new(2);
        let t = pool.submit(|| 6 * 7);
        assert_eq!(t.wait(), 42);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn task_wait_propagates_panic_instead_of_hanging() {
        let pool = ThreadPool::new(1);
        let t = pool.submit(|| -> u32 { panic!("job exploded") });
        let r = catch_unwind(AssertUnwindSafe(|| t.wait()));
        let p = r.expect_err("wait must propagate the job panic");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "job exploded");
        // the worker survived the panic and still runs jobs
        assert_eq!(pool.submit(|| 7).wait(), 7);
    }

    #[test]
    fn task_try_wait_polls_then_takes() {
        let pool = ThreadPool::new(1);
        let t = pool.submit(|| 11);
        // poll until the result lands, then the slot is consumed
        let v = loop {
            if let Some(v) = t.try_wait() {
                break v;
            }
            std::thread::yield_now();
        };
        assert_eq!(v, 11);
        assert_eq!(t.try_wait(), None, "a taken result is gone");
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(3);
        for n in [0usize, 1, 2, 3, 4, 7, 100] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(n, &|_c, s, e| {
                for h in &hits[s..e] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n}: every index exactly once"
            );
        }
    }

    #[test]
    fn chunking_is_deterministic_and_static() {
        // depends only on (n, lanes): recomputing gives identical bounds
        let a: Vec<_> = chunk_bounds(13, 4).collect();
        let b: Vec<_> = chunk_bounds(13, 4).collect();
        assert_eq!(a, b);
        assert_eq!(a, vec![(0, 0, 4), (1, 4, 7), (2, 7, 10), (3, 10, 13)]);
        // n < lanes: only non-empty chunks materialize, indexes preserved
        let c: Vec<_> = chunk_bounds(2, 4).collect();
        assert_eq!(c, vec![(0, 0, 1), (1, 1, 2)]);
    }

    #[test]
    fn parallel_for_borrows_and_writes_disjoint_ranges() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 257];
        let view = SharedSliceMut::new(&mut out);
        pool.parallel_for(257, &|_c, s, e| {
            view.debug_claim(s, e);
            // SAFETY: each chunk writes only its own [s, e) shard.
            let out = unsafe { view.get() };
            for (i, o) in out[s..e].iter_mut().enumerate() {
                *o = s + i;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    fn debug_claim_panics_on_overlap() {
        let mut buf = vec![0u8; 8];
        let view = SharedSliceMut::new(&mut buf);
        view.debug_claim(0, 4);
        view.debug_claim(4, 8); // disjoint: fine
        let r = catch_unwind(AssertUnwindSafe(|| view.debug_claim(3, 5)));
        assert!(r.is_err(), "overlapping claim must panic");
    }

    #[test]
    fn parallel_for_propagates_chunk_panic_after_completion() {
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(30, &|c, s, e| {
                if c == 1 {
                    panic!("chunk down");
                }
                done.fetch_add(e - s, Ordering::Relaxed);
            });
        }));
        assert!(r.is_err(), "worker-chunk panic must reach the caller");
        // pool still usable afterwards
        let total = AtomicUsize::new(0);
        pool.parallel_for(10, &|_c, s, e| {
            total.fetch_add(e - s, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10);
    }
}

/// Exhaustive interleaving models for the latch and task-slot protocols.
/// Only compiled by the CI loom job (`RUSTFLAGS="--cfg loom" cargo test
/// --lib loom_`), where `crate::sync` resolves to `loom::sync`.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::{Latch, Panic, TaskSlot};
    use crate::sync::Arc;
    use loom::cell::UnsafeCell;

    /// Two workers write disjoint cells then `done()`; after `wait()`
    /// returns, both writes must be visible to the caller (the latch is
    /// the only synchronization — exactly the `parallel_for` protocol).
    #[test]
    fn loom_latch_publishes_worker_writes() {
        loom::model(|| {
            let latch = Arc::new(Latch::new(2));
            let cells = Arc::new((UnsafeCell::new(0u32), UnsafeCell::new(0u32)));
            let mut workers = Vec::new();
            for id in 0..2u32 {
                let latch = Arc::clone(&latch);
                let cells = Arc::clone(&cells);
                workers.push(loom::thread::spawn(move || {
                    let cell = if id == 0 { &cells.0 } else { &cells.1 };
                    // SAFETY: each worker writes only its own cell, and
                    // the caller reads only after the latch opens.
                    cell.with_mut(|p| unsafe { *p = id + 1 });
                    latch.done(None);
                }));
            }
            assert!(latch.wait().is_none());
            // SAFETY: all workers have counted down; no writer is live.
            let a = cells.0.with(|p| unsafe { *p });
            // SAFETY: as above.
            let b = cells.1.with(|p| unsafe { *p });
            assert_eq!((a, b), (1, 2));
            for w in workers {
                w.join().unwrap();
            }
        });
    }

    /// A worker panic payload recorded concurrently with completion must
    /// surface exactly once from `wait()`.
    #[test]
    fn loom_latch_reports_panic_from_any_worker() {
        loom::model(|| {
            let latch = Arc::new(Latch::new(2));
            let mut workers = Vec::new();
            for id in 0..2u32 {
                let latch = Arc::clone(&latch);
                workers.push(loom::thread::spawn(move || {
                    let payload = (id == 0).then(|| Box::new("boom") as Panic);
                    latch.done(payload);
                }));
            }
            let p = latch.wait().expect("one worker reported a panic");
            assert_eq!(p.downcast_ref::<&str>(), Some(&"boom"));
            for w in workers {
                w.join().unwrap();
            }
        });
    }

    /// Completion racing the blocking waiter: the value always arrives.
    #[test]
    fn loom_task_slot_complete_vs_wait() {
        loom::model(|| {
            let slot = Arc::new(TaskSlot::new());
            let s = Arc::clone(&slot);
            let t = loom::thread::spawn(move || s.complete(Ok(7u32)));
            let r = slot.take_blocking();
            assert!(matches!(r, Some(Ok(7))));
            t.join().unwrap();
        });
    }

    /// A job destroyed unexecuted (pool shutdown) must unblock the
    /// waiter with `None`, never deadlock.
    #[test]
    fn loom_task_slot_orphan_unblocks_waiter() {
        loom::model(|| {
            let slot = Arc::new(TaskSlot::<u32>::new());
            let s = Arc::clone(&slot);
            let t = loom::thread::spawn(move || s.orphan());
            let r = slot.take_blocking();
            assert!(r.is_none());
            t.join().unwrap();
        });
    }

    /// The normal worker path (`complete` then the drop-guard's `orphan`)
    /// racing the waiter: the result must never be lost.
    #[test]
    fn loom_task_slot_orphan_after_complete_keeps_result() {
        loom::model(|| {
            let slot = Arc::new(TaskSlot::new());
            let s = Arc::clone(&slot);
            let t = loom::thread::spawn(move || {
                s.complete(Ok(1u32));
                s.orphan();
            });
            let r = slot.take_blocking();
            assert!(matches!(r, Some(Ok(1))));
            t.join().unwrap();
        });
    }
}
