//! Fixed-size thread pool (substrate S23 — no tokio in this environment).
//!
//! Used by the coordinator for request handling and by the layerwise
//! loader to prefetch layer N+1 while layer N executes.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("rwkv-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx, workers }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run `f` asynchronously, returning a handle to await its result.
    pub fn submit<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(&self, f: F) -> Task<T> {
        let (tx, rx) = channel();
        self.spawn(move || {
            let _ = tx.send(f());
        });
        Task { rx }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A pending result from [`ThreadPool::submit`].
pub struct Task<T> {
    rx: Receiver<T>,
}

impl<T> Task<T> {
    pub fn wait(self) -> T {
        self.rx.recv().expect("task completed")
    }

    pub fn try_wait(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<_> = (0..64)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(move || c.fetch_add(1, Ordering::SeqCst))
            })
            .collect();
        for t in tasks {
            t.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn submit_returns_value() {
        let pool = ThreadPool::new(2);
        let t = pool.submit(|| 6 * 7);
        assert_eq!(t.wait(), 42);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
