//! Group-quantized 4-bit weight formats (Q4 / Q4_1): pack layout,
//! quantizers, and the in-register dequant primitives shared by the
//! matvec/matmat kernels, [`crate::tensor::Mat`], and the engine's
//! streaming `RowView` path.
//!
//! Layout (the rwkv.cpp-style block quantization of ROADMAP item 1):
//!
//! * Elements are grouped along the LAST axis (`cols`) in groups of
//!   [`Q4_GROUP`] = 32; each (row, group) pair gets its own parameters.
//! * The payload packs two 4-bit codes per byte, row-major: element
//!   `(r, c)` lives in byte `r * cols.div_ceil(2) + c / 2`, even `c` in
//!   the LOW nibble, odd `c` in the HIGH nibble.  A row with odd `cols`
//!   pads its trailing high nibble: 8 for Q4 (offset-binary zero) and 0
//!   for Q4_1.
//! * Group parameters are f16 BITS in sibling arrays of shape
//!   `(rows, cols.div_ceil(32))`: Q4 stores a scale `s` per group
//!   (code `q ∈ [1, 15]` offset-binary, value `s * (q - 8)`); Q4_1 adds
//!   a per-group minimum `m` (code `q ∈ [0, 15]` unsigned, value
//!   `s * q + m`) so all-positive groups keep full code range.
//!
//! # Determinism / bit-exactness contract
//!
//! Dequantization of element `(r, c)` is a pure function of the stored
//! bytes — [`dq4`] / [`dq4_1`] are THE definition, used identically by
//! the serial kernels, the `_par` shards (a column split mid-group is
//! safe: no cross-element state), `Mat::decode_row`, and the engine
//! `RowView` path — and the kernel reductions replicate the matvec.rs
//! LANES accumulator shape in ascending index order, so every quantized
//! kernel is bit-identical to running the dense f32 kernel on
//! [`dequant_row_q4`] output.
//!
//! The quantizers round with `round_ties_even` against the f16-ROUNDED
//! scale (quantize with exactly the scale the dequantizer will see).
//! The Python exporter (`python/compile/compress/quant.py`) mirrors this
//! arithmetic operation-for-operation in float32; the cross-language
//! round-trip test (`tests/q4_export_roundtrip.rs`) pins the equality.

use crate::util::f16::{f16_to_f32_fast, f32_to_f16};

/// Elements per quantization group along the column axis.
pub const Q4_GROUP: usize = 32;

/// Number of groups (scale entries) per row of `cols` elements.
#[inline]
pub fn q4_groups(cols: usize) -> usize {
    cols.div_ceil(Q4_GROUP)
}

/// Packed payload bytes per row of `cols` elements (two codes per byte).
#[inline]
pub fn q4_row_packed_bytes(cols: usize) -> usize {
    cols.div_ceil(2)
}

/// Extract the 4-bit code of element `c` from a packed row.
#[inline]
pub fn q4_nib(packed_row: &[u8], c: usize) -> u8 {
    let b = packed_row[c / 2];
    if c % 2 == 0 {
        b & 0x0F
    } else {
        b >> 4
    }
}

/// Dequantize one Q4 element: `s * (q - 8)` with `s` the group's f16 scale.
#[inline]
pub fn dq4(packed_row: &[u8], scale_row: &[u16], c: usize) -> f32 {
    let s = f16_to_f32_fast(scale_row[c / Q4_GROUP]);
    s * (q4_nib(packed_row, c) as i32 - 8) as f32
}

/// Dequantize one Q4_1 element: `s * q + m`.
#[inline]
pub fn dq4_1(packed_row: &[u8], scale_row: &[u16], min_row: &[u16], c: usize) -> f32 {
    let g = c / Q4_GROUP;
    let s = f16_to_f32_fast(scale_row[g]);
    let m = f16_to_f32_fast(min_row[g]);
    s * q4_nib(packed_row, c) as f32 + m
}

/// Dequantize one packed Q4 row into `out` (`out.len()` = logical cols).
pub fn dequant_row_q4(packed_row: &[u8], scale_row: &[u16], out: &mut [f32]) {
    for (c, o) in out.iter_mut().enumerate() {
        *o = dq4(packed_row, scale_row, c);
    }
}

/// Dequantize one packed Q4_1 row into `out`.
pub fn dequant_row_q4_1(packed_row: &[u8], scale_row: &[u16], min_row: &[u16], out: &mut [f32]) {
    for (c, o) in out.iter_mut().enumerate() {
        *o = dq4_1(packed_row, scale_row, min_row, c);
    }
}

/// Quantize a row-major `(rows, cols)` f32 matrix to Q4.
/// Returns `(packed nibbles, per-group f16 scale bits)`.
pub fn quantize_q4(rows: usize, cols: usize, data: &[f32]) -> (Vec<u8>, Vec<u16>) {
    assert_eq!(data.len(), rows * cols, "quantize_q4: shape/data mismatch");
    let ng = q4_groups(cols);
    let prb = q4_row_packed_bytes(cols);
    let mut packed = vec![0u8; rows * prb];
    let mut scale = vec![0u16; rows * ng];
    for r in 0..rows {
        let wrow = &data[r * cols..(r + 1) * cols];
        for g in 0..ng {
            let lo = g * Q4_GROUP;
            let hi = ((g + 1) * Q4_GROUP).min(cols);
            let mut amax = 0f32;
            for &w in &wrow[lo..hi] {
                amax = amax.max(w.abs());
            }
            // quantize against the f16-ROUNDED scale — exactly the value
            // every dequant consumer will decode
            let sbits = f32_to_f16(amax / 7.0);
            scale[r * ng + g] = sbits;
            let s = f16_to_f32_fast(sbits);
            let denom = if s == 0.0 { 1.0 } else { s };
            for c in lo..hi {
                let q = (wrow[c] / denom).round_ties_even().clamp(-7.0, 7.0) as i32 + 8;
                let byte = &mut packed[r * prb + c / 2];
                if c % 2 == 0 {
                    *byte |= q as u8;
                } else {
                    *byte |= (q as u8) << 4;
                }
            }
        }
        if cols % 2 == 1 {
            // trailing pad nibble is offset-binary zero
            packed[r * prb + prb - 1] |= 8u8 << 4;
        }
    }
    (packed, scale)
}

/// Quantize a row-major `(rows, cols)` f32 matrix to Q4_1.
/// Returns `(packed nibbles, scale bits, min bits)`.
pub fn quantize_q4_1(rows: usize, cols: usize, data: &[f32]) -> (Vec<u8>, Vec<u16>, Vec<u16>) {
    assert_eq!(data.len(), rows * cols, "quantize_q4_1: shape/data mismatch");
    let ng = q4_groups(cols);
    let prb = q4_row_packed_bytes(cols);
    let mut packed = vec![0u8; rows * prb];
    let mut scale = vec![0u16; rows * ng];
    let mut min = vec![0u16; rows * ng];
    for r in 0..rows {
        let wrow = &data[r * cols..(r + 1) * cols];
        for g in 0..ng {
            let lo = g * Q4_GROUP;
            let hi = ((g + 1) * Q4_GROUP).min(cols);
            let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
            for &w in &wrow[lo..hi] {
                mn = mn.min(w);
                mx = mx.max(w);
            }
            let sbits = f32_to_f16((mx - mn) / 15.0);
            let mbits = f32_to_f16(mn);
            scale[r * ng + g] = sbits;
            min[r * ng + g] = mbits;
            let s = f16_to_f32_fast(sbits);
            let m = f16_to_f32_fast(mbits);
            let denom = if s == 0.0 { 1.0 } else { s };
            for c in lo..hi {
                let q = ((wrow[c] - m) / denom).round_ties_even().clamp(0.0, 15.0) as u8;
                let byte = &mut packed[r * prb + c / 2];
                if c % 2 == 0 {
                    *byte |= q;
                } else {
                    *byte |= q << 4;
                }
            }
            // Q4_1's pad nibble stays 0 (the buffer is pre-zeroed)
        }
    }
    (packed, scale, min)
}

/// Spread 4 packed bytes (8 consecutive 4-bit codes, low nibble first)
/// into a `u64` holding one code per byte lane: byte `k` of the result is
/// the code of element `c + k` when `v` is the little-endian `u32` read
/// of `packed_row[c/2..c/2 + 4]` (even `c`).
///
/// This is the SIMD-friendly unpack used by [`crate::tensor::simd`]: the
/// result is one widening move away from 8 integer lanes, and it feeds
/// exactly the same `s * (q - 8)` / `s * q + m` arithmetic as [`q4_nib`],
/// so vector decode stays bit-identical to [`dq4`] / [`dq4_1`].
#[inline]
pub(crate) fn spread_nibbles8(v: u32) -> u64 {
    let mut w = v as u64;
    // fan the 4 bytes out to one byte per 16-bit lane
    w = (w | (w << 16)) & 0x0000_FFFF_0000_FFFF;
    w = (w | (w << 8)) & 0x00FF_00FF_00FF_00FF;
    // even elements live in the low nibbles (byte lanes 0,2,4,6), odd
    // elements in the high nibbles (byte lanes 1,3,5,7)
    (w & 0x000F_000F_000F_000F) | (((w >> 4) & 0x000F_000F_000F_000F) << 8)
}

// Keep in lock-step with matvec.rs: the dots below must replicate
// `dot_f32`'s reduction shape exactly (8-lane accumulator array over
// full chunks, then a scalar tail) for the bit-exactness contract.
const LANES: usize = 8;

/// `dot(dequant_q4(row), x)` with exactly the [`crate::tensor::dot_f32`]
/// reduction shape — bit-identical to dequantizing the row to f32 first.
#[inline]
pub fn dot_q4(packed_row: &[u8], scale_row: &[u16], x: &[f32]) -> f32 {
    let n = x.len();
    let full = n - n % LANES;
    let mut acc = [0f32; LANES];
    let mut c = 0;
    while c < full {
        for k in 0..LANES {
            acc[k] += dq4(packed_row, scale_row, c + k) * x[c + k];
        }
        c += LANES;
    }
    let mut s: f32 = acc.iter().sum();
    for i in full..n {
        s += dq4(packed_row, scale_row, i) * x[i];
    }
    s
}

/// `dot(dequant_q4_1(row), x)` with the [`crate::tensor::dot_f32`] shape.
#[inline]
pub fn dot_q4_1(packed_row: &[u8], scale_row: &[u16], min_row: &[u16], x: &[f32]) -> f32 {
    let n = x.len();
    let full = n - n % LANES;
    let mut acc = [0f32; LANES];
    let mut c = 0;
    while c < full {
        for k in 0..LANES {
            acc[k] += dq4_1(packed_row, scale_row, min_row, c + k) * x[c + k];
        }
        c += LANES;
    }
    let mut s: f32 = acc.iter().sum();
    for i in full..n {
        s += dq4_1(packed_row, scale_row, min_row, i) * x[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matvec::dot_f32;
    use crate::util::XorShift;

    fn randv(r: &mut XorShift, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn q4_round_trip_error_bounded_by_half_step() {
        let mut r = XorShift::new(0x51);
        for &cols in &[32usize, 64, 33, 31, 7] {
            let data = randv(&mut r, 2 * cols);
            let (packed, scale) = quantize_q4(2, cols, &data);
            let (prb, ng) = (q4_row_packed_bytes(cols), q4_groups(cols));
            for row in 0..2 {
                let mut dec = vec![0f32; cols];
                dequant_row_q4(&packed[row * prb..], &scale[row * ng..], &mut dec);
                for c in 0..cols {
                    let s = crate::util::f16::f16_to_f32(scale[row * ng + c / Q4_GROUP]);
                    let err = (dec[c] - data[row * cols + c]).abs();
                    // within one quantization step of the group scale
                    // (half-step plus f16 rounding slack)
                    assert!(err <= s * 0.51 + 1e-6, "c={c} err={err} s={s}");
                }
            }
        }
    }

    #[test]
    fn q4_1_round_trip_error_bounded_by_half_step() {
        let mut r = XorShift::new(0x52);
        for &cols in &[32usize, 48, 17] {
            // shift positive so the asymmetric format's min/offset matters
            let data: Vec<f32> = randv(&mut r, 3 * cols).iter().map(|v| v.abs() + 0.5).collect();
            let (packed, scale, min) = quantize_q4_1(3, cols, &data);
            let (prb, ng) = (q4_row_packed_bytes(cols), q4_groups(cols));
            for row in 0..3 {
                let mut dec = vec![0f32; cols];
                dequant_row_q4_1(
                    &packed[row * prb..],
                    &scale[row * ng..],
                    &min[row * ng..],
                    &mut dec,
                );
                for c in 0..cols {
                    let g = c / Q4_GROUP;
                    let s = crate::util::f16::f16_to_f32(scale[row * ng + g]);
                    let err = (dec[c] - data[row * cols + c]).abs();
                    // half-step + f16 rounding of both scale and min
                    let slack = s * 0.51 + data[row * cols + c].abs() * 1e-3 + 1e-6;
                    assert!(err <= slack, "c={c} err={err} s={s}");
                }
            }
        }
    }

    #[test]
    fn odd_cols_pad_nibble_is_inert() {
        // cols=5: the high nibble of byte 2 is padding; Q4 stores 8
        // (dequantizes to 0), Q4_1 stores 0 — neither can leak into
        // element values, which only ever index c < cols.
        let data = vec![0.5f32, -0.25, 0.125, 1.0, -1.0];
        let (packed, scale) = quantize_q4(1, 5, &data);
        assert_eq!(packed.len(), 3);
        assert_eq!(packed[2] >> 4, 8, "Q4 pad nibble must be offset-binary zero");
        let mut dec = vec![0f32; 5];
        dequant_row_q4(&packed, &scale, &mut dec);
        for (d, w) in dec.iter().zip(&data) {
            assert!((d - w).abs() < 0.2, "{d} vs {w}");
        }
        let (packed1, _, _) = quantize_q4_1(1, 5, &data);
        assert_eq!(packed1[2] >> 4, 0, "Q4_1 pad nibble must be 0");
    }

    #[test]
    fn all_zero_group_survives_zero_scale() {
        let data = vec![0f32; 64];
        let (packed, scale) = quantize_q4(1, 64, &data);
        let mut dec = vec![1f32; 64];
        dequant_row_q4(&packed, &scale, &mut dec);
        assert!(dec.iter().all(|&v| v == 0.0));
        let (packed1, scale1, min1) = quantize_q4_1(1, 64, &data);
        let mut dec1 = vec![1f32; 64];
        dequant_row_q4_1(&packed1, &scale1, &min1, &mut dec1);
        assert!(dec1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn spread_nibbles8_matches_q4_nib() {
        let mut r = XorShift::new(0x54);
        let packed: Vec<u8> = (0..16).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for c in [0usize, 2, 8, 16, 24] {
            let v = u32::from_le_bytes(packed[c / 2..c / 2 + 4].try_into().unwrap());
            let spread = spread_nibbles8(v);
            for k in 0..8 {
                assert_eq!(
                    ((spread >> (8 * k)) & 0xF) as u8,
                    q4_nib(&packed, c + k),
                    "c={c} k={k}"
                );
            }
        }
    }

    #[test]
    fn dot_q4_bitwise_matches_dense_dot_on_dequant() {
        let mut r = XorShift::new(0x53);
        for &cols in &[8usize, 32, 40, 37, 5, 96] {
            let data = randv(&mut r, cols);
            let x = randv(&mut r, cols);
            let (packed, scale) = quantize_q4(1, cols, &data);
            let mut dec = vec![0f32; cols];
            dequant_row_q4(&packed, &scale, &mut dec);
            assert_eq!(dot_q4(&packed, &scale, &x), dot_f32(&dec, &x), "cols={cols}");

            let (p1, s1, m1) = quantize_q4_1(1, cols, &data);
            let mut dec1 = vec![0f32; cols];
            dequant_row_q4_1(&p1, &s1, &m1, &mut dec1);
            assert_eq!(dot_q4_1(&p1, &s1, &m1, &x), dot_f32(&dec1, &x), "cols={cols}");
        }
    }
}
