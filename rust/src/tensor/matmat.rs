//! Fused multi-vector (matrix x batch-of-vectors) kernels — the batched
//! decode hot path.
//!
//! A scheduling round with B concurrent requests used to call the matvec
//! kernels B times per weight matrix, streaming every weight byte B times.
//! These kernels invert the loop: each weight ROW is streamed exactly once
//! per round and applied to all B activation vectors while it is hot, so a
//! decode round costs ~one pass over the weights regardless of B (the
//! memory-bandwidth argument of the paper's §3.2/§5 applied cross-request).
//!
//! Batch layout is row-major `(B, dim)` flat slices: slot `s` of `xs` is
//! `xs[s*dim..(s+1)*dim]`.  Every kernel is BIT-IDENTICAL per slot to its
//! matvec.rs counterpart: the per-slot accumulation order (weight rows in
//! ascending index, the same dot reductions, the same i8 scale folding) is
//! preserved exactly, so the batched engine path produces the same logits
//! as the per-slot path down to the last ulp.
//!
//! Inner loops keep the matvec.rs shape LLVM auto-vectorizes: contiguous
//! slices, iterator zips (no bounds checks), f32 accumulation, and the
//! LANES accumulator-array dots from matvec.rs for the row-layout forms.
//!
//! The engine drives resident weights ([`Mat`]) through `matmat_in_out` /
//! `matmat_rows` directly.  The indexed forms (`matmat_rows_indexed`,
//! `accum_rows_indexed_batch`) are the resident-weight counterparts of the
//! union-fused sparse FFN; the mmap-streaming engine path implements the
//! same loop over `RowView` (engine::sparse_ffn::sparse_ffn_apply_batch),
//! and these kernels double as the reference that path is tested against.

use crate::tensor::matvec::{dot_f16, dot_f32, dot_i8};
use crate::tensor::Mat;
use crate::util::f16::f16_to_f32_fast as f16_to_f32;

/// Batched `(in, out)`-layout apply:
/// `outs[s][j] += sum_i xs[s][i] * w[i][j]` for every slot `s`.
///
/// `xs` is `(B, rows)` flat, `outs` is `(B, cols)` flat; `outs` may carry a
/// residual accumulator (as in matvec).  `scratch` is caller-owned so the
/// hot loop is allocation-free: the f16 arm uses `cols` floats to decode
/// each weight row once per round, the i8 arm uses `B*cols` floats for the
/// per-slot unscaled accumulators (the per-column scale must apply to only
/// THIS product, exactly as in `matvec_in_out`).
pub fn matmat_in_out(xs: &[f32], w: &Mat, outs: &mut [f32], scratch: &mut Vec<f32>) {
    let (rows, cols) = (w.rows(), w.cols());
    assert!(rows > 0 && cols > 0, "empty weight matrix");
    assert_eq!(xs.len() % rows, 0, "xs not a whole number of slots");
    let b = xs.len() / rows;
    assert_eq!(outs.len(), b * cols);
    match w {
        Mat::F32 { data, .. } => {
            for i in 0..rows {
                let row = &data[i * cols..(i + 1) * cols];
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    let out = &mut outs[s * cols..(s + 1) * cols];
                    for (o, &wij) in out.iter_mut().zip(row) {
                        *o += xi * wij;
                    }
                }
            }
        }
        Mat::F16 { data, .. } => {
            scratch.clear();
            scratch.resize(cols, 0.0);
            for i in 0..rows {
                // decode the f16 row once; every slot reuses the f32 copy
                for (r, &h) in scratch.iter_mut().zip(&data[i * cols..(i + 1) * cols]) {
                    *r = f16_to_f32(h);
                }
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    let out = &mut outs[s * cols..(s + 1) * cols];
                    for (o, &wij) in out.iter_mut().zip(scratch.iter()) {
                        *o += xi * wij;
                    }
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            scratch.clear();
            scratch.resize(b * cols, 0.0);
            for i in 0..rows {
                let row = &data[i * cols..(i + 1) * cols];
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    let acc = &mut scratch[s * cols..(s + 1) * cols];
                    for (a, &q) in acc.iter_mut().zip(row) {
                        *a += xi * q as f32;
                    }
                }
            }
            for s in 0..b {
                let out = &mut outs[s * cols..(s + 1) * cols];
                let acc = &scratch[s * cols..(s + 1) * cols];
                for ((o, &a), &sc) in out.iter_mut().zip(acc).zip(scale) {
                    *o += a * sc;
                }
            }
        }
    }
}

/// Batched row-per-output apply: `outs[s][j] = dot(w[j], xs[s])`.
/// `xs` is `(B, cols)` flat, `outs` is `(B, rows)` flat.  Each weight row
/// is read once and dotted against all B activations while cached.
pub fn matmat_rows(w: &Mat, xs: &[f32], outs: &mut [f32]) {
    let (rows, cols) = (w.rows(), w.cols());
    assert!(rows > 0 && cols > 0, "empty weight matrix");
    assert_eq!(xs.len() % cols, 0, "xs not a whole number of slots");
    let b = xs.len() / cols;
    assert_eq!(outs.len(), b * rows);
    match w {
        Mat::F32 { data, .. } => {
            for j in 0..rows {
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * rows + j] = dot_f32(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::F16 { data, .. } => {
            for j in 0..rows {
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * rows + j] = dot_f16(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            for j in 0..rows {
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * rows + j] = scale[j] * dot_i8(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
    }
}

/// Batched sparse row-layout apply: `outs[s][k] = dot(w[idx[k]], xs[s])`.
/// `xs` is `(B, cols)` flat, `outs` is `(B, idx.len())` flat.  The §3.2
/// union-compute path: the caller passes the cross-slot UNION of predicted
/// rows so each selected row streams once per round for every slot.
pub fn matmat_rows_indexed(w: &Mat, idx: &[u32], xs: &[f32], outs: &mut [f32]) {
    let cols = w.cols();
    assert!(cols > 0, "empty weight matrix");
    assert_eq!(xs.len() % cols, 0, "xs not a whole number of slots");
    let b = xs.len() / cols;
    let k = idx.len();
    assert_eq!(outs.len(), b * k);
    match w {
        Mat::F32 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let j = j as usize;
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * k + kk] = dot_f32(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::F16 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let j = j as usize;
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * k + kk] = dot_f16(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let j = j as usize;
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * k + kk] = scale[j] * dot_i8(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
    }
}

/// Batched sparse accumulate of selected `(in,out)`-layout rows:
/// `outs[s][:] += sum_k hs[s][k] * w[idx[k]][:]` — the W_v half of the
/// union-fused sparse FFN.  `hs` is `(B, idx.len())` flat, `outs` is
/// `(B, cols)` flat and MUST be zeroed by the caller for the i8 arm (the
/// per-column scale is folded over the whole accumulator at the end,
/// mirroring `accum_rows_indexed`).  Slots mask themselves by passing
/// `hs[s][k] == 0.0` for union rows outside their own predicted set —
/// zero entries are skipped exactly as the per-slot kernel skips them.
pub fn accum_rows_indexed_batch(w: &Mat, idx: &[u32], hs: &[f32], b: usize, outs: &mut [f32]) {
    let cols = w.cols();
    let k = idx.len();
    assert_eq!(hs.len(), b * k);
    assert_eq!(outs.len(), b * cols);
    match w {
        Mat::F32 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let row = &data[j as usize * cols..(j as usize + 1) * cols];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    let out = &mut outs[s * cols..(s + 1) * cols];
                    for (o, &wv) in out.iter_mut().zip(row) {
                        *o += hk * wv;
                    }
                }
            }
        }
        Mat::F16 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let row = &data[j as usize * cols..(j as usize + 1) * cols];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    let out = &mut outs[s * cols..(s + 1) * cols];
                    for (o, &hh) in out.iter_mut().zip(row) {
                        *o += hk * f16_to_f32(hh);
                    }
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let row = &data[j as usize * cols..(j as usize + 1) * cols];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    let out = &mut outs[s * cols..(s + 1) * cols];
                    for (o, &q) in out.iter_mut().zip(row) {
                        *o += hk * q as f32;
                    }
                }
            }
            for s in 0..b {
                let out = &mut outs[s * cols..(s + 1) * cols];
                for (o, &sc) in out.iter_mut().zip(scale) {
                    *o *= sc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matvec::{
        accum_rows_indexed, matvec_in_out, matvec_rows, matvec_rows_indexed,
    };
    use crate::util::XorShift;

    fn randv(r: &mut XorShift, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal()).collect()
    }

    /// The three dtype variants of one f32 matrix (i8 scale per column for
    /// in-out layout, per row for rows layout — chosen by `scale_rows`).
    fn variants(rows: usize, cols: usize, data: &[f32], scale_rows: bool) -> Vec<Mat> {
        let q: Vec<i8> = data.iter().map(|v| (v * 30.0).clamp(-127.0, 127.0) as i8).collect();
        let scale_len = if scale_rows { rows } else { cols };
        let scale: Vec<f32> = (0..scale_len).map(|i| 0.01 + 0.001 * i as f32).collect();
        vec![
            Mat::from_f32(rows, cols, data.to_vec()),
            Mat::f32_to_f16_mat(rows, cols, data),
            Mat::I8 { rows, cols, data: q, scale },
        ]
    }

    #[test]
    fn matmat_in_out_bitwise_matches_matvec_per_slot() {
        let mut r = XorShift::new(11);
        let (rows, cols) = (23, 17);
        let data = randv(&mut r, rows * cols);
        for w in variants(rows, cols, &data, false) {
            for b in [1usize, 2, 5] {
                let xs = randv(&mut r, b * rows);
                // residual content must be preserved identically too
                let residual = randv(&mut r, b * cols);
                let mut outs = residual.clone();
                let mut scratch = Vec::new();
                matmat_in_out(&xs, &w, &mut outs, &mut scratch);
                for s in 0..b {
                    let mut want = residual[s * cols..(s + 1) * cols].to_vec();
                    let mut acc = Vec::new();
                    matvec_in_out(&xs[s * rows..(s + 1) * rows], &w, &mut want, &mut acc);
                    assert_eq!(&outs[s * cols..(s + 1) * cols], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn matmat_rows_bitwise_matches_matvec_per_slot() {
        let mut r = XorShift::new(12);
        let (rows, cols) = (19, 21);
        let data = randv(&mut r, rows * cols);
        for w in variants(rows, cols, &data, true) {
            for b in [1usize, 3, 8] {
                let xs = randv(&mut r, b * cols);
                let mut outs = vec![0.0f32; b * rows];
                matmat_rows(&w, &xs, &mut outs);
                for s in 0..b {
                    let mut want = vec![0.0f32; rows];
                    matvec_rows(&w, &xs[s * cols..(s + 1) * cols], &mut want);
                    assert_eq!(&outs[s * rows..(s + 1) * rows], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn matmat_rows_indexed_bitwise_matches_matvec_per_slot() {
        let mut r = XorShift::new(13);
        let (rows, cols) = (29, 11);
        let data = randv(&mut r, rows * cols);
        let idx = vec![0u32, 3, 7, 8, 20, 28];
        for w in variants(rows, cols, &data, true) {
            for b in [1usize, 4] {
                let xs = randv(&mut r, b * cols);
                let mut outs = vec![0.0f32; b * idx.len()];
                matmat_rows_indexed(&w, &idx, &xs, &mut outs);
                for s in 0..b {
                    let mut want = vec![0.0f32; idx.len()];
                    matvec_rows_indexed(&w, &idx, &xs[s * cols..(s + 1) * cols], &mut want);
                    assert_eq!(&outs[s * idx.len()..(s + 1) * idx.len()], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn accum_batch_bitwise_matches_accum_per_slot() {
        let mut r = XorShift::new(14);
        let (rows, cols) = (26, 9); // (F, D) layout
        let data = randv(&mut r, rows * cols);
        let idx = vec![1u32, 4, 5, 12, 25];
        for w in variants(rows, cols, &data, false) {
            for b in [1usize, 3] {
                let mut hs = randv(&mut r, b * idx.len());
                // sprinkle zeros: masked-out union rows must be skipped
                for (i, h) in hs.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *h = 0.0;
                    }
                }
                let mut outs = vec![0.0f32; b * cols];
                accum_rows_indexed_batch(&w, &idx, &hs, b, &mut outs);
                let k = idx.len();
                for s in 0..b {
                    let mut want = vec![0.0f32; cols];
                    accum_rows_indexed(&w, &idx, &hs[s * k..(s + 1) * k], &mut want);
                    assert_eq!(&outs[s * cols..(s + 1) * cols], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn single_slot_equals_matvec_on_empty_index() {
        // degenerate sparse round: no predicted rows at all
        let w = Mat::from_f32(4, 3, vec![1.0; 12]);
        let mut outs = vec![0.0f32; 3];
        accum_rows_indexed_batch(&w, &[], &[], 1, &mut outs);
        assert_eq!(outs, vec![0.0, 0.0, 0.0]);
        let xs = vec![1.0f32, 2.0, 3.0];
        let mut o = vec![0.0f32; 0];
        matmat_rows_indexed(&w, &[], &xs, &mut o);
        assert!(o.is_empty());
    }
}
