//! Fused multi-vector (matrix x batch-of-vectors) kernels — the batched
//! decode hot path, serial or pool-sharded through ONE entry point per
//! kernel driven by a [`Par`] handle.
//!
//! A scheduling round with B concurrent requests used to call the matvec
//! kernels B times per weight matrix, streaming every weight byte B times.
//! These kernels invert the loop: each weight ROW is streamed exactly once
//! per round and applied to all B activation vectors while it is hot, so a
//! decode round costs ~one pass over the weights regardless of B (the
//! memory-bandwidth argument of the paper's §3.2/§5 applied cross-request).
//!
//! # Dtype support matrix
//!
//! | kernel                        | f32 | f16 | i8 (scale)        | q4/q4_1 (group) |
//! |-------------------------------|-----|-----|-------------------|-----------------|
//! | [`matmat_in_out`]             | yes | yes | per-column        | yes             |
//! | [`matmat_rows`]               | yes | yes | per-row           | yes             |
//! | [`matmat_rows_indexed`]       | yes | yes | per-row           | yes             |
//! | [`accum_rows_indexed_batch`]  | yes | yes | per-column        | yes             |
//!
//! The q4/q4_1 arms dequantize in-register ([`crate::tensor::q4`]); each
//! element's f32 value is a pure function of the stored bytes, so the
//! column sharding below may split MID-group and stay bit-identical.
//!
//! Low-rank / enhanced-SVD projections (§3.1) are compositions of
//! `matmat_in_out` over their factor matrices (see
//! `engine::weights::ProjW::apply_batch`), so they inherit both the dtype
//! matrix and the sharding below.
//!
//! # Kernel dispatch
//!
//! Each public entry point resolves the active
//! [`crate::tensor::simd::Kernels`] table ONCE and threads it through its
//! range core, so the dot / widen / axpy inner loops run on the selected
//! backend without per-row dispatch.  Every backend is bit-identical to
//! the scalar reference, so nothing below this paragraph depends on which
//! one is active.
//!
//! # Batch layout and bit-identity
//!
//! Batch layout is row-major `(B, dim)` flat slices: slot `s` of `xs` is
//! `xs[s*dim..(s+1)*dim]`.  Every kernel is BIT-IDENTICAL per slot to its
//! matvec.rs counterpart: the per-slot accumulation order (weight rows in
//! ascending index, the same dot reductions, the same i8 scale folding) is
//! preserved exactly, so the batched engine path produces the same logits
//! as the per-slot path down to the last ulp.
//!
//! # Sharding contract (the `Par` argument)
//!
//! Each kernel splits its **output elements** into disjoint contiguous
//! ranges and computes each range on one lane of a
//! [`crate::pool::ThreadPool`] (deterministic static chunking; inline when
//! the [`Par`] handle has no pool — pass [`Par::serial`] for the plain
//! serial kernel):
//!
//! * row-per-output kernels (`matmat_rows`, `matmat_rows_indexed`) shard
//!   over **output rows** — each lane streams a disjoint contiguous slice
//!   of the weight matrix;
//! * `(in, out)`-layout kernels (`matmat_in_out`,
//!   `accum_rows_indexed_batch`) shard over **output columns** — each lane
//!   streams a disjoint column slice of every weight row.
//!
//! Either way every weight byte is read exactly once per round across all
//! lanes, and every output element is written by exactly one lane.
//!
//! # Determinism guarantee
//!
//! The value of each output element is computed by the *same* sequence of
//! floating-point operations in every sharding (the split never cuts
//! through a reduction: reductions run over weight-row index inside a
//! single lane, in ascending order, exactly as with [`Par::serial`]), so
//! pool-sharded results are bit-identical to serial for EVERY pool
//! size — the engine's `threads ∈ {1, 2, 8}` equivalence tests
//! (`tests/thread_equivalence.rs`) enforce this end to end.
//!
//! Inner loops keep the matvec.rs shape: contiguous slices, iterator zips
//! (no bounds checks), f32 accumulation, and the LANES accumulator-array
//! dots shared through the kernel table.
//!
//! The engine drives resident weights ([`Mat`]) through `matmat_in_out` /
//! `matmat_rows` directly.  The indexed forms (`matmat_rows_indexed`,
//! `accum_rows_indexed_batch`) are the resident-weight counterparts of the
//! union-fused sparse FFN; the mmap-streaming engine path implements the
//! same loop over `RowView` (engine::sparse_ffn::sparse_ffn_apply_batch),
//! and these kernels double as the reference that path is tested against.

use crate::pool::{Par, SharedSliceMut};
use crate::tensor::q4::{q4_groups, q4_row_packed_bytes};
use crate::tensor::simd::{self, Kernels};
use crate::tensor::Mat;

/// Grow a per-lane scratch pool to `lanes` entries (capacity is retained
/// across rounds, so the hot loop stays allocation-free after warm-up).
fn ensure_lanes(scratch: &mut Vec<Vec<f32>>, lanes: usize) {
    while scratch.len() < lanes {
        scratch.push(Vec::new());
    }
}

// ---------------------------------------------------------------------------
// (in, out) layout — shard over output COLUMNS
// ---------------------------------------------------------------------------

/// Column-range core of [`matmat_in_out`]: computes output columns
/// `[c0, c1)` for every slot (reads `w[i][c0..c1]` of every weight row —
/// a disjoint weight slice per lane).  Per-column accumulation order is
/// identical to the full-range kernel, hence bit-identical.
fn matmat_in_out_cols(
    k: &Kernels,
    xs: &[f32],
    w: &Mat,
    outs: &mut [f32],
    scratch: &mut Vec<f32>,
    c0: usize,
    c1: usize,
) {
    let (rows, cols) = (w.rows(), w.cols());
    let b = xs.len() / rows;
    let cw = c1 - c0;
    match w {
        Mat::F32 { data, .. } => {
            for i in 0..rows {
                let row = &data[i * cols + c0..i * cols + c1];
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    (k.axpy_f32)(xi, row, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
        Mat::F16 { data, .. } => {
            scratch.clear();
            scratch.resize(cw, 0.0);
            for i in 0..rows {
                // decode the f16 row slice once; every slot reuses it
                (k.widen_f16)(&data[i * cols + c0..i * cols + c1], scratch);
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    (k.axpy_f32)(xi, scratch, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            scratch.clear();
            scratch.resize(b * cw, 0.0);
            for i in 0..rows {
                let row = &data[i * cols + c0..i * cols + c1];
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    (k.axpy_i8)(xi, row, &mut scratch[s * cw..(s + 1) * cw]);
                }
            }
            for s in 0..b {
                let out = &mut outs[s * cols + c0..s * cols + c1];
                let acc = &scratch[s * cw..(s + 1) * cw];
                for ((o, &a), &sc) in out.iter_mut().zip(acc).zip(&scale[c0..c1]) {
                    *o += a * sc;
                }
            }
        }
        Mat::Q4 { data, scale, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            scratch.clear();
            scratch.resize(cw, 0.0);
            for i in 0..rows {
                // dequantize the column window once; every slot reuses the
                // exact f32 values the per-slot matvec arm computes
                let prow = &data[i * prb..(i + 1) * prb];
                let srow = &scale[i * ng..(i + 1) * ng];
                (k.widen_q4)(prow, srow, c0, scratch);
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    (k.axpy_f32)(xi, scratch, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            scratch.clear();
            scratch.resize(cw, 0.0);
            for i in 0..rows {
                let prow = &data[i * prb..(i + 1) * prb];
                let srow = &scale[i * ng..(i + 1) * ng];
                let mrow = &min[i * ng..(i + 1) * ng];
                (k.widen_q4_1)(prow, srow, mrow, c0, scratch);
                for s in 0..b {
                    let xi = xs[s * rows + i];
                    if xi == 0.0 {
                        continue;
                    }
                    (k.axpy_f32)(xi, scratch, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
    }
}

/// Batched `(in, out)`-layout apply:
/// `outs[s][j] += sum_i xs[s][i] * w[i][j]` for every slot `s`, sharded
/// over output columns across `par`'s lanes (inline with [`Par::serial`]
/// or no pool).  Bit-identical for every pool size.
///
/// `xs` is `(B, rows)` flat, `outs` is `(B, cols)` flat; `outs` may carry
/// a residual accumulator (as in matvec).  `scratch` holds one kernel
/// scratch per lane, caller-owned so the hot loop is allocation-free: the
/// f16/q4 arms use a column-window decode buffer per lane, the i8 arm
/// `B*window` floats for the per-slot unscaled accumulators (the
/// per-column scale must apply to only THIS product, exactly as in
/// `matvec_in_out`).
pub fn matmat_in_out(
    xs: &[f32],
    w: &Mat,
    outs: &mut [f32],
    scratch: &mut Vec<Vec<f32>>,
    par: Par<'_>,
) {
    let (rows, cols) = (w.rows(), w.cols());
    assert!(rows > 0 && cols > 0, "empty weight matrix");
    assert_eq!(xs.len() % rows, 0, "xs not a whole number of slots");
    let b = xs.len() / rows;
    assert_eq!(outs.len(), b * cols);
    let k = simd::kernels();
    ensure_lanes(scratch, par.lanes());
    let out_view = SharedSliceMut::new(outs);
    let scr_view = SharedSliceMut::new(scratch);
    par.run(cols, &|chunk, c0, c1| {
        out_view.debug_claim(c0, c1);
        scr_view.debug_claim(chunk, chunk + 1);
        // SAFETY: each lane writes only output columns [c0, c1) of every
        // slot and scratch entry `chunk` — disjoint ranges, asserted by
        // the claims above in debug builds.
        let outs = unsafe { out_view.get() };
        // SAFETY: as above — scratch entry `chunk` belongs to this lane.
        let scr = &mut unsafe { scr_view.get() }[chunk];
        matmat_in_out_cols(k, xs, w, outs, scr, c0, c1);
    });
}

// ---------------------------------------------------------------------------
// (out, in) row-per-output layout — shard over output ROWS
// ---------------------------------------------------------------------------

/// Row-range core of [`matmat_rows`]: output rows `[j0, j1)` for every
/// slot (streams the contiguous weight rows `w[j0..j1]` — a disjoint
/// weight slice per lane).
fn matmat_rows_range(k: &Kernels, w: &Mat, xs: &[f32], outs: &mut [f32], j0: usize, j1: usize) {
    let (rows, cols) = (w.rows(), w.cols());
    let b = xs.len() / cols;
    match w {
        Mat::F32 { data, .. } => {
            for j in j0..j1 {
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * rows + j] = (k.dot_f32)(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::F16 { data, .. } => {
            for j in j0..j1 {
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * rows + j] = (k.dot_f16)(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            for j in j0..j1 {
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * rows + j] =
                        scale[j] * (k.dot_i8)(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::Q4 { data, scale, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for j in j0..j1 {
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                for s in 0..b {
                    outs[s * rows + j] = (k.dot_q4)(prow, srow, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for j in j0..j1 {
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                let mrow = &min[j * ng..(j + 1) * ng];
                for s in 0..b {
                    outs[s * rows + j] =
                        (k.dot_q4_1)(prow, srow, mrow, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
    }
}

/// Batched row-per-output apply: `outs[s][j] = dot(w[j], xs[s])`, sharded
/// over output rows across `par`'s lanes — each lane streams a disjoint
/// contiguous slice of the weight matrix (inline with [`Par::serial`]).
/// `xs` is `(B, cols)` flat, `outs` is `(B, rows)` flat.  Each weight row
/// is read once and dotted against all B activations while cached.
pub fn matmat_rows(w: &Mat, xs: &[f32], outs: &mut [f32], par: Par<'_>) {
    let (rows, cols) = (w.rows(), w.cols());
    assert!(rows > 0 && cols > 0, "empty weight matrix");
    assert_eq!(xs.len() % cols, 0, "xs not a whole number of slots");
    let b = xs.len() / cols;
    assert_eq!(outs.len(), b * rows);
    let k = simd::kernels();
    let out_view = SharedSliceMut::new(outs);
    par.run(rows, &|_chunk, j0, j1| {
        out_view.debug_claim(j0, j1);
        // SAFETY: each lane writes only output rows [j0, j1) of every
        // slot — disjoint index sets, claimed above in debug builds.
        let outs = unsafe { out_view.get() };
        matmat_rows_range(k, w, xs, outs, j0, j1);
    });
}

/// Index-range core of [`matmat_rows_indexed`]: selected positions
/// `[k0, k1)` of `idx` for every slot.
fn matmat_rows_indexed_range(
    kern: &Kernels,
    w: &Mat,
    idx: &[u32],
    xs: &[f32],
    outs: &mut [f32],
    k0: usize,
    k1: usize,
) {
    let cols = w.cols();
    let b = xs.len() / cols;
    let k = idx.len();
    match w {
        Mat::F32 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate().take(k1).skip(k0) {
                let j = j as usize;
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * k + kk] = (kern.dot_f32)(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::F16 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate().take(k1).skip(k0) {
                let j = j as usize;
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * k + kk] = (kern.dot_f16)(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            for (kk, &j) in idx.iter().enumerate().take(k1).skip(k0) {
                let j = j as usize;
                let row = &data[j * cols..(j + 1) * cols];
                for s in 0..b {
                    outs[s * k + kk] =
                        scale[j] * (kern.dot_i8)(row, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::Q4 { data, scale, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (kk, &j) in idx.iter().enumerate().take(k1).skip(k0) {
                let j = j as usize;
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                for s in 0..b {
                    outs[s * k + kk] = (kern.dot_q4)(prow, srow, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (kk, &j) in idx.iter().enumerate().take(k1).skip(k0) {
                let j = j as usize;
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                let mrow = &min[j * ng..(j + 1) * ng];
                for s in 0..b {
                    outs[s * k + kk] =
                        (kern.dot_q4_1)(prow, srow, mrow, &xs[s * cols..(s + 1) * cols]);
                }
            }
        }
    }
}

/// Batched sparse row-layout apply: `outs[s][k] = dot(w[idx[k]], xs[s])`,
/// sharded over the selected index positions — each lane streams a
/// disjoint subset of the selected weight rows.
/// `xs` is `(B, cols)` flat, `outs` is `(B, idx.len())` flat.  The §3.2
/// union-compute path: the caller passes the cross-slot UNION of predicted
/// rows so each selected row streams once per round for every slot.
pub fn matmat_rows_indexed(w: &Mat, idx: &[u32], xs: &[f32], outs: &mut [f32], par: Par<'_>) {
    let cols = w.cols();
    assert!(cols > 0, "empty weight matrix");
    assert_eq!(xs.len() % cols, 0, "xs not a whole number of slots");
    let b = xs.len() / cols;
    assert_eq!(outs.len(), b * idx.len());
    let kern = simd::kernels();
    let out_view = SharedSliceMut::new(outs);
    par.run(idx.len(), &|_chunk, k0, k1| {
        out_view.debug_claim(k0, k1);
        // SAFETY: each lane writes only selected positions [k0, k1) of
        // every slot — disjoint `kk` sets, claimed above in debug builds.
        let outs = unsafe { out_view.get() };
        matmat_rows_indexed_range(kern, w, idx, xs, outs, k0, k1);
    });
}

/// Column-range core of [`accum_rows_indexed_batch`]: accumulates output
/// columns `[c0, c1)`.  Row visit order (ascending `kk`) per column is
/// unchanged, hence bit-identical to the full-range kernel.
fn accum_rows_indexed_batch_cols(
    kern: &Kernels,
    w: &Mat,
    idx: &[u32],
    hs: &[f32],
    b: usize,
    outs: &mut [f32],
    c0: usize,
    c1: usize,
) {
    let cols = w.cols();
    let k = idx.len();
    match w {
        Mat::F32 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let row = &data[j as usize * cols + c0..j as usize * cols + c1];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    (kern.axpy_f32)(hk, row, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
        Mat::F16 { data, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let row = &data[j as usize * cols + c0..j as usize * cols + c1];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    (kern.axpy_f16)(hk, row, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
        Mat::I8 { data, scale, .. } => {
            for (kk, &j) in idx.iter().enumerate() {
                let row = &data[j as usize * cols + c0..j as usize * cols + c1];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    (kern.axpy_i8)(hk, row, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
            for s in 0..b {
                let out = &mut outs[s * cols + c0..s * cols + c1];
                for (o, &sc) in out.iter_mut().zip(&scale[c0..c1]) {
                    *o *= sc;
                }
            }
        }
        Mat::Q4 { data, scale, .. } => {
            // group scales fold in per element (no end-of-loop column
            // fold), mirroring `accum_rows_indexed`'s q4 arm exactly
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (kk, &j) in idx.iter().enumerate() {
                let j = j as usize;
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    (kern.axpy_q4)(hk, prow, srow, c0, &mut outs[s * cols + c0..s * cols + c1]);
                }
            }
        }
        Mat::Q41 { data, scale, min, .. } => {
            let (ng, prb) = (q4_groups(cols), q4_row_packed_bytes(cols));
            for (kk, &j) in idx.iter().enumerate() {
                let j = j as usize;
                let prow = &data[j * prb..(j + 1) * prb];
                let srow = &scale[j * ng..(j + 1) * ng];
                let mrow = &min[j * ng..(j + 1) * ng];
                for s in 0..b {
                    let hk = hs[s * k + kk];
                    if hk == 0.0 {
                        continue;
                    }
                    (kern.axpy_q4_1)(
                        hk,
                        prow,
                        srow,
                        mrow,
                        c0,
                        &mut outs[s * cols + c0..s * cols + c1],
                    );
                }
            }
        }
    }
}

/// Batched sparse accumulate of selected `(in,out)`-layout rows:
/// `outs[s][:] += sum_k hs[s][k] * w[idx[k]][:]` — the W_v half of the
/// union-fused sparse FFN, sharded over output columns — each lane
/// accumulates a disjoint column slice of every selected weight row, in
/// the same ascending row order as the serial kernel.  `hs` is
/// `(B, idx.len())` flat, `outs` is `(B, cols)` flat and MUST be zeroed
/// by the caller for the i8 arm (the per-column scale is folded over the
/// whole accumulator at the end, mirroring `accum_rows_indexed`).  Slots
/// mask themselves by passing `hs[s][k] == 0.0` for union rows outside
/// their own predicted set — zero entries are skipped exactly as the
/// per-slot kernel skips them.
pub fn accum_rows_indexed_batch(
    w: &Mat,
    idx: &[u32],
    hs: &[f32],
    b: usize,
    outs: &mut [f32],
    par: Par<'_>,
) {
    let cols = w.cols();
    let k = idx.len();
    assert_eq!(hs.len(), b * k);
    assert_eq!(outs.len(), b * cols);
    let kern = simd::kernels();
    let out_view = SharedSliceMut::new(outs);
    par.run(cols, &|_chunk, c0, c1| {
        out_view.debug_claim(c0, c1);
        // SAFETY: each lane accumulates only output columns [c0, c1) of
        // every slot — disjoint ranges, claimed above in debug builds.
        let outs = unsafe { out_view.get() };
        accum_rows_indexed_batch_cols(kern, w, idx, hs, b, outs, c0, c1);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::ThreadPool;
    use crate::tensor::matvec::{
        accum_rows_indexed, matvec_in_out, matvec_rows, matvec_rows_indexed,
    };
    use crate::util::XorShift;

    fn randv(r: &mut XorShift, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal()).collect()
    }

    /// The five dtype variants of one f32 matrix (i8 scale per column for
    /// in-out layout, per row for rows layout — chosen by `scale_rows`;
    /// q4/q4_1 group parameters are layout-independent).
    fn variants(rows: usize, cols: usize, data: &[f32], scale_rows: bool) -> Vec<Mat> {
        let q: Vec<i8> = data.iter().map(|v| (v * 30.0).clamp(-127.0, 127.0) as i8).collect();
        let scale_len = if scale_rows { rows } else { cols };
        let scale: Vec<f32> = (0..scale_len).map(|i| 0.01 + 0.001 * i as f32).collect();
        vec![
            Mat::from_f32(rows, cols, data.to_vec()),
            Mat::f32_to_f16_mat(rows, cols, data),
            Mat::I8 { rows, cols, data: q, scale },
            Mat::quantize_q4_mat(rows, cols, data),
            Mat::quantize_q4_1_mat(rows, cols, data),
        ]
    }

    #[test]
    fn matmat_in_out_bitwise_matches_matvec_per_slot() {
        let mut r = XorShift::new(11);
        let (rows, cols) = (23, 17);
        let data = randv(&mut r, rows * cols);
        for w in variants(rows, cols, &data, false) {
            for b in [1usize, 2, 5] {
                let xs = randv(&mut r, b * rows);
                // residual content must be preserved identically too
                let residual = randv(&mut r, b * cols);
                let mut outs = residual.clone();
                let mut scratch = Vec::new();
                matmat_in_out(&xs, &w, &mut outs, &mut scratch, Par::serial());
                for s in 0..b {
                    let mut want = residual[s * cols..(s + 1) * cols].to_vec();
                    let mut acc = Vec::new();
                    matvec_in_out(&xs[s * rows..(s + 1) * rows], &w, &mut want, &mut acc);
                    assert_eq!(&outs[s * cols..(s + 1) * cols], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn matmat_rows_bitwise_matches_matvec_per_slot() {
        let mut r = XorShift::new(12);
        let (rows, cols) = (19, 21);
        let data = randv(&mut r, rows * cols);
        for w in variants(rows, cols, &data, true) {
            for b in [1usize, 3, 8] {
                let xs = randv(&mut r, b * cols);
                let mut outs = vec![0.0f32; b * rows];
                matmat_rows(&w, &xs, &mut outs, Par::serial());
                for s in 0..b {
                    let mut want = vec![0.0f32; rows];
                    matvec_rows(&w, &xs[s * cols..(s + 1) * cols], &mut want);
                    assert_eq!(&outs[s * rows..(s + 1) * rows], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn matmat_rows_indexed_bitwise_matches_matvec_per_slot() {
        let mut r = XorShift::new(13);
        let (rows, cols) = (29, 11);
        let data = randv(&mut r, rows * cols);
        let idx = vec![0u32, 3, 7, 8, 20, 28];
        for w in variants(rows, cols, &data, true) {
            for b in [1usize, 4] {
                let xs = randv(&mut r, b * cols);
                let mut outs = vec![0.0f32; b * idx.len()];
                matmat_rows_indexed(&w, &idx, &xs, &mut outs, Par::serial());
                for s in 0..b {
                    let mut want = vec![0.0f32; idx.len()];
                    matvec_rows_indexed(&w, &idx, &xs[s * cols..(s + 1) * cols], &mut want);
                    assert_eq!(&outs[s * idx.len()..(s + 1) * idx.len()], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn accum_batch_bitwise_matches_accum_per_slot() {
        let mut r = XorShift::new(14);
        let (rows, cols) = (26, 9); // (F, D) layout
        let data = randv(&mut r, rows * cols);
        let idx = vec![1u32, 4, 5, 12, 25];
        for w in variants(rows, cols, &data, false) {
            for b in [1usize, 3] {
                let mut hs = randv(&mut r, b * idx.len());
                // sprinkle zeros: masked-out union rows must be skipped
                for (i, h) in hs.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *h = 0.0;
                    }
                }
                let mut outs = vec![0.0f32; b * cols];
                accum_rows_indexed_batch(&w, &idx, &hs, b, &mut outs, Par::serial());
                let k = idx.len();
                for s in 0..b {
                    let mut want = vec![0.0f32; cols];
                    accum_rows_indexed(&w, &idx, &hs[s * k..(s + 1) * k], &mut want);
                    assert_eq!(&outs[s * cols..(s + 1) * cols], &want[..], "slot {s}");
                }
            }
        }
    }

    #[test]
    fn single_slot_equals_matvec_on_empty_index() {
        // degenerate sparse round: no predicted rows at all
        let w = Mat::from_f32(4, 3, vec![1.0; 12]);
        let mut outs = vec![0.0f32; 3];
        accum_rows_indexed_batch(&w, &[], &[], 1, &mut outs, Par::serial());
        assert_eq!(outs, vec![0.0, 0.0, 0.0]);
        let xs = vec![1.0f32, 2.0, 3.0];
        let mut o = vec![0.0f32; 0];
        matmat_rows_indexed(&w, &[], &xs, &mut o, Par::serial());
        assert!(o.is_empty());
    }

    /// Every kernel must be BITWISE identical between [`Par::serial`] and
    /// pool-backed [`Par`] handles for every dtype and several pool sizes
    /// (including pools larger than the work) — the sharding contract of
    /// the module docs.
    #[test]
    fn par_kernels_bitwise_match_serial_for_all_pool_sizes() {
        let mut r = XorShift::new(15);
        let (rows, cols) = (23, 19);
        let data = randv(&mut r, rows * cols);
        let idx = vec![1u32, 2, 6, 9, 14, 21, 22];
        let pools: Vec<ThreadPool> =
            vec![ThreadPool::new(1), ThreadPool::new(3), ThreadPool::new(8)];
        for scale_rows in [false, true] {
            for w in variants(rows, cols, &data, scale_rows) {
                let b = 3usize;
                // --- matmat_in_out (B, rows) -> (B, cols)
                if !scale_rows {
                    let xs = randv(&mut r, b * rows);
                    let residual = randv(&mut r, b * cols);
                    let mut want = residual.clone();
                    matmat_in_out(&xs, &w, &mut want, &mut Vec::new(), Par::serial());
                    for pool in &pools {
                        let mut got = residual.clone();
                        let mut scr = Vec::new();
                        matmat_in_out(&xs, &w, &mut got, &mut scr, Par::new(Some(pool)));
                        assert_eq!(got, want, "in_out, pool={}", pool.workers());
                    }
                    // --- accum_rows_indexed_batch (per-column scale)
                    let mut hs = randv(&mut r, b * idx.len());
                    for (i, h) in hs.iter_mut().enumerate() {
                        if i % 4 == 0 {
                            *h = 0.0;
                        }
                    }
                    let mut want = vec![0.0f32; b * cols];
                    accum_rows_indexed_batch(&w, &idx, &hs, b, &mut want, Par::serial());
                    for pool in &pools {
                        let mut got = vec![0.0f32; b * cols];
                        accum_rows_indexed_batch(
                            &w,
                            &idx,
                            &hs,
                            b,
                            &mut got,
                            Par::new(Some(pool)),
                        );
                        assert_eq!(got, want, "accum, pool={}", pool.workers());
                    }
                } else {
                    // --- matmat_rows / matmat_rows_indexed (per-row scale)
                    let xs = randv(&mut r, b * cols);
                    let mut want = vec![0.0f32; b * rows];
                    matmat_rows(&w, &xs, &mut want, Par::serial());
                    for pool in &pools {
                        let mut got = vec![0.0f32; b * rows];
                        matmat_rows(&w, &xs, &mut got, Par::new(Some(pool)));
                        assert_eq!(got, want, "rows, pool={}", pool.workers());
                    }
                    let mut want = vec![0.0f32; b * idx.len()];
                    matmat_rows_indexed(&w, &idx, &xs, &mut want, Par::serial());
                    for pool in &pools {
                        let mut got = vec![0.0f32; b * idx.len()];
                        matmat_rows_indexed(&w, &idx, &xs, &mut got, Par::new(Some(pool)));
                        assert_eq!(got, want, "rows_indexed, pool={}", pool.workers());
                    }
                }
            }
        }
    }

    #[test]
    fn par_without_pool_runs_inline() {
        let w = Mat::from_f32(4, 5, (0..20).map(|i| i as f32).collect());
        let xs = vec![1.0f32, 0.5, -1.0, 2.0];
        let mut want = vec![0.0f32; 5];
        matmat_in_out(&xs, &w, &mut want, &mut Vec::new(), Par::serial());
        let mut got = vec![0.0f32; 5];
        matmat_in_out(&xs, &w, &mut got, &mut Vec::new(), Par::new(None));
        assert_eq!(got, want);
    }
}
