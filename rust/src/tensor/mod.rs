//! Tensor substrate (S13): weight matrices in f32 / f16 / int8 /
//! group-quantized 4-bit (Q4/Q4_1, [`q4`]) / 1-bit representations and
//! the fused matvec kernels over them.
//!
//! This module is the rust analog of the paper's custom ARM NEON kernels
//! (§4): dequantization is fused into the matvec inner loop so a separate
//! dequantized weight copy never exists.  Loops are written to
//! auto-vectorize (contiguous accumulate-over-rows / dot-per-row forms).
//!
//! Two orientations, matching the `.rkv` layouts (python/compile/export.py):
//! * `(in, out)` "in-out": `out[j] += x[i] * w[i][j]` — used by square
//!   projections and `wv`.
//! * `(out, in)` "row-per-output": `out[j] = dot(w[j], x)` — used by
//!   `wk_t`, `head`, `emb`, where the sparse/hierarchical loaders need
//!   contiguous per-neuron / per-token rows.
//!
//! Each orientation exists in two arities: single-vector ([`matvec`], the
//! per-slot decode path) and multi-vector ([`matmat`], the batched decode
//! path that streams each weight row once per scheduling round and applies
//! it to all B slot activations — bit-identical per slot to matvec).  The
//! multi-vector kernels take a [`crate::pool::Par`] handle: serial and
//! pool-sharded execution share ONE entry point each, sharded over
//! disjoint output ranges of a [`crate::pool::ThreadPool`] and
//! bit-identical for every pool size (see the `matmat` module docs for
//! the sharding contract and determinism guarantee).
//!
//! The hot inner loops (dots, fused dequant-dots, f16/q4 widening, row
//! axpys) are routed through [`simd`]: one runtime-dispatched kernel
//! table per instruction set (scalar / NEON / AVX2), resolved once per
//! matrix pass and bit-identical across backends, selectable at engine
//! load via `--simd`.

pub mod mat;
pub mod matmat;
pub mod matvec;
pub mod ops;
pub mod q4;
pub mod simd;

pub use mat::{DType, Mat};
pub use matmat::*;
pub use matvec::*;
pub use ops::*;
pub use q4::*;
pub use simd::{Kernels, SimdBackend};
