//! Element-wise / normalization ops used by the native engine.
//!
//! These are deliberately NOT routed through the [`crate::tensor::simd`]
//! kernel table: they are O(dim) per token (vs the kernels' O(dim²)),
//! their reductions are whole-vector (a different shape from the LANES=8
//! dot tree), and keeping them scalar keeps one reference implementation
//! for the normalization arithmetic the state tests pin.

/// LayerNorm: `out = (x - mean) / sqrt(var + eps) * scale + bias`.
pub fn layer_norm(x: &[f32], scale: &[f32], bias: &[f32], eps: f32, out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * scale[i] + bias[i];
    }
}

/// Per-head GroupNorm (the RWKV `ln_x`): normalize each of `heads` groups
/// independently, then apply the full-width affine.  Matches the jax
/// `_group_norm_heads` (eps = 64e-5, the official head_size-scaled eps).
pub fn group_norm_heads(x: &mut [f32], heads: usize, scale: &[f32], bias: &[f32]) {
    let hs = x.len() / heads;
    for h in 0..heads {
        let seg = &mut x[h * hs..(h + 1) * hs];
        let n = hs as f32;
        let mean = seg.iter().sum::<f32>() / n;
        let var = seg.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let inv = 1.0 / (var + 64e-5).sqrt();
        for v in seg.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
    for i in 0..x.len() {
        x[i] = x[i] * scale[i] + bias[i];
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// RWKV token-shift lerp: `out = x * mu + prev * (1 - mu)`.
pub fn lerp_shift(x: &[f32], prev: &[f32], mu: &[f32], out: &mut [f32]) {
    for i in 0..x.len() {
        out[i] = x[i] * mu[i] + prev[i] * (1.0 - mu[i]);
    }
}

/// GELU (tanh approximation, matches jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

/// `relu(x)^2` in place — the RWKV channel-mix nonlinearity.
pub fn sqrelu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        let r = v.max(0.0);
        *v = r * r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let scale = [1.0f32; 4];
        let bias = [0.0f32; 4];
        let mut out = [0f32; 4];
        layer_norm(&x, &scale, &bias, 1e-5, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn group_norm_normalizes_each_head() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let scale = vec![1.0f32; 8];
        let bias = vec![0.0f32; 8];
        group_norm_heads(&mut x, 2, &scale, &bias);
        for h in 0..2 {
            let seg = &x[h * 4..(h + 1) * 4];
            let mean: f32 = seg.iter().sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "head {h} mean {mean}");
        }
    }

    #[test]
    fn sqrelu_suppresses_negative() {
        let mut x = vec![-1.0f32, 0.0, 2.0];
        sqrelu_inplace(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 4.0]);
    }

    #[test]
    fn sigmoid_and_silu_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(silu(0.0).abs() < 1e-6);
        assert!(silu(5.0) > 4.9);
    }
}
